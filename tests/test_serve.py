import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import greedy_generate, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "recurrentgemma-2b",
                                  "xlstm-125m"])
def test_greedy_generate_consistency(arch):
    """Greedy generation via prefill+decode must equal re-scoring the
    generated prefix with the parallel forward pass at every step."""
    cfg = configs.get(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    n_new = 4
    out = greedy_generate(model, params, prompt, n_new, cache_len=16)
    assert out.shape == (2, 6 + n_new)
    # teacher-forced check: feeding out[:, :-1] reproduces each greedy pick
    logits, _ = jax.jit(lambda p, t: model.forward_train(p, t))(
        params, out[:, :-1])
    for i in range(n_new):
        pos = 6 + i - 1
        want = logits[:, pos].argmax(-1)
        np.testing.assert_array_equal(np.asarray(want),
                                      np.asarray(out[:, 6 + i]))


def test_prefill_last_only_shape():
    cfg = configs.get("qwen3-4b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, caches = jax.jit(make_prefill_step(model, 16))(
        params, {"tokens": toks})
    assert logits.shape == (2, 1, cfg.vocab)


def test_decode_pos_advances_cache():
    cfg = configs.get("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(2, 8)
    dec = jax.jit(make_decode_step(model))
    toks = jnp.ones((2, 1), jnp.int32)
    _, caches = dec(params, caches, toks, jnp.asarray(0, jnp.int32))
    seg = next(iter(caches.values()))
    assert int(seg["attn"]["pos"][0]) == 1
