"""CLI launcher smoke tests (subprocess, tiny workloads)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

_SRC = str(Path(__file__).parent.parent / "src")


def _run(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, "-m"] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli_runs_and_resumes(tmp_path):
    args = ["repro.launch.train", "--arch", "xlstm-125m", "--smoke",
            "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path)]
    p1 = _run(args + ["--steps", "6", "--ckpt-every", "3"])
    assert p1.returncode == 0, p1.stderr[-2000:]
    assert "done" in p1.stdout
    p2 = _run(args + ["--steps", "9", "--ckpt-every", "3"])
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 5" in p2.stdout


@pytest.mark.slow
def test_serve_cli(tmp_path):
    p = _run(["repro.launch.serve", "--n-docs", "48", "--queries", "16",
              "--concurrency", "8", "--no-warmup"])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "accuracy vs ground truth: 16/16" in p.stdout
    assert "p50=" in p.stdout and "dispatch[" in p.stdout
