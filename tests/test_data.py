import numpy as np
import pytest

from repro.core import dna
from repro.data import (make_corpus, make_queries, mutate, random_genome,
                        read_fasta, write_fasta)


def test_corpus_shapes_and_determinism():
    a = make_corpus(10, k=9, mean_length=200, seed=4)
    b = make_corpus(10, k=9, mean_length=200, seed=4)
    assert a.n_docs == 10
    for x, y in zip(a.documents, b.documents):
        np.testing.assert_array_equal(x, y)


def test_corpus_size_skew():
    c = make_corpus(300, k=15, mean_length=1000, sigma=1.2, seed=0)
    counts = c.term_counts()
    assert counts.max() > 5 * counts.mean()  # the property motivating COBS


def test_queries_labels_correct():
    c = make_corpus(20, k=9, mean_length=300, seed=1)
    qs, origin = make_queries(c, n_pos=5, n_neg=5, length=50, seed=2)
    assert len(qs) == 10
    u = set()
    for t in c.doc_terms:
        u |= set((t[:, 0].astype(np.uint64)
                  | (t[:, 1].astype(np.uint64) << np.uint64(32))).tolist())
    for q, o in zip(qs, origin):
        terms = dna.pack_kmers(q, c.k)
        t64 = (terms[:, 0].astype(np.uint64)
               | (terms[:, 1].astype(np.uint64) << np.uint64(32)))
        if o >= 0:
            # every k-mer of a positive is in its origin document
            d = c.doc_terms[o]
            d64 = set((d[:, 0].astype(np.uint64)
                       | (d[:, 1].astype(np.uint64) << np.uint64(32))).tolist())
            assert all(int(v) in d64 for v in t64)
        else:
            assert not any(int(v) in u for v in t64)


def test_mutate_rate():
    rng = np.random.default_rng(0)
    g = random_genome(rng, 1000)
    m = mutate(rng, g, 0.1)
    diff = (g != m).mean()
    assert 0.05 < diff < 0.15
    assert mutate(rng, g, 0.0).tolist() == g.tolist()


def test_fasta_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    reads = [random_genome(rng, 50), random_genome(rng, 80)]
    write_fasta(tmp_path / "x.fa", reads)
    back = read_fasta(tmp_path / "x.fa")
    assert len(back) == 2
    for a, b in zip(reads, back):
        np.testing.assert_array_equal(a, b)
