import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hashing


def test_jnp_np_agree():
    rng = np.random.default_rng(0)
    terms = rng.integers(0, 2 ** 32, size=(256, 2), dtype=np.uint32)
    h_np = hashing.hash_terms_np(terms, 4)
    h_j = np.asarray(hashing.hash_terms(jnp.asarray(terms), 4))
    np.testing.assert_array_equal(h_np, h_j)


def test_seeds_differ():
    terms = np.array([[123, 456]], dtype=np.uint32)
    h = hashing.hash_terms_np(terms, 4)[0]
    assert len(set(h.tolist())) == 4


def test_deterministic():
    terms = np.array([[1, 2], [3, 4]], dtype=np.uint32)
    a = hashing.hash_terms_np(terms, 2)
    b = hashing.hash_terms_np(terms, 2)
    np.testing.assert_array_equal(a, b)


def test_avalanche():
    """Flipping one input bit should flip ~half the output bits on average —
    this is what makes the modulo range reduction safe."""
    rng = np.random.default_rng(1)
    terms = rng.integers(0, 2 ** 32, size=(2000, 2), dtype=np.uint32)
    h0 = hashing.hash_terms_np(terms, 1)[:, 0]
    flipped = terms.copy()
    flipped[:, 0] ^= np.uint32(1) << rng.integers(0, 32, 2000, dtype=np.uint32)
    h1 = hashing.hash_terms_np(flipped, 1)[:, 0]
    diff = np.unpackbits((h0 ^ h1).view(np.uint8)).mean() * 32
    assert 14.0 < diff < 18.0  # ideal 16


def test_uniformity_modulo():
    """After mod w the distribution should be near-uniform (chi-square)."""
    rng = np.random.default_rng(2)
    terms = rng.integers(0, 2 ** 32, size=(50_000, 2), dtype=np.uint32)
    h = hashing.hash_terms_np(terms, 1)[:, 0]
    w = 64
    counts = np.bincount(h % w, minlength=w).astype(np.float64)
    expected = len(h) / w
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # dof = 63; P(chi2 > 120) << 0.001
    assert chi2 < 120.0


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 2 ** 32 - 1))
def test_property_no_trivial_collisions(lo, hi):
    """Nearby inputs never collide under any of the first 4 seeds."""
    t = np.array([[lo, hi], [lo ^ 1, hi], [lo, hi ^ 1]], dtype=np.uint32)
    h = hashing.hash_terms_np(t, 4)
    assert (h[0] != h[1]).all()
    assert (h[0] != h[2]).all()
