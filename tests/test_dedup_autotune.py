"""PR-4 coverage: the batched row-dedup scoring path and the kernel
autotuner.

Dedup invariants (the acceptance bar): the dedup pair must be BIT-
identical to the fused multi-query kernel and the jnp ref across shapes,
including fully-duplicate and fully-disjoint row sets — dedup is pure
re-addressing, never a semantic change. Tuner invariants: the on-disk
cache round-trips, a reopened tuner serves without re-measuring, and the
planner's method choice follows measured costs when present.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IndexParams, build_compact
from repro.core.query import (QueryEngine, plan_dedup_batch,
                              make_dedup_score_fn, pad_term_batch,
                              compile_pattern)
from repro.core.store import save_index_v2, load_index_v2, tuning_path
from repro.data import make_corpus, make_queries
from repro.kernels import ops, ref
from repro.kernels.autotune import (KernelTuner, TunedEntry, TuningCache,
                                    tuning_key)
from repro.serve import QueryServer, ServerConfig
from repro.serve.planner import QueryPlanner, choose_method


# --------------------------------------------------------------------------
# Dedup kernels == fused multi == oracle
# --------------------------------------------------------------------------

def _dedup_inputs(rng, Q, nb, L, R, duplication):
    """Row batch [Q, nb, L] + its dedup addressing. duplication: 'disjoint'
    = every cell a distinct row, 'dup' = all cells share very few rows,
    'mixed' = uniform draws."""
    n = Q * nb * L
    if duplication == "disjoint":
        idx = rng.permutation(max(R, n))[:n] % R
    elif duplication == "dup":
        idx = rng.choice(rng.integers(0, R, size=max(1, n // 8)), size=n)
    else:
        idx = rng.integers(0, R, size=n)
    idx = idx.reshape(Q, nb, L).astype(np.int32)
    uniq, inv = np.unique(idx, return_inverse=True)
    pad = max(8, 1 << max(0, uniq.size - 1).bit_length())
    uniq_pad = np.zeros(pad, dtype=np.int32)
    uniq_pad[: uniq.size] = uniq
    return idx, uniq_pad, inv.reshape(idx.shape).astype(np.int32)


@pytest.mark.parametrize("duplication", ["disjoint", "dup", "mixed"])
@pytest.mark.parametrize("Q,nb,L,W", [(2, 1, 8, 8), (3, 2, 17, 40),
                                      (4, 1, 33, 130)])
def test_dedup_matches_multi_and_ref(Q, nb, L, W, duplication):
    rng = np.random.default_rng(Q * 100 + L + len(duplication))
    R = 4 * Q * nb * L + 1
    arena = rng.integers(0, 2 ** 32, size=(R, W), dtype=np.uint32)
    idx, uniq_pad, indir = _dedup_inputs(rng, Q, nb, L, R, duplication)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_dedup_ref(
        jnp.asarray(arena), jnp.asarray(uniq_pad), jnp.asarray(indir),
        jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_dedup(
        jnp.asarray(arena), jnp.asarray(uniq_pad), jnp.asarray(indir),
        jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)
    fused = np.asarray(ops.bitslice_lookup_score_multi(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(fused, got)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 2), st.integers(1, 24),
       st.integers(1, 20), st.integers(0, 2 ** 31),
       st.sampled_from(["disjoint", "dup", "mixed"]))
def test_property_dedup_equals_fused_and_oracle(Q, nb, L, W, seed,
                                                duplication):
    rng = np.random.default_rng(seed)
    R = 2 * Q * nb * L + 1
    arena = rng.integers(0, 2 ** 32, size=(R, W), dtype=np.uint32)
    idx, uniq_pad, indir = _dedup_inputs(rng, Q, nb, L, R, duplication)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_multi_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_dedup(
        jnp.asarray(arena), jnp.asarray(uniq_pad), jnp.asarray(indir),
        jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)
    fused = np.asarray(ops.bitslice_lookup_score_multi(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, fused)


def test_grid_order_variants_bit_identical():
    rng = np.random.default_rng(5)
    Q, nb, L, W = 3, 2, 17, 40
    arena = rng.integers(0, 2 ** 32, size=(128, W), dtype=np.uint32)
    idx = rng.integers(0, 128, size=(Q, nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    a, i, m = jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)
    wq = np.asarray(ops.bitslice_lookup_score_multi(a, i, m,
                                                    grid_order="wq"))
    qw = np.asarray(ops.bitslice_lookup_score_multi(a, i, m,
                                                    grid_order="qw"))
    np.testing.assert_array_equal(wq, qw)


def test_word_block_variants_bit_identical():
    rng = np.random.default_rng(6)
    Q, nb, L, W = 2, 1, 16, 96
    arena = rng.integers(0, 2 ** 32, size=(80, W), dtype=np.uint32)
    idx = rng.integers(0, 80, size=(Q, nb, L)).astype(np.int32)
    mask = np.ones((Q, nb, L), dtype=np.int32)
    a, i, m = jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)
    base = np.asarray(ops.bitslice_lookup_score_multi(a, i, m))
    for wb in (8, 32):
        np.testing.assert_array_equal(
            base, np.asarray(ops.bitslice_lookup_score_multi(
                a, i, m, word_block=wb)))


# --------------------------------------------------------------------------
# Host-side dedup planning
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dedup_index():
    c = make_corpus(48, k=15, mean_length=400, sigma=1.0, seed=7)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    return c, build_compact(c.doc_terms, params, block_docs=32,
                            row_align=64)


def test_plan_dedup_batch_addressing(dedup_index):
    """uniq_rows[indir] must reproduce the exact rows the fused kernel
    would gather on every live cell."""
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=11)
    term_sets = [compile_pattern(q, idx.params) for q in qs]
    buf, ells = pad_term_batch(term_sets, 64)
    dp = plan_dedup_batch(buf, ells, np.asarray(idx.layout.row_offset),
                          np.asarray(idx.layout.block_width))
    from repro.core import hashing
    h = hashing.hash_terms_np(buf, 1)[..., 0]
    rows = (h[..., None] % idx.layout.block_width.astype(np.uint32)
            + idx.layout.row_offset.astype(np.uint32))
    rows = np.swapaxes(rows, 1, 2).astype(np.int64)        # [Q, nb, L]
    live = dp.mask.astype(bool)
    np.testing.assert_array_equal(dp.uniq_rows[dp.indir][live], rows[live])
    # validity mask matches ells
    L = buf.shape[1]
    want_valid = np.arange(L)[None, :] < ells[:, None]
    np.testing.assert_array_equal(
        dp.mask[:, 0, :].astype(bool), want_valid)


def test_dedup_rate_duplicate_vs_disjoint(dedup_index):
    """Duplicate queries drive the measured dedup rate up; the traffic
    accounting shows >= 2x fewer row gathers at ~90% duplication — the
    acceptance criterion's property at planning level."""
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=2, n_neg=0, length=120, seed=13)
    base = compile_pattern(qs[0], idx.params)
    ro = np.asarray(idx.layout.row_offset)
    bw = np.asarray(idx.layout.block_width)
    # 10 copies of one query ~ 90% duplicate gathers
    buf, ells = pad_term_batch([base] * 10, 64)
    dp_dup = plan_dedup_batch(buf, ells, ro, bw)
    assert dp_dup.dedup_rate >= 0.85
    assert dp_dup.n_gathers >= 2 * dp_dup.n_unique
    # distinct queries: low duplication
    term_sets = [compile_pattern(q, idx.params) for q in qs]
    buf2, ells2 = pad_term_batch(term_sets, 64)
    dp_dis = plan_dedup_batch(buf2, ells2, ro, bw)
    assert dp_dis.dedup_rate < dp_dup.dedup_rate


def test_dedup_score_fn_matches_engine(dedup_index):
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=17)
    qs = qs + qs[:2]
    term_sets = [compile_pattern(q, idx.params) for q in qs]
    buf, ells = pad_term_batch(term_sets, 64)
    eng = QueryEngine(idx, method="lookup")
    want = eng.score_terms_batch(buf, ells)
    dp = plan_dedup_batch(buf, ells, np.asarray(idx.layout.row_offset),
                          np.asarray(idx.layout.block_width))
    fn = make_dedup_score_fn()
    slots = np.asarray(fn(idx.storage.full_device(),
                          jnp.asarray(dp.uniq_rows), jnp.asarray(dp.indir),
                          jnp.asarray(dp.mask)))
    got = slots[:, np.asarray(idx.layout.doc_slot)]
    np.testing.assert_array_equal(want, got)


# --------------------------------------------------------------------------
# Serving integration: dedup path end-to-end (dense + paged)
# --------------------------------------------------------------------------

def _serve(index, cfg, queries, threshold=0.8):
    s = QueryServer(index, cfg)
    ids = [s.submit(q, threshold=threshold) for q in queries]
    s.drain()
    resp = s.pop_responses()
    out = []
    for rid in ids:
        r = resp[rid].result
        out.append((tuple(r.doc_ids.tolist()), tuple(r.scores.tolist())))
    return s, out


def test_server_dedup_bit_identical_dense(dedup_index):
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=120, seed=19)
    qs = qs + qs[:3]                      # duplicates -> dedup fires
    s_on, r_on = _serve(idx, ServerConfig(result_cache=0, row_cache=0,
                                          dedup_min_rate=0.0), qs)
    s_off, r_off = _serve(idx, ServerConfig(result_cache=0, row_cache=0,
                                            dedup_min_rate=None), qs)
    assert r_on == r_off
    assert s_on.planner.dispatch_counts.get("dedup", 0) > 0
    assert "dedup" not in s_off.planner.dispatch_counts


def test_server_dedup_bit_identical_paged(dedup_index, tmp_path):
    c, idx = dedup_index
    store = tmp_path / "store"
    save_index_v2(idx, store, blocks_per_shard=1)
    v2 = load_index_v2(store)
    assert v2.storage.n_shards > 1        # really paged
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=120, seed=23)
    qs = qs + qs[:3]
    _, r_on = _serve(v2, ServerConfig(result_cache=0, row_cache=0,
                                      dedup_min_rate=0.0), qs)
    _, r_off = _serve(v2, ServerConfig(result_cache=0, row_cache=0,
                                       dedup_min_rate=None), qs)
    _, r_dense = _serve(idx, ServerConfig(result_cache=0, row_cache=0,
                                          dedup_min_rate=None), qs)
    assert r_on == r_off == r_dense


def test_server_dedup_threshold_gates(dedup_index):
    """A threshold above the batch's measured rate keeps the fused path."""
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=4, n_neg=0, length=120, seed=29)
    s, _ = _serve(idx, ServerConfig(result_cache=0, row_cache=0,
                                    dedup_min_rate=0.99), qs)
    assert s.planner.dispatch_counts.get("dedup", 0) == 0


def test_server_word_block_end_to_end(dedup_index):
    """ServerConfig.word_block reaches the kernels and never changes
    results."""
    c, idx = dedup_index
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=31)
    _, base = _serve(idx, ServerConfig(result_cache=0, row_cache=0), qs)
    for wb in (16, 64):
        s, got = _serve(idx, ServerConfig(result_cache=0, row_cache=0,
                                          word_block=wb), qs)
        assert got == base
        assert s.planner.word_block == wb
        assert all(p.word_block == wb for p in
                   [s.planner.plan(64, 4), s.planner.plan(128, 1)])


# --------------------------------------------------------------------------
# Autotuner + tuning cache
# --------------------------------------------------------------------------

def test_tuning_cache_round_trip(tmp_path):
    path = tmp_path / "tuning.json"
    cache = TuningCache(path)
    e1 = TunedEntry("lookup", 128, 8, "qw", 123.4, dedup_threshold=0.4)
    e2 = TunedEntry("vertical", 64, 16, "wq", 56.7)
    cache.put("k1", e1)
    cache.put("k2", e2)
    cache.save()
    reopened = TuningCache(path)
    assert len(reopened) == 2
    assert reopened.get("k1") == e1
    assert reopened.get("k2") == e2
    assert reopened.hits == 2 and reopened.misses == 0
    # the payload is versioned json beside the manifest
    data = json.loads(path.read_text())
    assert data["version"] == 1 and "k1" in data["entries"]


def test_tuning_cache_version_mismatch_falls_back_empty(tmp_path):
    """A cache written by a different build must not take serving down:
    the file is treated as empty (invalid flag set) and heuristics apply
    until re-tuning rewrites it in the current format."""
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps({"version": 999, "entries": {}}))
    cache = TuningCache(path)
    assert cache.invalid and len(cache) == 0
    assert cache.get("anything") is None          # pure miss, no crash


def test_tuner_persists_and_reopens_without_retuning(dedup_index, tmp_path):
    _, idx = dedup_index
    path = tmp_path / "tuning.json"
    tuner = KernelTuner.for_index(idx, TuningCache(path), word_blocks=(8,),
                                  term_blocks=(8,), repeats=1,
                                  max_tune_rows=64, max_tune_blocks=1)
    e = tuner.entry("lookup", 64, 4)
    assert e is not None and tuner.tunes == 1
    assert e.word_block == 8
    # same tuner: cache hit, no second measurement
    assert tuner.entry("lookup", 64, 4) == e and tuner.tunes == 1
    # reopened tuner (fresh process analogue): disk hit, zero measurements
    tuner2 = KernelTuner.for_index(idx, TuningCache(path))
    assert tuner2.entry("lookup", 64, 4) == e
    assert tuner2.tunes == 0


def test_tuner_disabled_never_measures(dedup_index):
    _, idx = dedup_index
    tuner = KernelTuner.for_index(idx, enabled=False)
    assert tuner.entry("lookup", 64, 4) is None
    assert tuner.tunes == 0


def test_tuning_key_shape_sensitivity():
    k1 = tuning_key(100, 4, 1, 3, "lookup", 64, 4)
    assert k1 != tuning_key(101, 4, 1, 3, "lookup", 64, 4)
    assert k1 != tuning_key(100, 4, 1, 3, "lookup", 64, 8)
    assert k1 == tuning_key(100, 4, 1, 3, "lookup", 64, 4)


def test_choose_method_consults_costs():
    # heuristic: batched k=1 -> lookup
    assert choose_method(1, 64, 8) == "lookup"
    # measured costs flip it
    costs = {"lookup": 100.0, "unpack": 10.0, "vertical": 50.0}
    assert choose_method(1, 64, 8, costs=costs) == "unpack"
    # lookup cost ignored when k > 1 (method does not apply)
    assert choose_method(2, 64, 8, costs={"lookup": 1.0, "vertical": 9.0}) \
        == "vertical"
    # deterministic tie-break
    assert choose_method(1, 64, 8, costs={"vertical": 5.0, "unpack": 5.0}) \
        == "unpack"


def test_planner_uses_cached_measurements(dedup_index):
    """Pre-seeded cache entries drive method, tile config, and the dedup
    threshold without any measurement in the serving path."""
    _, idx = dedup_index
    cache = TuningCache()
    tuner = KernelTuner.for_index(idx, cache, enabled=False)
    cache.put(tuner.key("lookup", 64, 4),
              TunedEntry("lookup", 32, 8, "qw", 20.0, dedup_threshold=0.25))
    cache.put(tuner.key("vertical", 64, 4),
              TunedEntry("vertical", 64, 16, "wq", 90.0))
    cache.put(tuner.key("unpack", 64, 4),
              TunedEntry("unpack", 64, 8, "wq", 80.0))
    planner = QueryPlanner(idx, tuner=tuner)
    plan = planner.plan(64, 4)
    assert plan.method == "lookup"
    assert plan.word_block == 32 and plan.grid_order == "qw"
    assert plan.dedup_threshold == 0.25
    assert tuner.tunes == 0
    # flip the measurements: vertical now cheapest
    cache.put(tuner.key("vertical", 64, 4),
              TunedEntry("vertical", 128, 16, "wq", 5.0))
    plan2 = planner.plan(64, 4)
    assert plan2.method == "vertical"
    assert plan2.word_block == 128 and plan2.term_block == 16


def test_planner_sentinel_threshold_disables_dedup(dedup_index):
    """The tuner's 2.0 'measured, dedup never wins' sentinel must turn
    the plan's threshold OFF entirely — the server then skips the
    per-batch host-side dedup planning instead of computing a rate that
    can never clear the bar."""
    _, idx = dedup_index
    cache = TuningCache()
    tuner = KernelTuner.for_index(idx, cache, enabled=False)
    cache.put(tuner.key("lookup", 64, 4),
              TunedEntry("lookup", 32, 8, "wq", 20.0, dedup_threshold=2.0))
    planner = QueryPlanner(idx, tuner=tuner)
    assert planner.plan(64, 4).dedup_threshold is None
    # an explicit config threshold >= 1 is equally unreachable
    planner2 = QueryPlanner(idx, dedup_min_rate=1.0)
    assert planner2.plan(64, 4).dedup_threshold is None


def test_tuned_server_serves_measured_config(dedup_index, tmp_path):
    """End-to-end: autotune once against a store-side cache, reopen the
    server read-only, verify it plans from disk without re-tuning and
    answers bit-identically to the untuned server."""
    c, idx = dedup_index
    path = tmp_path / "tuning.json"
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=37)
    s1 = QueryServer(idx, ServerConfig(result_cache=0, row_cache=0,
                                       autotune=True,
                                       tuning_cache=str(path)))
    # tiny tuning space so interpret-mode measurement stays fast
    s1.tuner.word_blocks = (8,)
    s1.tuner.term_blocks = (8,)
    s1.tuner.grid_orders = ("wq",)
    s1.tuner.repeats = 1
    s1.tuner.max_tune_rows = 64
    s1.tuner.max_tune_blocks = 1
    ids = [s1.submit(q, threshold=0.8) for q in qs]
    s1.drain()
    resp1 = s1.pop_responses()
    r1 = [resp1[i].result for i in ids]
    assert s1.tuner.tunes > 0 and path.exists()
    # reopen: tuning disabled, cache consulted, zero measurements
    s2 = QueryServer(idx, ServerConfig(result_cache=0, row_cache=0,
                                       tuning_cache=str(path)))
    ids2 = [s2.submit(q, threshold=0.8) for q in qs]
    s2.drain()
    resp2 = s2.pop_responses()
    r2 = [resp2[i].result for i in ids2]
    assert s2.tuner.tunes == 0 and s2.tuner.cache.hits > 0
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)


def test_store_tuning_path_beside_manifest(tmp_path):
    p = tuning_path(tmp_path / "store")
    assert p.parent == tmp_path / "store"
    assert p.name == "tuning.json"
