"""Out-of-core arena tests: layout/storage split, the cobs-jax-v2 shard
store, streaming construction, paged query execution, O(metadata) merges,
and the device tile cache.

The load-bearing invariant throughout: an index built streaming to a v2
store and queried via MappedArena is BIT-IDENTICAL — arena bytes, scores,
hit sets, top-k — to build_compact + DeviceArena."""
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (DeviceArena, DeviceTileCache, IndexParams,
                        MappedArena, QueryEngine, build_compact, load_index,
                        load_index_v2, merge_compact, merge_stores,
                        migrate_v1_to_v2, save_index)
from repro.core.query import plan_shards, select_top_k
from repro.data import make_corpus, make_queries
from repro.index import build_compact_streaming


PARAMS = IndexParams(n_hashes=1, fpr=0.3, kmer=15)


def _corpus(n=64, seed=7, mean=400):
    return make_corpus(n, k=15, mean_length=mean, sigma=1.0, seed=seed)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    c = _corpus(96)
    dense = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = tmp_path_factory.mktemp("store") / "v2"
    mapped, stats = build_compact_streaming(
        c.doc_terms, store, PARAMS, block_docs=32, row_align=64)
    return c, dense, mapped, stats, store


# --------------------------------------------------------------------------
# Streaming build == dense build (the acceptance criterion)
# --------------------------------------------------------------------------

def test_streaming_build_bit_identical(built):
    _, dense, mapped, stats, _ = built
    assert mapped.storage.n_shards == dense.n_blocks > 1
    np.testing.assert_array_equal(mapped.storage.full_host(),
                                  np.asarray(dense.arena))
    np.testing.assert_array_equal(mapped.layout.row_offset,
                                  dense.layout.row_offset)
    np.testing.assert_array_equal(mapped.layout.doc_slot,
                                  dense.layout.doc_slot)
    assert mapped.params == dense.params


def test_streaming_build_peak_memory_is_one_block_group(built):
    """The out-of-core guarantee: the streaming builder's allocation
    accounting must show peak host usage == the largest single shard, not
    the arena (which is several shards big)."""
    _, _, _, stats, _ = built
    assert stats.n_shards > 1
    assert stats.peak_block_bytes == stats.max_shard_bytes
    assert stats.peak_block_bytes < stats.total_arena_bytes


def test_streaming_build_resumes_from_shards(built, tmp_path):
    c, _, mapped, _, _ = built
    store = tmp_path / "v2r"
    full, s1 = build_compact_streaming(c.doc_terms, store, PARAMS,
                                       block_docs=32, row_align=64)
    # simulate crash after some shards: drop the manifest and one shard
    (store / "manifest.json").unlink()
    victims = sorted(store.glob("shard-*.npy"))[1:2]
    for v in victims:
        v.unlink()
    resumed, s2 = build_compact_streaming(c.doc_terms, store, PARAMS,
                                          block_docs=32, row_align=64)
    assert s2.n_resumed == s1.n_shards - 1
    np.testing.assert_array_equal(resumed.storage.full_host(),
                                  mapped.storage.full_host())


def test_mapped_arena_pages_not_loads(built):
    """Opening a v2 store must not read arena bytes: shards stay closed
    until touched, and touched shards come back as read-only memmaps."""
    _, _, _, _, store = built
    idx = load_index(store)                 # dispatches on the v2 manifest
    assert isinstance(idx.storage, MappedArena)
    assert not idx.storage._open            # nothing mapped yet
    a = idx.storage.shard_host(0)
    assert isinstance(a, np.memmap)
    assert len(idx.storage._open) == 1      # only the touched shard


# --------------------------------------------------------------------------
# Paged query == dense query
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["lookup", "vertical", "unpack"])
def test_paged_query_bit_identical(built, method):
    c, dense, mapped, _, _ = built
    ed = QueryEngine(dense, method=method)
    em = QueryEngine(mapped, method=method)
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=120, seed=3)
    for q in qs:
        rd, rm = ed.search(q, 0.7), em.search(q, 0.7)
        np.testing.assert_array_equal(rd.doc_ids, rm.doc_ids)
        np.testing.assert_array_equal(rd.scores, rm.scores)
        assert rd.threshold == rm.threshold
    # every shard was touched: a COBS query gathers one row per block
    assert em.tiles.faults == mapped.storage.n_shards
    assert em.tiles.hits > 0                # later queries hit the cache


def test_paged_batch_query_bit_identical(built):
    c, dense, mapped, _, _ = built
    ed, em = QueryEngine(dense), QueryEngine(mapped)
    qs, _ = make_queries(c, n_pos=3, n_neg=3, length=90, seed=5)
    ra = ed.search_batch(list(qs), threshold=0.6)
    rb = em.search_batch(list(qs), threshold=0.6)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.doc_ids, y.doc_ids)
        np.testing.assert_array_equal(x.scores, y.scores)


@settings(max_examples=8, deadline=None)
@given(st.integers(24, 80), st.integers(0, 10**6), st.integers(40, 200),
       st.sampled_from(["lookup", "vertical"]))
def test_property_mapped_equals_device(n_docs, seed, qlen, method):
    """Property sweep: random corpora/queries, shard-per-block stores —
    MappedArena and DeviceArena return byte-identical scores and top-k.
    block_docs=32 on up to 80 docs gives up to 3 blocks, so query terms
    always address rows across shard boundaries."""
    import tempfile
    c = _corpus(n_docs, seed=seed % 1000, mean=300)
    dense = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = Path(tempfile.mkdtemp()) / "store"
    mapped, _ = build_compact_streaming(c.doc_terms, store, PARAMS,
                                        block_docs=32, row_align=64)
    qs, _ = make_queries(c, n_pos=2, n_neg=1, length=qlen,
                         seed=seed % 97)
    ed = QueryEngine(dense, method=method)
    em = QueryEngine(mapped, method=method)
    for q in qs:
        import repro.core.dna as dna
        terms = dna.unique_terms(dna.pack_kmers(q, 15))
        sd, sm = ed.score_terms(terms), em.score_terms(terms)
        np.testing.assert_array_equal(sd, sm)
        td = select_top_k(sd, terms.shape[0], 5)
        tm = select_top_k(sm, terms.shape[0], 5)
        np.testing.assert_array_equal(td.doc_ids, tm.doc_ids)
        np.testing.assert_array_equal(td.scores, tm.scores)


# --------------------------------------------------------------------------
# Persistence: v2 round trip, v1 compat, migration, integrity
# --------------------------------------------------------------------------

def test_save_index_v2_roundtrip(built, tmp_path):
    _, dense, _, _, _ = built
    save_index(dense, tmp_path / "v2", version=2, blocks_per_shard=2)
    idx = load_index(tmp_path / "v2")
    assert isinstance(idx.storage, MappedArena)
    assert idx.storage.n_shards == (dense.n_blocks + 1) // 2
    np.testing.assert_array_equal(idx.storage.full_host(),
                                  np.asarray(dense.arena))


def test_v1_indexes_still_load(built, tmp_path):
    _, dense, _, _, _ = built
    save_index(dense, tmp_path / "v1")            # default stays v1
    man = json.loads((tmp_path / "v1" / "manifest.json").read_text())
    assert man["format"] == "cobs-jax-v1"
    idx = load_index(tmp_path / "v1")
    np.testing.assert_array_equal(np.asarray(idx.arena),
                                  np.asarray(dense.arena))
    assert idx.params == dense.params


def test_migrate_v1_to_v2(built, tmp_path):
    c, dense, _, _, _ = built
    save_index(dense, tmp_path / "v1")
    migrate_v1_to_v2(tmp_path / "v1", tmp_path / "v2", blocks_per_shard=1)
    idx = load_index(tmp_path / "v2")
    assert isinstance(idx.storage, MappedArena)
    np.testing.assert_array_equal(idx.storage.full_host(),
                                  np.asarray(dense.arena))
    # queries agree end to end
    q, _ = make_queries(c, n_pos=1, n_neg=0, length=100, seed=11)
    ra = QueryEngine(dense).search(q[0], 0.7)
    rb = QueryEngine(idx).search(q[0], 0.7)
    np.testing.assert_array_equal(ra.doc_ids, rb.doc_ids)


def test_v2_verify_detects_corruption(built, tmp_path):
    c, _, _, _, _ = built
    store = tmp_path / "v2c"
    build_compact_streaming(c.doc_terms, store, PARAMS, block_docs=32,
                            row_align=64)
    f = sorted(store.glob("shard-*.npy"))[0]
    a = np.load(f)
    a[0, 0] ^= np.uint32(1)
    np.save(f, a)
    load_index_v2(store)                          # lazy open: fine
    with pytest.raises(IOError):
        load_index_v2(store, verify=True)


# --------------------------------------------------------------------------
# Merges on the new layout
# --------------------------------------------------------------------------

def test_merge_mapped_is_metadata_only(tmp_path):
    ca, cb = _corpus(40, seed=31), _corpus(24, seed=32)
    a, _ = build_compact_streaming(ca.doc_terms, tmp_path / "a", PARAMS,
                                   block_docs=32, row_align=64)
    b, _ = build_compact_streaming(cb.doc_terms, tmp_path / "b", PARAMS,
                                   block_docs=32, row_align=64)
    m = merge_compact(a, b)
    # shard-list concatenation: same sources, nothing materialized
    assert isinstance(m.storage, MappedArena)
    assert m.storage.n_shards == a.storage.n_shards + b.storage.n_shards
    assert m.storage.sources[:a.storage.n_shards] == a.storage.sources
    # merged-then-query == query-then-union (b's ids shift by a.n_docs)
    ea, eb, em = QueryEngine(a), QueryEngine(b), QueryEngine(m)
    for src, seed in ((ca, 33), (cb, 34)):
        qs, _ = make_queries(src, n_pos=3, n_neg=1, length=80, seed=seed)
        for q in qs:
            ra, rb, rm = (e.search(q, 0.8) for e in (ea, eb, em))
            want = set(ra.doc_ids.tolist()) | {
                int(d) + a.n_docs for d in rb.doc_ids}
            assert set(rm.doc_ids.tolist()) == want


def test_merge_stores_links_shards(tmp_path):
    ca, cb = _corpus(40, seed=41), _corpus(24, seed=42)
    a, _ = build_compact_streaming(ca.doc_terms, tmp_path / "a", PARAMS,
                                   block_docs=32, row_align=64)
    b, _ = build_compact_streaming(cb.doc_terms, tmp_path / "b", PARAMS,
                                   block_docs=32, row_align=64)
    merge_stores(tmp_path / "a", tmp_path / "b", tmp_path / "m")
    m = load_index(tmp_path / "m")
    ref = merge_compact(a, b)
    np.testing.assert_array_equal(m.storage.full_host(),
                                  ref.storage.full_host())
    np.testing.assert_array_equal(m.layout.doc_slot, ref.layout.doc_slot)
    np.testing.assert_array_equal(m.layout.row_offset, ref.layout.row_offset)
    # linked, not copied (same inode) — skip silently if the fs can't link
    src = tmp_path / "a" / "shard-000000.npy"
    dst = tmp_path / "m" / "shard-000000.npy"
    if src.stat().st_ino == dst.stat().st_ino:
        assert src.stat().st_nlink >= 2
    # query equivalence through the merged store
    qs, _ = make_queries(cb, n_pos=2, n_neg=0, length=80, seed=44)
    for q in qs:
        rb = QueryEngine(b).search(q, 0.8)
        rm = QueryEngine(m).search(q, 0.8)
        assert set(rm.doc_ids.tolist()) >= {
            int(d) + a.n_docs for d in rb.doc_ids}


def test_merge_stores_rejects_mismatch(tmp_path):
    c = _corpus(24, seed=51)
    build_compact_streaming(c.doc_terms, tmp_path / "a", PARAMS,
                            block_docs=32, row_align=64)
    build_compact_streaming(c.doc_terms, tmp_path / "b",
                            IndexParams(n_hashes=1, fpr=0.1, kmer=15),
                            block_docs=32, row_align=64)
    with pytest.raises(ValueError):
        merge_stores(tmp_path / "a", tmp_path / "b", tmp_path / "m")


# --------------------------------------------------------------------------
# Device tile cache
# --------------------------------------------------------------------------

def test_tile_cache_lru_eviction(built):
    _, _, mapped, stats, _ = built
    # room for exactly one shard: every distinct access is a page fault
    cache = DeviceTileCache(mapped.storage,
                            capacity_bytes=stats.max_shard_bytes)
    n = mapped.storage.n_shards
    for s in range(n):
        cache.get(s)
    assert cache.faults == n and len(cache) == 1
    assert cache.resident_bytes <= stats.max_shard_bytes
    cache.get(n - 1)                        # still resident
    assert cache.hits == 1
    cache.get(0)                            # evicted earlier -> fault again
    assert cache.faults == n + 1


def test_tile_cache_unbounded_keeps_all(built):
    _, _, mapped, _, _ = built
    cache = DeviceTileCache(mapped.storage)
    n = mapped.storage.n_shards
    for _ in range(3):
        for s in range(n):
            cache.get(s)
    assert cache.faults == n and cache.hits == 2 * n
    assert cache.resident_shards == tuple(range(n))


# --------------------------------------------------------------------------
# Paged serving
# --------------------------------------------------------------------------

def test_server_paged_results_and_metrics(built):
    from repro.serve import QueryServer, ServerConfig
    c, dense, mapped, stats, _ = built
    eng = QueryEngine(dense)
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=100, seed=61)
    # HBM budget of ONE tile: every batch must re-page each shard in turn
    server = QueryServer(mapped, ServerConfig(
        max_batch=4, max_wait_s=0.0, result_cache=0, row_cache=0,
        tile_cache_bytes=stats.max_shard_bytes))
    ids = [server.submit(q, threshold=0.7) for q in qs]
    server.drain()
    resp = server.pop_responses()
    for rid, q in zip(ids, qs):
        want = eng.search(q, threshold=0.7)
        np.testing.assert_array_equal(resp[rid].result.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(resp[rid].result.scores, want.scores)
    snap = server.metrics.snapshot()
    n_shards = mapped.storage.n_shards
    assert snap.page_faults >= n_shards     # cold start pages every shard
    assert snap.resident_tiles == 1         # the HBM budget held
    assert "tiles[" in snap.report()
    assert server.tiles.resident_bytes <= stats.max_shard_bytes


def test_plan_shards_blocks_partition(built):
    _, _, mapped, _, _ = built
    plans = plan_shards(mapped.layout, mapped.storage.shard_row_starts)
    assert plans[0].block_start == 0
    assert plans[-1].block_end == mapped.n_blocks
    for p, q in zip(plans, plans[1:]):
        assert p.block_end == q.block_start
    for p in plans:
        assert int(p.row_offset[0]) == 0    # rebased to the shard tile
