"""Multi-DEVICE check for the sharded serving data plane — executed in a
subprocess by test_multihost_devices.py with XLA_FLAGS forcing 4 host
devices (the rest of the suite must see exactly 1 device, the same
isolation mechanism as distributed_check.py / tests/conftest.py).

Each fake host's ShardWorker pins its DeviceTileCache and addressing to a
DISTINCT jax device, so shard tiles genuinely live on separate devices and
the frontend's scatter/gather crosses device boundaries. Asserts:

  * every worker's tiles reside on its own device
  * frontend threshold + top-k results == single-host QueryEngine
  * results stay bit-identical with one host down (replica failover)
"""
import os
import tempfile
from pathlib import Path

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

assert len(jax.devices()) == 4, jax.devices()

from repro.core import IndexParams, QueryEngine, build_compact
from repro.data import make_corpus, make_queries
from repro.index import ShardPlacement, build_compact_streaming
from repro.serve import Frontend, FrontendConfig, ShardWorker

params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
corpus = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=21)
dense = build_compact(corpus.doc_terms, params, block_docs=32, row_align=64)
store = Path(tempfile.mkdtemp()) / "v2"
mapped, _ = build_compact_streaming(corpus.doc_terms, store, params,
                                    block_docs=32, row_align=64)
assert mapped.storage.n_shards >= 3

nodes = ["h0", "h1", "h2"]
devices = jax.devices()[1:4]                   # distinct device per host
place = ShardPlacement.for_store(store, nodes, replication=2)
held = place.replica_assignment()
workers = {n: ShardWorker(n, store, held[n], device=d)
           for n, d in zip(nodes, devices) if held[n]}
fe = Frontend(workers, place, FrontendConfig(max_batch=8, max_wait_s=0.0))
eng = QueryEngine(dense)

queries, _ = make_queries(corpus, n_pos=6, n_neg=3, length=100, seed=5)
tids = [fe.submit(q, threshold=0.7) for q in queries]
kids = [fe.submit(q, top_k=5) for q in queries]
fe.drain()
resp = fe.pop_responses()
for rid, q in zip(tids, queries):
    want = eng.search(q, threshold=0.7)
    np.testing.assert_array_equal(resp[rid].result.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(resp[rid].result.scores, want.scores)
for rid, q in zip(kids, queries):
    want = eng.top_k(q, k=5)
    np.testing.assert_array_equal(resp[rid].result.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(resp[rid].result.scores, want.scores)
print("OK multi-device frontend == engine")

for name, w in workers.items():
    for tile in w.tiles._tiles.values():
        tile_devs = {d for d in tile.devices()}
        assert tile_devs == {w.device}, (name, tile_devs, w.device)
print("OK tiles pinned per host device")

fe.fail_worker(place.owner(0))
assert place.is_covered()
tids = [fe.submit(q, threshold=0.7) for q in queries]
fe.drain()
resp = fe.pop_responses()
for rid, q in zip(tids, queries):
    want = eng.search(q, threshold=0.7)
    np.testing.assert_array_equal(resp[rid].result.doc_ids, want.doc_ids)
assert fe.metrics.snapshot().failovers > 0
print("OK failover across devices bit-identical")

print("ALL-MULTIHOST-OK")
