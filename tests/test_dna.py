import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dna


def test_encode_decode_roundtrip():
    s = "ACGTACGTTTGGCCAA"
    codes = dna.encode_dna(s)
    assert dna.decode_dna(codes) == s


def test_encode_drops_non_acgt():
    assert dna.decode_dna(dna.encode_dna("ACGNNNTA")) == "ACGTA"


def test_pack_kmers_values():
    # A=0 C=1 G=2 T=3; "CA" with k=2 -> lo = 1 | (0 << 2) = 1
    codes = dna.encode_dna("CAT")
    packed = dna.pack_kmers(codes, 2)
    assert packed.shape == (2, 2)
    assert packed[0, 0] == 1           # "CA"
    assert packed[1, 0] == 0 | (3 << 2)  # "AT"
    assert (packed[:, 1] == 0).all()


def test_pack_kmers_hi_word():
    codes = np.zeros(20, dtype=np.uint8)
    codes[16] = 3  # base 16 lands in hi word, bit 0..1
    packed = dna.pack_kmers(codes, 20)
    assert packed.shape == (1, 2)
    assert packed[0, 1] == 3


def test_pack_kmers_short_input():
    assert dna.pack_kmers(np.zeros(3, np.uint8), 5).shape == (0, 2)


def test_kmer_k_bounds():
    with pytest.raises(ValueError):
        dna.pack_kmers(np.zeros(40, np.uint8), 32)


def test_canonical_is_revcomp_invariant():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 4, 64, dtype=np.uint8)
    rc = (3 - codes)[::-1].copy()
    a = dna.pack_kmers(codes, 15, canonical=True)
    b = dna.pack_kmers(rc, 15, canonical=True)
    a64 = set((a[:, 0].astype(np.uint64) | (a[:, 1].astype(np.uint64) << np.uint64(32))).tolist())
    b64 = set((b[:, 0].astype(np.uint64) | (b[:, 1].astype(np.uint64) << np.uint64(32))).tolist())
    assert a64 == b64


def test_unique_terms():
    t = np.array([[1, 0], [2, 0], [1, 0], [1, 1]], dtype=np.uint32)
    u = dna.unique_terms(t)
    assert u.shape == (3, 2)


def test_document_terms_union():
    r1 = dna.encode_dna("ACGTACGT")
    r2 = dna.encode_dna("ACGTACGT")
    t = dna.document_terms([r1, r2], 4)
    assert t.shape[0] == len(set(map(tuple, dna.pack_kmers(r1, 4).tolist())))


def test_qgrams_bytes():
    packed = dna.pack_qgrams_bytes(b"abcdef", 3)
    assert packed.shape == (4, 2)
    assert packed[0, 0] == ord("a") | (ord("b") << 8) | (ord("c") << 16)


@settings(max_examples=50, deadline=None)
@given(st.integers(5, 200), st.integers(1, 31), st.integers(0, 2 ** 31))
def test_property_kmer_count(n, k, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 4, n, dtype=np.uint8)
    packed = dna.pack_kmers(codes, k)
    assert packed.shape[0] == max(0, n - k + 1)
    # every k-mer is reconstructible: decode bits back to codes
    if packed.shape[0]:
        i = int(rng.integers(0, packed.shape[0]))
        lo, hi = int(packed[i, 0]), int(packed[i, 1])
        val = lo | (hi << 32)
        rec = [(val >> (2 * j)) & 3 for j in range(k)]
        assert rec == list(codes[i:i + k])
