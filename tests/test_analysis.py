"""Roofline analysis unit tests: HLO collective parser (trip-count-aware,
op-semantic byte counts) + analytic FLOP model sanity."""
import pytest

from repro import configs
from repro.launch import analysis, analytic
from repro.launch.specs import SHAPES

HLO = """\
HloModule test

%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ag = f32[2048]{0} all-gather(%x), replica_groups={}
  %ar = bf16[1024]{0} all-reduce(%y), to_apply=%add
  ROOT %t = tuple(%i, %z)
}

%cond.1 (p: (s32[], f32[128])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %w = (s32[], f32[128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %big = f32[4096]{0} reduce-scatter(%operand9), replica_groups={}
  %operand9 = f32[65536]{0} add(%a, %a)
  %cp = f32[256]{0} collective-permute(%a), source_target_pairs={{0,1}}
  ROOT %out = f32[128] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_trip_counts():
    out = analysis.collective_bytes(HLO)
    # all-gather: 2048 f32 = 8192 B x 10 trips
    assert out["all-gather"] == 8192 * 10
    # all-reduce: 1024 bf16 = 2048 B x 2 (ring) x 10 trips
    assert out["all-reduce"] == 2048 * 2 * 10
    # reduce-scatter: OPERAND size (65536 f32), not result
    assert out["reduce-scatter"] == 65536 * 4
    assert out["collective-permute"] == 256 * 4


def test_shape_bytes():
    assert analysis._shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert analysis._shape_bytes("bf16[16]") == 32
    assert analysis._shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert analysis._shape_bytes("pred[]") == 1


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(flops_per_chip=197e12, bytes_per_chip=819e9,
                          coll_bytes_per_chip=0.0, coll_breakdown={},
                          model_flops=100e12, chips=1)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    r2 = analysis.Roofline(1, 1, 50e9, {}, chips=1)
    assert r2.bottleneck == "collective"
    assert r2.t_collective == pytest.approx(1.0)


@pytest.mark.parametrize("arch", configs.list_archs())
def test_analytic_flops_sane(arch):
    """computed >= useful MODEL_FLOPS (waste is never negative) and both
    scale with tokens."""
    cfg = configs.get(arch)
    s = SHAPES["train_4k"]
    fb = analytic.flops_model(cfg, "train", s.seq_len, s.global_batch)
    assert fb.computed_flops > 0 and fb.useful_flops > 0
    assert fb.computed_flops >= 0.9 * fb.useful_flops, (
        f"{arch}: computed {fb.computed_flops:.2e} < useful "
        f"{fb.useful_flops:.2e}")
    fb2 = analytic.flops_model(cfg, "train", s.seq_len, s.global_batch * 2)
    assert fb2.computed_flops == pytest.approx(2 * fb.computed_flops, rel=.01)


def test_decode_flops_much_smaller_than_train():
    cfg = configs.get("phi4-mini-3.8b")
    tr = analytic.flops_model(cfg, "train", 4096, 256)
    de = analytic.flops_model(cfg, "decode", 32768, 128)
    assert de.computed_flops < 1e-3 * tr.computed_flops


def test_moe_flops_use_active_params():
    cfg = configs.get("qwen3-moe-30b-a3b")
    fb = analytic.flops_model(cfg, "train", 4096, 256)
    dense_equiv = 6.0 * cfg.param_count() * 4096 * 256
    assert fb.useful_flops < 0.2 * dense_equiv
