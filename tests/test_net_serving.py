"""Network serving tests: the active ServingLoop, the TCP wire protocol,
and the serving-policy satellites that ride on them.

The load-bearing invariant mirrors the rest of the serving stack: any
result that crosses the socket must be BIT-IDENTICAL to a synchronous
QueryEngine run — threshold and top-k alike — no matter how concurrent
clients' queries were coalesced into micro-batches. On top of that, the
failure paths must be loud, not silent: queue-cap overflow answers the
CLIENT with a REJECTED reply (never a hang, never a dead server), expired
deadlines come back DROPPED, and graceful shutdown drains every in-flight
request before the socket closes.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import IndexParams, QueryEngine, build_compact, load_index
from repro.core.query import compile_pattern
from repro.data import make_corpus, make_queries
from repro.index import ShardPlacement, ShardSim, build_compact_streaming
from repro.kernels.autotune import (KernelTuner, TunedEntry, TuningCache,
                                    tuning_key)
from repro.launch.serve import run_closed
from repro.serve import (Frontend, FrontendConfig, LoopClosed, NetClient,
                         NetServer, QueryServer, ServerConfig, ServingLoop,
                         ShardWorker, Status)
from repro.serve.net import (MSG_HELLO, MSG_QUERY, MSG_RESULT, PROTO_VERSION,
                             _QUERY, decode_hello, decode_query,
                             decode_result, encode_query, encode_result,
                             read_frame, write_frame)
from repro.serve.request import QueryResponse

PARAMS = IndexParams(n_hashes=1, fpr=0.3, kmer=15)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    c = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=11)
    index = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = tmp_path_factory.mktemp("net-store") / "v2"
    mapped, _ = build_compact_streaming(c.doc_terms, store, PARAMS,
                                        block_docs=32, row_align=64)
    assert mapped.storage.n_shards >= 3    # placements spread over hosts
    return c, index, store


@pytest.fixture(scope="module")
def oracle(built):
    _, index, _ = built
    return QueryEngine(index)


def _serve(index, **cfg):
    """(server, loop, netserver) over an ephemeral localhost port."""
    server = QueryServer(index, ServerConfig(**cfg))
    net = NetServer(ServingLoop(server)).start()
    return server, net


def _assert_identical(got, want):
    assert np.array_equal(got.doc_ids, want.doc_ids)
    assert np.array_equal(got.scores, want.scores)
    assert got.n_terms == want.n_terms
    assert got.threshold == want.threshold


# --------------------------------------------------------------------------
# Wire protocol round trips (no sockets: pure encode/decode)
# --------------------------------------------------------------------------

def test_wire_query_round_trip():
    terms = np.array([[1, 2], [3, 4], [5, 6]], dtype=np.uint32)
    payload = encode_query(42, terms, 0.75, 7, 1.5)
    rid, t2, th, k, dl, tid = decode_query(payload)
    assert rid == 42 and th == 0.75 and k == 7 and dl == 1.5 and tid == 0
    assert np.array_equal(t2, terms) and t2.dtype == np.uint32
    # defaults: NaN threshold -> None, deadline 0 -> None
    rid, t2, th, k, dl, tid = decode_query(
        encode_query(0, terms, None, 0, None))
    assert th is None and dl is None and k == 0 and tid == 0
    # v2 trailing trace id round-trips
    rid, t2, th, k, dl, tid = decode_query(
        encode_query(7, terms, 0.5, 0, None, trace_id=0xBEEF00012345))
    assert rid == 7 and tid == 0xBEEF00012345
    assert np.array_equal(t2, terms)


def test_wire_result_round_trip():
    from repro.core.query import SearchResult
    res = SearchResult(np.array([5, 2, 9], np.int32),
                       np.array([7, 6, 6], np.int32), 8, 6)
    resp = QueryResponse(0, Status.OK, res, method="lookup", batch_size=4,
                         wait_s=0.25, service_s=0.125)
    rid, out = decode_result(encode_result(3, resp))
    assert rid == 3 and out.status == Status.OK
    assert out.method == "lookup" and out.batch_size == 4
    assert out.wait_s == 0.25 and out.service_s == 0.125
    assert out.trace_id == 0 and out.stages is None
    _assert_identical(out.result, res)
    # non-OK statuses carry no result
    for status in (Status.REJECTED, Status.DROPPED, Status.FAILED):
        rid, out = decode_result(
            encode_result(9, QueryResponse(0, status)))
        assert out.status == status and out.result is None


def test_wire_result_trace_block_round_trip():
    """The v2 trailing trace block (trace id + per-stage breakdown)
    round-trips on OK and non-OK results alike, insertion order kept."""
    from repro.core.query import SearchResult
    res = SearchResult(np.array([1], np.int32), np.array([9], np.int32),
                       4, 3)
    stages = {"queue_wait": 0.001, "kernel_score": 0.25, "select": 0.002}
    resp = QueryResponse(0, Status.OK, res, method="fused",
                         trace_id=77, stages=stages)
    rid, out = decode_result(encode_result(5, resp, trace_id=77))
    assert rid == 5 and out.trace_id == 77
    assert out.stages == stages
    assert list(out.stages) == list(stages)      # order preserved
    _assert_identical(out.result, res)
    # non-OK (e.g. DROPPED) still carries its breakdown
    dropped = QueryResponse(0, Status.DROPPED, trace_id=9,
                            stages={"queue_wait": 0.5})
    rid, out = decode_result(encode_result(6, dropped, trace_id=9))
    assert out.status == Status.DROPPED and out.trace_id == 9
    assert out.stages == {"queue_wait": 0.5}


# --------------------------------------------------------------------------
# End-to-end: concurrent clients, randomized workloads, oracle identity
# --------------------------------------------------------------------------

def test_net_property_concurrent_clients(built, oracle):
    """N concurrent fake clients push randomized workloads (mixed term
    lengths, thresholds, top-k, duplicate queries) through the socket;
    every response must be bit-identical to the QueryEngine oracle, and
    the kernel dispatch count must stay below the request count (the
    whole point of the shared micro-batch loop)."""
    c, index, _ = built
    server, net = _serve(index, max_batch=8, max_wait_s=0.02)
    n_clients, per_client = 4, 18
    failures: list[str] = []
    done: list[int] = []

    def client(ci: int) -> None:
        rng = np.random.default_rng(100 + ci)
        qs = []
        for length in (40, 80, 160, 320):
            got, _ = make_queries(c, n_pos=3, n_neg=2, length=length,
                                  seed=200 + 7 * ci + length)
            qs.extend(got)
        try:
            with NetClient(*net.address, timeout_s=120.0) as cl:
                assert cl.params == PARAMS and cl.n_docs == index.n_docs
                flight = []
                for i in range(per_client):
                    q = qs[int(rng.integers(len(qs)))]   # duplicates happen
                    th = float(rng.choice([0.5, 0.8]))
                    k = int(rng.choice([0, 3]))
                    fut = cl.submit(q, threshold=None if k else th,
                                    top_k=k or None)
                    flight.append((q, th, k, fut))
                for q, th, k, fut in flight:
                    r = fut.result(120.0)
                    assert r.status == Status.OK
                    want = (oracle.top_k(q, k=k) if k
                            else oracle.search(q, threshold=th))
                    _assert_identical(r.result, want)
                    done.append(1)
        except Exception as e:             # pragma: no cover - diagnostics
            failures.append(f"client {ci}: {e!r}")

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    net.close()
    assert not failures, failures
    assert len(done) == n_clients * per_client
    snap = server.metrics.snapshot()
    assert snap.served == n_clients * per_client
    # coalescing really happened: fewer kernel dispatches than requests
    # (shared micro-batches and/or result-cache hits on duplicates)
    assert snap.batches < snap.served
    assert snap.total_connections == n_clients
    # connection gauge returns to zero once the reader threads wind down
    deadline = time.monotonic() + 5.0
    while (server.metrics.connections and time.monotonic() < deadline):
        time.sleep(0.01)
    assert server.metrics.connections == 0


def test_net_single_client_threshold_and_topk(built, oracle):
    """Deterministic single-session check of both selection modes plus
    the empty-query fast path."""
    c, index, _ = built
    _, net = _serve(index, max_batch=4, max_wait_s=0.001)
    q, _ = make_queries(c, n_pos=2, n_neg=1, length=120, seed=5)
    try:
        with NetClient(*net.address) as cl:
            for pattern in q:
                _assert_identical(
                    cl.search(pattern, threshold=0.6).result,
                    oracle.search(pattern, threshold=0.6))
                _assert_identical(
                    cl.top_k(pattern, k=5).result,
                    oracle.top_k(pattern, k=5))
            # empty pattern (shorter than k) answers OK with zero hits
            r = cl.search(np.zeros(3, np.uint8))
            assert r.status == Status.OK and r.result.doc_ids.size == 0
    finally:
        net.close()


def test_net_multihost_frontend_over_socket(built):
    """The wire protocol serves the sharded Frontend identically to the
    single-host path: socket results over 3 fake hosts == QueryEngine."""
    c, index, store = built
    eng = QueryEngine(load_index(store))
    nodes = ["h0", "h1", "h2"]
    place = ShardPlacement.for_store(store, nodes, replication=2)
    held = place.replica_assignment()
    workers = {n: ShardWorker(n, store, held[n]) for n in nodes if held[n]}
    fe = Frontend(workers, place,
                  FrontendConfig(max_batch=8, max_wait_s=0.005))
    net = NetServer(ServingLoop(fe)).start()
    qs, _ = make_queries(c, n_pos=3, n_neg=2, length=120, seed=9)
    try:
        with NetClient(*net.address, timeout_s=120.0) as cl:
            futs = [cl.submit(q, threshold=0.8) for q in qs]
            futs += [cl.submit(q, top_k=4) for q in qs]
            for q, f in zip(qs, futs[: len(qs)]):
                _assert_identical(f.result(120.0).result,
                                  eng.search(q, threshold=0.8))
            for q, f in zip(qs, futs[len(qs):]):
                _assert_identical(f.result(120.0).result,
                                  eng.top_k(q, k=4))
    finally:
        net.close()


# --------------------------------------------------------------------------
# Protocol-version interop (v1 <-> v2)
# --------------------------------------------------------------------------

def test_net_v1_frames_against_v2_server(built, oracle):
    """Old client -> new server: raw protocol-1 QUERY frames (terms only,
    no trailing trace id) against a default (v2) server must be answered
    with plain v1 RESULT frames — no trace block, bit-identical result."""
    import socket as socketlib
    c, index, _ = built
    _, net = _serve(index, max_batch=4, max_wait_s=0.001)
    (q,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=41)
    terms = compile_pattern(q, PARAMS)
    try:
        sock = socketlib.create_connection(net.address, timeout=60.0)
        try:
            hello = read_frame(sock)
            assert hello[0] == MSG_HELLO
            params, n_docs, version = decode_hello(hello)
            assert version == PROTO_VERSION >= 2     # server advertises v2
            # a v1 client's encoder: header + packed terms, nothing else
            frame = _QUERY.pack(MSG_QUERY, 11, 0.8, 0, 0.0,
                                terms.shape[0]) + np.ascontiguousarray(
                                    terms, dtype="<u4").tobytes()
            write_frame(sock, frame)
            payload = read_frame(sock)
            assert payload[0] == MSG_RESULT
            rid, res = decode_result(payload)
            assert rid == 11 and res.status == Status.OK
            assert res.trace_id == 0 and res.stages is None  # no v2 tail
            _assert_identical(res.result, oracle.search(q, threshold=0.8))
        finally:
            sock.close()
    finally:
        net.close()


def test_net_v2_client_against_v1_pinned_server(built, oracle):
    """New client -> old server (NetServer pinned to proto_version=1):
    the client sees version 1 in HELLO, never sends trace ids, and gets
    plain v1 results; STATS is refused client-side."""
    c, index, _ = built
    server = QueryServer(index, ServerConfig(max_batch=4, max_wait_s=0.001))
    net = NetServer(ServingLoop(server), proto_version=1).start()
    (q,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=43)
    try:
        with NetClient(*net.address, timeout_s=60.0) as cl:
            assert cl.proto_version == 1 and not cl.trace
            r = cl.search(q, threshold=0.8)
            assert r.status == Status.OK
            assert r.trace_id == 0 and r.stages is None
            _assert_identical(r.result, oracle.search(q, threshold=0.8))
            with pytest.raises(ConnectionError):
                cl.stats()
    finally:
        net.close()


def test_net_trace_and_stats_round_trip(built, oracle):
    """v2 <-> v2: a traced query returns its client-minted trace id plus
    a per-stage breakdown, and STATS serves both formats over the same
    pipelined session."""
    from repro.obs.export import parse_prometheus
    c, index, _ = built
    server, net = _serve(index, max_batch=4, max_wait_s=0.001)
    (q,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=47)
    try:
        with NetClient(*net.address, timeout_s=60.0) as cl:
            assert cl.proto_version >= 2 and cl.trace
            r = cl.search(q, threshold=0.8)
            assert r.status == Status.OK and r.trace_id != 0
            assert r.stages and "kernel_score" in r.stages
            assert all(v >= 0 for v in r.stages.values())
            _assert_identical(r.result, oracle.search(q, threshold=0.8))
            # the server-side trace carries the SAME id end to end
            trace = server.tracer.find(r.trace_id)
            assert trace is not None and trace.done
            snap = cl.stats()
            assert snap["served"] >= 1 and "p99_ms" in snap
            text = cl.stats(prometheus=True)
            parsed = parse_prometheus(text)
            assert parsed.get("serve_requests_total{status=\"ok\"}",
                              0) >= 1
    finally:
        net.close()


# --------------------------------------------------------------------------
# Backpressure / deadline / drain regressions
# --------------------------------------------------------------------------

def test_net_backpressure_rejects_do_not_hang(built, oracle):
    """Queue-cap overflow must answer the CLIENT with a REJECTED reply —
    no silent hang, no server crash — and the accepted requests must
    still complete (drained at close)."""
    c, index, _ = built
    cap = 4
    # wait timer far beyond the test: accepted requests SIT in the
    # batcher, so the cap overflow is deterministic, and close(drain)
    # must flush them
    server, net = _serve(index, max_batch=64, max_wait_s=60.0,
                         max_queued=cap, result_cache=0, row_cache=0)
    qs, _ = make_queries(c, n_pos=6, n_neg=2, length=120, seed=13)
    cl = NetClient(*net.address, timeout_s=60.0)
    futs = [cl.submit(q, threshold=0.8) for q in qs[: cap + 3]]
    # overflow replies arrive while the accepted 4 are still queued
    rejected = [f.result(30.0) for f in futs[cap:]]
    assert [r.status for r in rejected] == [Status.REJECTED] * 3
    assert all(r.result is None for r in rejected)
    for f in futs[:cap]:
        assert not f.done()
    # graceful close drains the accepted requests: OK + bit-identical
    net.close(drain=True)
    for q, f in zip(qs, futs[:cap]):
        r = f.result(60.0)
        assert r.status == Status.OK
        _assert_identical(r.result, oracle.search(q, threshold=0.8))
    snap = server.metrics.snapshot()
    assert snap.rejected == 3 and snap.served == cap
    cl.close()


def test_net_deadline_drops_at_flush(built, oracle):
    """An expired deadline answers DROPPED — the request is never scored
    and the client is told, not left waiting. Holds even for a deadline
    QUEUED BEHIND a no-deadline request: the dispatcher wakes on any
    queued member's deadline, not just the bucket head's timer."""
    c, index, _ = built
    server, net = _serve(index, max_batch=64, max_wait_s=60.0,
                         result_cache=0, row_cache=0)
    qs, _ = make_queries(c, n_pos=2, n_neg=0, length=120, seed=17)
    cl = NetClient(*net.address, timeout_s=60.0)
    # same bucket: the no-deadline head sits on the 60s timer; the
    # deadlined request behind it must still be answered on time
    head_fut = cl.submit(qs[0])
    r = cl.submit(qs[1], deadline_s=0.05).result(30.0)
    assert r.status == Status.DROPPED and r.result is None
    assert r.wait_s >= 0.05                   # it queued until the deadline
    assert not head_fut.done()                # the head keeps waiting
    net.close(drain=True)                     # ... and still gets scored
    rh = head_fut.result(60.0)
    assert rh.status == Status.OK
    _assert_identical(rh.result, oracle.search(qs[0]))
    snap = server.metrics.snapshot()
    assert snap.dropped == 1 and snap.served == 1
    cl.close()


def test_net_graceful_drain_scores_in_flight(built, oracle):
    """close(drain=True) scores every queued request and writes every
    response before the socket goes down."""
    c, index, _ = built
    server, net = _serve(index, max_batch=64, max_wait_s=60.0,
                         result_cache=0, row_cache=0)
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=80, seed=19)
    cl = NetClient(*net.address, timeout_s=60.0)
    futs = [cl.submit(q, threshold=0.7) for q in qs]
    time.sleep(0.05)                          # all queued, none scored
    assert server.metrics.snapshot().served == 0
    net.close(drain=True)
    for q, f in zip(qs, futs):
        r = f.result(60.0)
        assert r.status == Status.OK
        _assert_identical(r.result, oracle.search(q, threshold=0.7))
    assert server.metrics.snapshot().served == len(qs)
    cl.close()


def test_loop_rejects_after_stop(built):
    _, index, _ = built
    loop = ServingLoop(QueryServer(index, ServerConfig())).start()
    loop.stop()
    with pytest.raises(LoopClosed):
        loop.submit(terms=np.ones((4, 2), np.uint32),
                    on_done=lambda r: None)


def test_loop_stop_without_drain_rejects_queued(built):
    """drain=False shutdown still fires every callback — queued requests
    come back REJECTED instead of being scored (or lost)."""
    _, index, _ = built
    server = QueryServer(index, ServerConfig(max_batch=64, max_wait_s=60.0,
                                             result_cache=0, row_cache=0))
    loop = ServingLoop(server).start()
    got: dict[int, QueryResponse] = {}
    terms = compile_pattern(np.full(60, 1, np.uint8), PARAMS)
    rids = [loop.submit(terms=terms, on_done=lambda r, i=i: got.__setitem__(
        i, r)) for i in range(3)]
    assert all(r >= 0 for r in rids)
    loop.stop(drain=False)
    assert sorted(got) == [0, 1, 2]
    assert all(r.status == Status.REJECTED for r in got.values())


def test_loop_survives_scoring_failure(built, oracle):
    """A kernel/device exception mid-batch answers that batch FAILED and
    the loop keeps serving — the worker must not die with the in-flight
    accounting leaked (which would wedge every later request)."""
    c, index, _ = built
    server = QueryServer(index, ServerConfig(max_batch=4, max_wait_s=0.0,
                                             result_cache=0, row_cache=0))
    real, boom = server.score_batch, {"armed": True}

    def flaky(batch):
        if boom.pop("armed", None):
            raise RuntimeError("injected kernel failure")
        return real(batch)

    server.score_batch = flaky
    loop = ServingLoop(server).start()
    (q1,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=31)
    (q2,), _ = make_queries(c, n_pos=1, n_neg=0, length=160, seed=33)
    got: dict[str, QueryResponse] = {}
    evs = {k: threading.Event() for k in ("a", "b")}

    def cb(key):
        return lambda r: (got.__setitem__(key, r), evs[key].set())

    loop.submit(terms=compile_pattern(q1, PARAMS), on_done=cb("a"))
    assert evs["a"].wait(30) and got["a"].status == Status.FAILED
    assert server.metrics.failed == 1
    # the loop is still alive and scoring correctly
    loop.submit(terms=compile_pattern(q2, PARAMS), threshold=0.8,
                on_done=cb("b"))
    assert evs["b"].wait(30) and got["b"].status == Status.OK
    _assert_identical(got["b"].result, oracle.search(q2, threshold=0.8))
    loop.stop()


def test_overload_still_serves_fast_paths(built):
    """The outstanding-work cap only rejects requests that would occupy
    the queue: a result-cache hit costs nothing and stays servable even
    with the queue full."""
    c, index, _ = built
    server = QueryServer(index, ServerConfig(max_batch=64, max_wait_s=60.0,
                                             max_queued=2, row_cache=0))
    (hot,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=37)
    rid = server.submit(hot, threshold=0.8)   # prime the result cache
    server.drain()
    want = server.pop_responses()[rid].result

    loop = ServingLoop(server).start()
    try:
        got: list[QueryResponse] = []
        fills, _ = make_queries(c, n_pos=2, n_neg=1, length=160, seed=39)
        for q in fills[:2]:                   # fill the cap (timer 60s)
            loop.submit(terms=compile_pattern(q, PARAMS),
                        on_done=lambda r: None)
        assert loop.pending() == 2
        # over cap: an uncached query bounces ...
        loop.submit(terms=compile_pattern(fills[2], PARAMS),
                    on_done=got.append)
        assert got[-1].status == Status.REJECTED
        # ... but the cached one is served (fast path, no queue space)
        loop.submit(terms=compile_pattern(hot, PARAMS), threshold=0.8,
                    on_done=got.append)
        assert got[-1].status == Status.OK and got[-1].cached
        _assert_identical(got[-1].result, want)
    finally:
        loop.stop()


# --------------------------------------------------------------------------
# Adaptive hedging (hedge_after from observed per-worker p95)
# --------------------------------------------------------------------------

def test_hedge_auto_adapts_to_straggler(built):
    """Deterministic SimClock scenario: with hedge_auto the frontend
    derives hedge_after from the healthy workers' observed p95 and starts
    firing backups against the straggler — without any configured
    deadline ever matching the latency model."""
    c, _, store = built
    base, straggle = 1e-3, 20.0
    # these node names HRW-spread the fixture store's 3 shards across 3
    # distinct owners (asserted below) — the median-of-p95 rule needs the
    # straggler to be a minority voice among the sampled workers
    nodes = ["a", "b", "c"]

    def frontend(auto: bool) -> Frontend:
        place = ShardPlacement.for_store(store, nodes, replication=2)
        held = place.replica_assignment()
        workers = {n: ShardWorker(n, store, held[n])
                   for n in nodes if held[n]}
        models = {n: ShardSim(n, base_latency=base) for n in nodes}
        fe = Frontend(workers, place, FrontendConfig(
            max_batch=8, max_wait_s=0.0,
            hedge_after_s=1e9,               # initial: effectively off
            hedge_auto=auto, hedge_auto_min_samples=4),
            latency_models=models)
        victim = fe.placement.owner(0)
        # the median-of-p95 rule needs the victim to be a minority voice
        assert len({fe.placement.owner(g)
                    for g in range(fe.placement.n_shards)}) >= 3
        models[victim].straggle_until = 1e9
        models[victim].straggle_factor = straggle
        return fe

    queries, _ = make_queries(c, n_pos=40, n_neg=24, length=120, seed=23)

    fixed = frontend(auto=False)
    run_closed(fixed, queries, 0.8, 8)
    assert fixed.metrics.hedges_fired == 0
    assert fixed.hedge_after_s == 1e9        # never adapted

    auto = frontend(auto=True)
    run_closed(auto, queries, 0.8, 8)
    # adapted to the healthy fleet's observed p95: it starts at base and
    # drifts up a little as hedged wins (hedge_after + base, attributed
    # to the winning backup) enter the histograms, but stays an order of
    # magnitude below the straggler's 20x latency
    assert base <= auto.hedge_after_s <= 5 * base
    assert auto.hedge_after_s < base * straggle / 4
    # and the adapted deadline actually fires backups that win
    assert auto.metrics.hedges_fired > 0
    assert auto.metrics.hedges_won > 0
    # latency beats the fixed-deadline (never-hedging) run — p50, since
    # the pre-adaptation warmup batches still ate the straggler latency
    assert (auto.metrics.percentile_ms(50)
            < fixed.metrics.percentile_ms(50))


# --------------------------------------------------------------------------
# Autotune cache invalidation
# --------------------------------------------------------------------------

def test_tuning_cache_corrupt_file_falls_back(tmp_path, built):
    """A truncated/corrupt tuning.json must not crash serving: the cache
    opens empty (invalid flag set) and the planner uses heuristics."""
    c, index, _ = built
    path = tmp_path / "tuning.json"
    path.write_text('{"version": 1, "entries": {"k":')   # truncated json
    cache = TuningCache(path)
    assert cache.invalid and len(cache) == 0

    server = QueryServer(index, ServerConfig(
        max_batch=4, max_wait_s=0.0, tuning_cache=str(path)))
    plan = server.planner.plan(64, 4)
    assert plan.word_block is None and plan.grid_order == "wq"  # heuristics
    (q,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=29)
    rid = server.submit(q, threshold=0.8)
    server.drain()
    assert server.pop_responses()[rid].status == Status.OK


def test_tuning_cache_malformed_entries_fall_back(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps(
        {"version": 1, "entries": {"k": {"method": "lookup"}}}))  # fields
    cache = TuningCache(path)                                     # missing
    assert cache.invalid and len(cache) == 0
    # non-dict payload
    path.write_text(json.dumps([1, 2, 3]))
    assert TuningCache(path).invalid


def test_tuning_cache_stale_geometry_never_served(tmp_path, built):
    """An entry measured for a DIFFERENT arena geometry must not be
    served: the tuning key carries the arena shape, so a mismatched
    index simply misses and heuristics apply."""
    _, index, _ = built
    path = tmp_path / "tuning.json"
    cache = TuningCache(path)
    stale_key = tuning_key(999999, 7, 1, 3, "lookup", 64, 4)  # wrong shape
    cache.put(stale_key, TunedEntry("lookup", 8, 8, "qw", 1.0,
                                    dedup_threshold=0.0))
    cache.save()

    reopened = TuningCache(path)
    assert not reopened.invalid and len(reopened) == 1
    tuner = KernelTuner.for_index(index, reopened, enabled=False)
    assert tuner.key("lookup", 64, 4) != stale_key
    assert tuner.entry("lookup", 64, 4) is None      # miss, not the stale
    assert reopened.hits == 0 and tuner.tunes == 0

    server = QueryServer(index, ServerConfig(tuning_cache=str(path)))
    plan = server.planner.plan(64, 4)
    assert plan.word_block is None and plan.grid_order == "wq"

# --------------------------------------------------------------------------
# Session drain: replies to a slow reader are delivered or counted, never
# silently orphaned (PR 10 regression — finish() used to enqueue the
# shutdown sentinel with a timeout, so a full outbox at close dropped
# every queued reply with no accounting)
# --------------------------------------------------------------------------

def _session_pair(on_drop=None):
    import socket as sk

    from repro.serve.net import _Session
    a, b = sk.socketpair()
    return _Session(a, on_drop=on_drop), b


def test_session_drain_delivers_to_slow_reader():
    """A client that reads slowly (but reads) at close(drain) receives
    EVERY accepted reply — finish() waits out the outbox before the
    shutdown sentinel."""
    from repro.serve.net import read_frame

    session, peer = _session_pair()
    n, got, errs = 40, [], []

    def reader():
        try:
            while True:
                frame = read_frame(peer)
                if frame is None:             # clean EOF
                    return
                got.append(frame)
                time.sleep(0.002)             # slow, not stopped
        except (OSError, ConnectionError, EOFError):
            pass
        except Exception as e:                # torn frame at EOF etc.
            errs.append(e)

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    for i in range(n):
        session.send(bytes([i % 256]) * 4096)
    session.finish(timeout_s=30.0)
    t.join(10.0)
    assert len(got) == n
    assert session.dropped_replies == 0
    assert not errs


def test_session_drain_wedged_reader_counts_every_drop():
    """A peer that STOPS reading can wedge the writer in sendall; the
    bounded drain must still terminate, and every undelivered reply must
    be counted — received + dropped == accepted, nothing silent."""
    from repro.serve.net import read_frame

    drops = []
    session, peer = _session_pair(on_drop=lambda k: drops.append(k))
    n, payload = 120, b"x" * 65536            # >> any socket buffer
    for _ in range(n):
        session.send(payload)
    t0 = time.monotonic()
    session.finish(timeout_s=0.5)
    assert time.monotonic() - t0 < 10.0       # bounded, no hang
    assert not session.writer.is_alive()
    assert session.dropped_replies > 0        # the peer really was wedged
    received = 0
    try:
        while read_frame(peer) is not None:   # drain what did arrive
            received += 1
    except Exception:                         # torn trailing frame
        pass
    assert received + session.dropped_replies == n
    assert sum(drops) == session.dropped_replies
    peer.close()


def test_net_drop_accounting_reaches_metrics(built):
    """Session drops surface in the server's metrics snapshot/report —
    the 'never silent' half of the drain contract at the NetServer
    level."""
    _, index, _ = built
    server, net = _serve(index, max_batch=4)
    net._record_drop(3)
    snap = server.metrics.snapshot()
    assert snap.dropped_replies == 3
    assert "dropped_replies=3" in snap.report()
    net.close(drain=False)
