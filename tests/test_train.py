import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.train import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, make_init_state, make_train_step)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(1e-4)


def test_adamw_decay_skips_1d_params():
    cfg = AdamWConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0,
                      total_steps=10)
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(cfg, zeros, adamw_init(params), params)
    assert float(new["w"].mean()) < 1.0      # decayed
    assert float(new["scale"].mean()) == 1.0  # not decayed (zero grad)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros((8,))}
    huge = {"w": jnp.full((8,), 1e6)}
    _, _, m = adamw_update(cfg, huge, adamw_init(params), params)
    assert float(m["grad_norm"]) == pytest.approx(1e6 * np.sqrt(8), rel=1e-5)


def test_gradient_accumulation_matches_full_batch():
    """microbatches=N must equal the single full-batch step: the loss to
    ~fp32 epsilon, the Adam update to within 2*lr (Adam's m/sqrt(v) is
    sign-like at step 1, amplifying bf16 reassociation noise to at most
    the learning rate per parameter)."""
    cfg = configs.get("qwen3-4b", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = jax.jit(make_init_state(model, opt))(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 12)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 12)),
                                   jnp.int32)}
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)))
    assert d <= 2.1 * opt.lr
    # and the metrics structure is identical
    assert set(m1) == set(m4)


def test_masked_labels_excluded():
    cfg = configs.get("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    from repro.train import loss_fn
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    labels_full = toks
    labels_masked = labels_full.at[:, :4].set(-1)
    l1, _ = loss_fn(model, params, {"tokens": toks, "labels": labels_full})
    l2, _ = loss_fn(model, params, {"tokens": toks, "labels": labels_masked})
    assert float(l1) != float(l2)
    assert np.isfinite(float(l2))
