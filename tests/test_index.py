import numpy as np
import pytest

from repro.core import (IndexParams, bloom, build_classic, build_compact,
                        load_index, merge_classic, save_index, theory)


def _docs(n, seed=0, lo=50, hi=4000, k=15):
    from repro.data import make_corpus
    c = make_corpus(n, k=k, mean_length=(lo + hi) // 4, sigma=1.2, seed=seed,
                    min_length=lo, max_length=hi)
    return c.doc_terms


def test_classic_single_block():
    idx = build_classic(_docs(10), IndexParams(kmer=15))
    assert idx.n_blocks == 1
    assert idx.block_docs == 32           # padded to word
    assert idx.arena.shape[1] == 1
    assert idx.n_docs == 10


def test_compact_blocks_and_widths_monotone():
    idx = build_compact(_docs(96), IndexParams(kmer=15), block_docs=32,
                        row_align=64)
    assert idx.n_blocks == 3
    widths = np.asarray(idx.block_width)
    # docs sorted ascending by size -> block widths non-decreasing (Fig. 4)
    assert (np.diff(widths) >= 0).all()
    offs = np.asarray(idx.row_offset)
    assert offs[0] == 0
    np.testing.assert_array_equal(np.diff(offs), widths[:-1])
    assert idx.total_rows == widths.sum()


def test_compact_smaller_than_classic_on_skewed_corpus():
    """The paper's headline structural claim (Fig. 4): compaction shrinks the
    index when document sizes are skewed."""
    docs = _docs(128, seed=3)
    params = IndexParams(kmer=15)
    classic = build_classic(docs, params, row_align=64)
    compact = build_compact(docs, params, block_docs=32, row_align=64)
    assert compact.size_bytes() < 0.7 * classic.size_bytes()


def test_doc_slot_is_permutation():
    idx = build_compact(_docs(70), IndexParams(kmer=15), block_docs=32)
    slots = np.asarray(idx.doc_slot)
    assert len(set(slots.tolist())) == idx.n_docs
    assert slots.max() < idx.n_slots


def test_expected_fpr_below_target():
    idx = build_compact(_docs(64), IndexParams(kmer=15, fpr=0.3), block_docs=32)
    fprs = idx.expected_fpr()
    assert (fprs <= 0.3 + 1e-9).all()


def test_merge_classic():
    params = IndexParams(kmer=15)
    docs = _docs(40, seed=1)
    # force equal widths by building with the same max doc
    a = build_classic(docs[:20] + [docs[-1]], params)
    b = build_classic(docs[20:], params)
    if int(a.block_width[0]) == int(b.block_width[0]):
        m = merge_classic(a, b)
        assert m.n_docs == a.n_docs + b.n_docs
        assert m.arena.shape[1] == a.arena.shape[1] + b.arena.shape[1]


def test_merge_rejects_mismatch():
    params = IndexParams(kmer=15)
    a = build_classic(_docs(8, seed=1, hi=500), params)
    b = build_classic(_docs(8, seed=2, hi=50_000), params)
    if int(a.block_width[0]) != int(b.block_width[0]):
        with pytest.raises(ValueError):
            merge_classic(a, b)


def test_save_load_roundtrip(tmp_path):
    idx = build_compact(_docs(48), IndexParams(kmer=15), block_docs=32)
    save_index(idx, tmp_path / "idx")
    idx2 = load_index(tmp_path / "idx")
    np.testing.assert_array_equal(np.asarray(idx.arena), np.asarray(idx2.arena))
    np.testing.assert_array_equal(np.asarray(idx.doc_slot), np.asarray(idx2.doc_slot))
    assert idx2.params == idx.params
    assert idx2.n_docs == idx.n_docs


def test_load_rejects_unknown_format(tmp_path):
    d = tmp_path / "bad"
    d.mkdir()
    (d / "manifest.json").write_text('{"format": "nope"}')
    with pytest.raises(ValueError):
        load_index(d)


def test_aligned_width():
    assert bloom.aligned_width(1, 64) == 64
    assert bloom.aligned_width(65, 64) == 128
    assert bloom.aligned_width(128, 64) == 128


def test_empty_docs_and_empty_set():
    docs = [np.zeros((0, 2), np.uint32)] * 3 + _docs(5)
    idx = build_compact(docs, IndexParams(kmer=15), block_docs=32)
    assert idx.n_docs == 8
    with pytest.raises(ValueError):
        build_classic([], IndexParams())


def test_classic_width_covers_largest_doc():
    docs = _docs(32, seed=5)
    params = IndexParams(kmer=15, fpr=0.3, n_hashes=1)
    idx = build_classic(docs, params, row_align=64)
    v_max = max(d.shape[0] for d in docs)
    assert int(idx.block_width[0]) >= theory.bloom_size(v_max, 0.3, 1)


def test_merge_compact_preserves_query_results():
    """Paper section 4 future work: compact indexes merge WITHOUT rebuild
    (block concatenation); merged queries == querying both separately."""
    from repro.core import QueryEngine, merge_compact
    from repro.data import make_corpus, make_queries
    params = IndexParams(kmer=15)
    ca = make_corpus(40, k=15, mean_length=500, sigma=1.0, seed=31)
    cb = make_corpus(40, k=15, mean_length=500, sigma=1.0, seed=32)
    a = build_compact(ca.doc_terms, params, block_docs=32, row_align=64)
    b = build_compact(cb.doc_terms, params, block_docs=32, row_align=64)
    m = merge_compact(a, b)
    assert m.n_docs == 80 and m.n_blocks == a.n_blocks + b.n_blocks

    qs, origin = make_queries(ca, n_pos=6, n_neg=2, length=80, seed=33)
    ea, em = QueryEngine(a), QueryEngine(m)
    for q, o in zip(qs, origin):
        import repro.core.dna as dna_mod
        terms = dna_mod.unique_terms(dna_mod.pack_kmers(q, 15))
        sa = ea.score_terms(terms)
        sm = em.score_terms(terms)
        np.testing.assert_array_equal(sa, sm[:40])   # a's docs: same scores
        if o >= 0:
            assert sm[o] == terms.shape[0]


def test_merge_compact_union_of_results():
    """Merged-index queries == the UNION of per-index results, with b's
    doc ids shifted by a.n_docs (the doc_slot remapping contract)."""
    from repro.core import QueryEngine, merge_compact
    from repro.data import make_corpus, make_queries
    params = IndexParams(kmer=15)
    ca = make_corpus(40, k=15, mean_length=500, sigma=1.0, seed=41)
    cb = make_corpus(24, k=15, mean_length=500, sigma=1.0, seed=42)
    a = build_compact(ca.doc_terms, params, block_docs=32, row_align=64)
    b = build_compact(cb.doc_terms, params, block_docs=32, row_align=64)
    m = merge_compact(a, b)

    ea, eb, em = QueryEngine(a), QueryEngine(b), QueryEngine(m)
    qa, _ = make_queries(ca, n_pos=4, n_neg=2, length=80, seed=43)
    qb, _ = make_queries(cb, n_pos=4, n_neg=2, length=80, seed=44)
    for q in list(qa) + list(qb):
        ra, rb, rm = (e.search(q, threshold=0.8) for e in (ea, eb, em))
        want = set(ra.doc_ids.tolist()) | {
            int(d) + a.n_docs for d in rb.doc_ids}
        assert set(rm.doc_ids.tolist()) == want
        # scores survive the merge doc-by-doc
        score_of = dict(zip(rm.doc_ids.tolist(), rm.scores.tolist()))
        for d, s in zip(ra.doc_ids.tolist(), ra.scores.tolist()):
            assert score_of[d] == s
        for d, s in zip(rb.doc_ids.tolist(), rb.scores.tolist()):
            assert score_of[d + a.n_docs] == s


def test_merge_classic_union_of_results():
    """Same union contract for the classic (column-concatenation) merge."""
    from repro.core import QueryEngine
    from repro.data import make_corpus, make_queries
    params = IndexParams(kmer=15)
    ca = make_corpus(20, k=15, mean_length=400, sigma=0.5, seed=45)
    cb = make_corpus(12, k=15, mean_length=400, sigma=0.5, seed=46)
    # classic width is set by the largest doc: cap b's docs at a's max and
    # append a's largest so both filters come out identical
    biggest = max(ca.doc_terms, key=lambda t: t.shape[0])
    b_docs = [t for t in cb.doc_terms
              if t.shape[0] <= biggest.shape[0]] + [biggest]
    a = build_classic(ca.doc_terms, params, row_align=64)
    b = build_classic(b_docs, params, row_align=64)
    assert int(a.block_width[0]) == int(b.block_width[0])
    m = merge_classic(a, b)
    assert m.n_docs == a.n_docs + b.n_docs

    ea, eb, em = QueryEngine(a), QueryEngine(b), QueryEngine(m)
    qa, _ = make_queries(ca, n_pos=4, n_neg=2, length=80, seed=47)
    for q in qa:
        ra, rb, rm = (e.search(q, threshold=0.8) for e in (ea, eb, em))
        want = set(ra.doc_ids.tolist()) | {
            int(d) + a.n_docs for d in rb.doc_ids}
        assert set(rm.doc_ids.tolist()) == want


def test_merge_compact_rejects_mismatch():
    from repro.core import merge_compact
    from repro.data import make_corpus
    ca = make_corpus(10, k=15, mean_length=300, seed=1)
    a = build_compact(ca.doc_terms, IndexParams(kmer=15), block_docs=32)
    b = build_compact(ca.doc_terms, IndexParams(kmer=15), block_docs=64)
    with pytest.raises(ValueError):
        merge_compact(a, b)
    c = build_compact(ca.doc_terms, IndexParams(kmer=15, fpr=0.1),
                      block_docs=32)
    with pytest.raises(ValueError):
        merge_compact(a, c)
