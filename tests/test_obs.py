"""Observability-plane tests: the metrics registry + Prometheus
exposition, request tracing (spans, slow-query JSONL sink), the kernel
profiler feeding the autotuner live costs, and the end-to-end
acceptance path — a NetClient query whose trace id comes back with a
per-stage breakdown AND shows up, same id and span tree, in the
server-side slow-query log.

The concurrency tests exist because the metrics surface is read by
monitoring threads while socket threads and the scatter pool write it:
pre-registry ServingMetrics iterated bare deques during appends, which
a concurrent reader can turn into ``RuntimeError: deque mutated during
iteration`` — the hammer test pins the lock-guarded fix.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import IndexParams, build_compact
from repro.data import make_corpus, make_queries
from repro.index import ShardPlacement, build_compact_streaming
from repro.kernels.autotune import (LIVE_PREFIX, KernelTuner, TuningCache)
from repro.obs import (EventLog, KernelProfiler, MetricsRegistry, Trace,
                       Tracer, render_prometheus)
from repro.obs.events import read_jsonl
from repro.obs.export import parse_prometheus
from repro.obs.profile import gather_bytes
from repro.serve import (Frontend, FrontendConfig, NetClient, NetServer,
                         QueryServer, ServerConfig, ServingLoop,
                         ServingMetrics, ShardWorker, Status)

PARAMS = IndexParams(n_hashes=1, fpr=0.3, kmer=15)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    c = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=13)
    index = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = tmp_path_factory.mktemp("obs-store") / "v2"
    mapped, _ = build_compact_streaming(c.doc_terms, store, PARAMS,
                                        block_docs=32, row_align=64)
    assert mapped.storage.n_shards >= 3
    return c, index, store


# --------------------------------------------------------------------------
# Metrics registry + Prometheus exposition
# --------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("depth")
    g.set(3)
    g.inc(-1)
    assert g.value == 2 and g.max == 3
    h = reg.histogram("lat_s", window=8)
    for v in range(10):
        h.observe(float(v))
    # window slid to the last 8 samples; lifetime count/sum exact
    assert len(h) == 8 and h.count == 10 and h.sum == sum(range(10))
    assert h.percentile(100) == 9.0
    assert h.values().min() == 2.0

    # constructors are idempotent: same name -> same object ...
    assert reg.counter("reqs_total") is c
    # ... and kind / label skew fails loudly
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")
    with pytest.raises(ValueError):
        reg.counter("reqs_total", labels=("method",))


def test_registry_labeled_families():
    reg = MetricsRegistry()
    fam = reg.counter("tiles_total", labels=("shard", "event"))
    fam.labels(0, "fault").inc()
    fam.labels(0, "fault").inc()
    fam.labels("1", "hit").inc(3)
    # label values coerce to str; children keyed per tuple
    assert fam.labels("0", "fault").value == 2
    kids = dict(fam.children())
    assert kids[("0", "fault")].value == 2
    assert kids[("1", "hit")].value == 3
    with pytest.raises(ValueError):
        fam.labels("only-one")


def test_prometheus_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("served_total", "requests served").inc(7)
    reg.gauge("conns", "open connections").set(2)
    fam = reg.counter("by_method_total", labels=("method",))
    fam.labels("fused").inc(4)
    fam.labels('we"ird\nname').inc(1)            # escaping survives
    h = reg.histogram("wait_s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    text = render_prometheus(reg)
    assert "# TYPE served_total counter" in text
    assert "# TYPE wait_s summary" in text
    parsed = parse_prometheus(text)
    assert parsed["served_total"] == 7
    assert parsed["conns"] == 2
    assert parsed['by_method_total{method="fused"}'] == 4
    assert parsed['wait_s{quantile="0.5"}'] == 2.5
    assert parsed["wait_s_count"] == 4 and parsed["wait_s_sum"] == 10


# --------------------------------------------------------------------------
# Event log (JSONL)
# --------------------------------------------------------------------------

def test_event_log_jsonl_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    with EventLog(path, ring=4) as log:
        for i in range(6):
            log.emit("slow_query", {"trace_id": i})
        log.emit("other", {"x": 1})
    assert log.emitted == 7
    # the ring is bounded; kind filtering works on the tail
    tail = log.tail(kind="slow_query")
    assert [e["trace_id"] for e in tail] == [3, 4, 5]
    events = read_jsonl(path)
    assert len(events) == 7
    assert all("ts" in e and "kind" in e for e in events)
    # a torn trailing line (crash mid-write) parses around, not over
    with open(path, "a") as fh:
        fh.write('{"kind": "slow_q')
    assert len(read_jsonl(path)) == 7
    # memory-only log never touches disk
    mem = EventLog(None)
    mem.emit("k", {})
    assert mem.path is None and mem.emitted == 1


# --------------------------------------------------------------------------
# Traces
# --------------------------------------------------------------------------

def test_trace_spans_and_stage_totals():
    t = Trace(9, request_id=4, started_s=10.0)
    t.add("queue_wait", 10.0, 10.5)
    t.add("kernel_score", 10.5, 11.0, {"method": "fused"})
    t.add("kernel_score", 11.0, 11.25)
    assert not t.done
    totals = t.stage_totals()
    assert totals == {"queue_wait": 0.5, "kernel_score": 0.75}
    assert list(totals) == ["queue_wait", "kernel_score"]  # causal order
    d = t.to_json()
    assert d["trace_id"] == 9 and len(d["spans"]) == 3
    assert d["spans"][1]["tags"] == {"method": "fused"}


def test_tracer_ring_find_and_slow_sink():
    sink = EventLog(None)
    clock_now = [100.0]
    tracer = Tracer(ring=4, slow_ms=50.0, sink=sink,
                    clock=lambda: clock_now[0])
    fast = tracer.begin(1)
    clock_now[0] += 0.010
    tracer.finish(fast)                       # 10ms: under budget
    slow = tracer.begin(2, trace_id=777)      # wire-minted id honored
    assert slow.trace_id == 777
    slow.add("kernel_score", clock_now[0], clock_now[0] + 0.2)
    clock_now[0] += 0.200
    tracer.finish(slow)
    tracer.finish(slow)                       # idempotent: no double emit
    assert tracer.finished_count == 2 and tracer.slow_count == 1
    assert tracer.find(777) is slow and tracer.find(12345) is None
    (ev,) = sink.tail(kind="slow_query")
    assert ev["trace_id"] == 777
    assert ev["spans"][0]["name"] == "kernel_score"
    assert ev["duration_ms"] == pytest.approx(200.0)
    # disabled tracer: begin is None, finish(None) a no-op
    off = Tracer(enabled=False)
    assert off.begin(1) is None
    off.finish(None)
    assert off.finished_count == 0


# --------------------------------------------------------------------------
# Kernel profiler -> registry, and -> autotuner live costs (satellite)
# --------------------------------------------------------------------------

def test_profiler_records_into_registry():
    reg = MetricsRegistry()
    prof = KernelProfiler(reg, None)
    for i in range(3):
        prof.record(method="fused", bucket=64, batch=8,
                    seconds=0.001 * (i + 1), word_block=8,
                    bytes_moved=gather_bytes(8, 16), shard=2)
    assert prof.count == 3
    assert prof.records()[-1]["shard"] == 2
    hist = reg.get("kernel_score_seconds").labels("fused", 64, 8)
    assert hist.count == 3
    assert reg.get("kernel_bytes_moved_total").labels(
        "fused", 64).value == 3 * 8 * 16 * 4
    # disabled profiler is a no-op
    off = KernelProfiler(reg, None, enabled=False)
    off.record(method="fused", bucket=64, batch=8, seconds=1.0)
    assert off.count == 0


def test_profiler_feeds_tuner_observed_costs(tmp_path, built):
    """Live kernel timings promote to observed=True TuningCache entries
    that the planner's cost lookup then PREFERS over synthetic tunes."""
    _, index, _ = built
    cache = TuningCache(tmp_path / "tuning.json")
    tuner = KernelTuner.for_index(index, cache, enabled=False)
    tuner.live_min_samples = 4
    reg = MetricsRegistry()
    prof = KernelProfiler(reg, tuner)
    assert tuner.entry("lookup", 64, 4) is None          # cold, no tune
    for _ in range(4):
        prof.record(method="lookup", bucket=64, batch=4,
                    seconds=0.002, word_block=8, grid_order="qw")
    e = tuner.entry("lookup", 64, 4)
    assert e is not None and e.observed
    assert e.word_block == 8 and e.grid_order == "qw"
    assert e.cost_us == pytest.approx(2000.0)
    # persisted under the live prefix and survives reopen
    key = LIVE_PREFIX + tuner.key("lookup", 64, 4)
    assert key in TuningCache(tmp_path / "tuning.json").entries
    # non-tunable methods (dedup pair) never pollute the live cache
    before = tuner.observations
    prof.record(method="fused_dedup", bucket=64, batch=4,
                seconds=5.0, word_block=8)
    assert tuner.observations == before


# --------------------------------------------------------------------------
# ServingMetrics under concurrency (satellite: lock-guarded reads)
# --------------------------------------------------------------------------

def test_metrics_concurrent_hammer():
    """Writers (request/batch/worker/shard-tile recorders) race readers
    (percentiles, snapshots, the Prometheus renderer) across threads;
    the run must be exception-free and the totals exact."""
    m = ServingMetrics()
    n_writers, per_writer = 4, 400
    errors: list = []
    stop = threading.Event()

    def writer(wi: int) -> None:
        try:
            for i in range(per_writer):
                m.record_request(wait_s=0.001 * (i % 7),
                                 service_s=0.002, cached=False)
                m.record_batch(4, 0.5, "fused")
                m.record_worker(f"h{wi}", 0.001 * (i % 5 + 1))
                m.record_shard_tile(wi, "fault")
                m.set_queue_depth(i % 9)
        except Exception as e:                 # pragma: no cover
            errors.append(("writer", wi, e))

    def reader() -> None:
        try:
            while not stop.is_set():
                m.percentile_ms(99)
                m.worker_recent_s
                m.shard_tile_counts("fault")
                m.snapshot()
                render_prometheus(m.registry)
        except Exception as e:                 # pragma: no cover
            errors.append(("reader", e))

    writers = [threading.Thread(target=writer, args=(i,))
               for i in range(n_writers)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join(timeout=120)
    stop.set()
    for t in readers:
        t.join(timeout=120)
    assert not errors, errors
    assert m.served == n_writers * per_writer
    assert m.n_batches == n_writers * per_writer
    assert m.shard_tile_counts("fault") == {
        str(i): per_writer for i in range(n_writers)}
    assert m.percentile_ms(50) >= 0.0
    snap = m.snapshot()
    assert snap.served == n_writers * per_writer


# --------------------------------------------------------------------------
# Per-shard tile counters through the sharded frontend (satellite)
# --------------------------------------------------------------------------

def test_frontend_shard_tile_counters_use_global_ids(built):
    """Worker tile-cache events surface in the frontend registry keyed
    by GLOBAL shard id (workers cache by local substore index — the
    observer must translate), and dispatch spans name the shard."""
    c, _, store = built
    nodes = ["h0", "h1"]
    place = ShardPlacement.for_store(store, nodes, replication=2)
    held = place.replica_assignment()
    workers = {n: ShardWorker(n, store, held[n]) for n in nodes if held[n]}
    fe = Frontend(workers, place,
                  FrontendConfig(max_batch=8, max_wait_s=0.0,
                                 hedge_after_s=1e9))
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=21)
    for q in qs:
        fe.submit(q, threshold=0.7)
    fe.drain()
    assert all(r.status == Status.OK for r in fe.pop_responses().values())

    touched = set()
    for event in ("fault", "prefetch"):
        touched |= set(fe.metrics.shard_tile_counts(event))
    hits = fe.metrics.shard_tile_counts("hit")
    # every global shard was staged once (fault or prefetch), then hit
    assert touched == {str(g) for g in range(place.n_shards)}
    assert set(hits) <= {str(g) for g in range(place.n_shards)}
    assert sum(hits.values()) > 0
    # the trace's dispatch spans carry the same global shard ids
    shards_in_spans = set()
    for trace in fe.tracer.recent():
        for s in trace.spans():
            if s.name == "shard_dispatch":
                shards_in_spans.add(str(s.tags["shard"]))
    assert shards_in_spans == {str(g) for g in range(place.n_shards)}


# --------------------------------------------------------------------------
# Acceptance: socket query -> trace id + breakdown -> server slow log
# --------------------------------------------------------------------------

def test_socket_trace_matches_server_slow_log(built, tmp_path):
    """A NetClient query returns its trace id and per-stage breakdown,
    and the server's slow-query JSONL contains the MATCHING span tree
    for that id — the end-to-end observability acceptance path."""
    c, index, _ = built
    log = tmp_path / "slow.jsonl"
    server = QueryServer(index, ServerConfig(
        max_batch=4, max_wait_s=0.001,
        trace_slow_ms=1e-6,                  # everything is "slow"
        trace_log=str(log)))
    net = NetServer(ServingLoop(server)).start()
    (q,), _ = make_queries(c, n_pos=1, n_neg=0, length=120, seed=51)
    try:
        with NetClient(*net.address, timeout_s=60.0) as cl:
            r = cl.search(q, threshold=0.8)
            assert r.status == Status.OK and r.trace_id != 0
            assert r.stages and "queue_wait" in r.stages
            assert "kernel_score" in r.stages
    finally:
        net.close()

    # the deliver span is added after the RESULT frame is written, so
    # give the loop a beat to seal + flush the trace
    deadline = time.monotonic() + 10.0
    events = []
    while time.monotonic() < deadline:
        events = [e for e in read_jsonl(log)
                  if e.get("kind") == "slow_query"
                  and e.get("trace_id") == r.trace_id]
        if events:
            break
        time.sleep(0.02)
    assert events, f"trace {r.trace_id} never reached {log}"
    (ev,) = events

    # span tree matches the breakdown the wire carried: every wire stage
    # appears with the same total, and the log additionally has the
    # deliver span the loop appends after the frame goes out
    by_stage: dict = {}
    for s in ev["spans"]:
        by_stage[s["name"]] = (by_stage.get(s["name"], 0.0)
                               + s["end_s"] - s["start_s"])
    for name, seconds in r.stages.items():
        assert by_stage.get(name, -1.0) == pytest.approx(seconds)
    assert "deliver" in by_stage
    assert ev["duration_ms"] > 0
    # intervals are sane: every span inside [started_s, ended_s]
    for s in ev["spans"]:
        assert ev["started_s"] <= s["start_s"] <= s["end_s"]
        assert s["end_s"] <= ev["ended_s"] + 1e-9
    # and the server-side ring has the same sealed trace
    trace = server.tracer.find(r.trace_id)
    assert trace is not None and trace.done
    assert trace.stage_totals().keys() == by_stage.keys()


def test_stats_snapshot_counts_traces(built):
    """MetricsSnapshot surfaces the tracer's finished/slow counters (the
    JSON STATS body clients poll)."""
    c, index, _ = built
    server = QueryServer(index, ServerConfig(max_batch=4, max_wait_s=0.0,
                                             trace_slow_ms=1e-6))
    qs, _ = make_queries(c, n_pos=2, n_neg=1, length=100, seed=53)
    for q in qs:
        server.submit(q, threshold=0.7)
    server.drain()
    assert all(r.status == Status.OK
               for r in server.pop_responses().values())
    snap = server.metrics.snapshot()
    assert snap.traces_finished >= len(qs)
    assert snap.slow_queries >= len(qs)      # threshold is microscopic
    assert json.dumps(snap.__dict__)         # snapshot stays serializable
