"""Serving subsystem tests: batcher, planner, caches, metrics, and the
end-to-end guarantee — anything that flows through the micro-batching
server is byte-identical to a per-query QueryEngine.search."""
import numpy as np
import pytest

from repro.core import IndexParams, QueryEngine, build_classic, build_compact
from repro.core.query import padded_len, select_hits
from repro.data import make_corpus, make_queries
from repro.serve import (LRUCache, MicroBatcher, QueryPlanner, QueryRequest,
                         QueryServer, ServerConfig, ServingMetrics, Status)


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(48, k=15, mean_length=400, sigma=1.0, seed=7)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    compact = build_compact(corpus.doc_terms, params, block_docs=32,
                            row_align=64)
    return corpus, compact


def _req(rid, ell, now=0.0, deadline=None, threshold=0.8):
    terms = np.full((ell, 2), rid + 1, dtype=np.uint32)
    return QueryRequest(rid, terms, ell, threshold, submitted_at=now,
                       deadline=deadline)


# --------------------------------------------------------------------------
# MicroBatcher
# --------------------------------------------------------------------------

def test_batcher_buckets_by_padded_length():
    b = MicroBatcher(term_pad=64, max_batch=8, max_wait_s=10.0)
    for rid, ell in enumerate([3, 60, 64, 65, 190]):
        assert b.submit(_req(rid, ell))
    batches, expired = b.poll(now=0.0, force=True)
    assert not expired
    got = {mb.bucket: sorted(r.request_id for r in mb.requests)
           for mb in batches}
    assert got == {64: [0, 1, 2], 128: [3], 192: [4]}
    assert all(padded_len(r.n_terms, 64) == mb.bucket
               for mb in batches for r in mb.requests)


def test_batcher_flushes_full_bucket_immediately():
    b = MicroBatcher(term_pad=64, max_batch=4, max_wait_s=100.0)
    for rid in range(11):
        b.submit(_req(rid, 10))
    batches, _ = b.poll(now=0.0)
    # two full batches leave; the remainder (3) waits for the timer
    assert [mb.size for mb in batches] == [4, 4]
    assert len(b) == 3
    batches, _ = b.poll(now=200.0)
    assert [mb.size for mb in batches] == [3]


def test_batcher_wait_timer():
    b = MicroBatcher(term_pad=64, max_batch=8, max_wait_s=0.5)
    b.submit(_req(0, 10, now=1.0))
    assert b.poll(now=1.2)[0] == []          # not due yet
    batches, _ = b.poll(now=1.6)             # oldest waited 0.6 > 0.5
    assert len(batches) == 1 and batches[0].size == 1


def test_batcher_backpressure():
    b = MicroBatcher(term_pad=64, max_batch=4, max_queued=2)
    assert b.submit(_req(0, 5))
    assert b.submit(_req(1, 5))
    assert not b.submit(_req(2, 5))          # full -> refused
    b.poll(now=0.0, force=True)
    assert b.submit(_req(3, 5))              # drained -> accepts again


def test_batcher_drops_expired():
    b = MicroBatcher(term_pad=64, max_batch=8)
    b.submit(_req(0, 5, now=0.0, deadline=1.0))
    b.submit(_req(1, 5, now=0.0, deadline=50.0))
    batches, expired = b.poll(now=2.0, force=True)
    assert [r.request_id for r in expired] == [0]
    assert [r.request_id for mb in batches for r in mb.requests] == [1]


def test_batcher_sweeps_non_head_deadlines():
    """A deadline BEHIND the bucket head still wakes the poll and is
    dropped on time — the live head keeps waiting for fill/timer."""
    b = MicroBatcher(term_pad=64, max_batch=8, max_wait_s=100.0)
    b.submit(_req(0, 10, now=0.0))                  # no deadline (head)
    b.submit(_req(1, 10, now=0.0, deadline=1.0))    # queued behind it
    assert b.next_due_at() == 1.0                   # deadline, not timer
    batches, expired = b.poll(now=2.0)
    assert [r.request_id for r in expired] == [1]
    assert batches == [] and len(b) == 1            # head still queued
    assert b.next_due_at() == 100.0                 # back to the timer


# --------------------------------------------------------------------------
# LRUCache
# --------------------------------------------------------------------------

def test_lru_eviction_order():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                   # refresh a
    c.put("c", 3)                            # evicts b (least recent)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.hits == 3 and c.misses == 1


def test_lru_zero_capacity_disabled():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None and len(c) == 0


# --------------------------------------------------------------------------
# QueryPlanner
# --------------------------------------------------------------------------

def test_planner_rules_k1(setup):
    _, compact = setup
    p = QueryPlanner(compact)                # n_hashes == 1
    assert p.plan(64, 8).method == "lookup"  # batch -> fused
    assert p.plan(64, 8).fused
    assert p.plan(64, 1).method == "unpack"  # short singleton
    assert p.plan(256, 1).method == "lookup"  # long singleton, k=1


def test_planner_rules_k2(setup):
    corpus, _ = setup
    idx = build_classic(corpus.doc_terms,
                        IndexParams(n_hashes=2, fpr=0.3, kmer=15))
    p = QueryPlanner(idx)
    assert p.plan(64, 8).method == "unpack"   # short batch, k>1
    assert p.plan(256, 8).method == "vertical"
    assert p.plan(256, 1).method == "vertical"
    assert not p.plan(256, 8).fused


def test_planner_never_plans_ref(setup):
    _, compact = setup
    p = QueryPlanner(compact)
    for bucket in (64, 128, 512):
        for q in (1, 2, 32):
            assert p.plan(bucket, q).method in ("lookup", "vertical",
                                                "unpack")


# --------------------------------------------------------------------------
# ServingMetrics
# --------------------------------------------------------------------------

def test_metrics_percentiles_and_occupancy():
    m = ServingMetrics()
    for ms in (1, 2, 3, 4, 100):
        m.record_request(wait_s=ms / 1e3, service_s=0.0)
    m.record_batch(8, 0.25, "lookup")
    m.record_batch(4, 0.125, "unpack")
    m.record_rejected()
    s = m.snapshot()
    assert s.served == 5 and s.rejected == 1 and s.batches == 2
    assert s.p50_ms == pytest.approx(3.0)
    assert s.p99_ms > 50
    assert s.mean_occupancy == pytest.approx(0.1875)
    assert s.methods == {"lookup": 8, "unpack": 4}
    assert "p50" in s.report()


# --------------------------------------------------------------------------
# QueryServer end-to-end
# --------------------------------------------------------------------------

def test_server_results_byte_identical_and_planner_mixes(setup):
    """The acceptance test: a mixed-length 'concurrent' workload through the
    batcher produces byte-identical results to per-query search, and the
    planner exercises >= 2 distinct kernels along the way."""
    corpus, compact = setup
    eng = QueryEngine(compact)
    workload = []
    for i, length in enumerate((30, 40, 90, 200, 400)):
        qs, _ = make_queries(corpus, n_pos=3, n_neg=3, length=length,
                             seed=20 + i)
        workload.extend(qs)
    rng = np.random.default_rng(0)
    workload = [workload[i] for i in rng.permutation(len(workload))]

    server = QueryServer(compact, ServerConfig(max_batch=8, max_wait_s=0.0,
                                               result_cache=0))
    ids = [server.submit(q, threshold=0.7) for q in workload]
    server.drain()
    # one lone short query flushed by itself exercises the singleton path
    lone, _ = make_queries(corpus, n_pos=1, n_neg=0, length=25, seed=99)
    lone_id = server.submit(lone[0], threshold=0.7)
    server.drain()
    responses = server.pop_responses()

    for rid, q in list(zip(ids, workload)) + [(lone_id, lone[0])]:
        r = responses[rid]
        assert r.status == Status.OK
        want = eng.search(q, threshold=0.7)
        np.testing.assert_array_equal(r.result.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(r.result.scores, want.scores)
        assert r.result.n_terms == want.n_terms
        assert r.result.threshold == want.threshold

    assert len(server.planner.methods_used) >= 2, \
        server.planner.dispatch_counts
    snap = server.metrics.snapshot()
    assert snap.served == len(workload) + 1
    assert snap.batches >= 2


def test_server_result_cache_hit(setup):
    corpus, compact = setup
    qs, _ = make_queries(corpus, n_pos=2, n_neg=0, length=100, seed=41)
    server = QueryServer(compact, ServerConfig(max_batch=4, max_wait_s=0.0))
    a = server.submit(qs[0]); b = server.submit(qs[1])
    server.drain()
    first = server.pop_responses()
    c = server.submit(qs[0])                  # identical resubmission
    server.drain()
    second = server.pop_responses()
    assert second[c].cached and second[c].method == "cache"
    np.testing.assert_array_equal(second[c].result.doc_ids,
                                  first[a].result.doc_ids)
    assert server.metrics.cache_hits == 1


def test_server_point_query_row_cache(setup):
    """Single-k-mer point queries are answered host-side from the row cache
    and still match the engine exactly."""
    corpus, compact = setup
    eng = QueryEngine(compact)
    term = corpus.doc_terms[3][:1]
    server = QueryServer(compact)
    a = server.submit(terms=term, threshold=0.5)
    b = server.submit(terms=term.copy(), threshold=0.9)
    resp = server.pop_responses()             # answered at submit, no drain
    assert resp[a].method == "row_cache"
    want = select_hits(eng.score_terms(term), 1, 0.5)
    np.testing.assert_array_equal(resp[a].result.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(resp[a].result.scores, want.scores)
    assert server.rows_cache.hits == 1        # second submit reused the row


def test_server_backpressure_rejects(setup):
    corpus, compact = setup
    qs, _ = make_queries(corpus, n_pos=4, n_neg=0, length=80, seed=51)
    server = QueryServer(compact, ServerConfig(max_queued=2, max_batch=8,
                                               result_cache=0, row_cache=0))
    ids = [server.submit(q) for q in qs]
    server.drain()
    resp = server.pop_responses()
    statuses = [resp[i].status for i in ids]
    assert statuses.count(Status.REJECTED) == 2
    assert statuses.count(Status.OK) == 2
    assert server.metrics.snapshot().rejected == 2


def test_server_deadline_drop(setup):
    corpus, compact = setup
    qs, _ = make_queries(corpus, n_pos=2, n_neg=0, length=80, seed=61)
    t = [0.0]
    server = QueryServer(compact,
                         ServerConfig(max_batch=8, max_wait_s=0.0,
                                      result_cache=0),
                         clock=lambda: t[0])
    a = server.submit(qs[0], deadline=1.0)
    b = server.submit(qs[1], deadline=100.0)
    t[0] = 5.0                                # past a's deadline
    server.drain()
    resp = server.pop_responses()
    assert resp[a].status == Status.DROPPED and resp[a].result is None
    assert resp[b].status == Status.OK
    assert server.metrics.snapshot().dropped == 1


def test_server_empty_query_immediate(setup):
    _, compact = setup
    server = QueryServer(compact)
    rid = server.submit("ACG")                # shorter than k
    resp = server.pop_responses()
    assert resp[rid].status == Status.OK
    assert len(resp[rid].result.doc_ids) == 0


def test_server_batch_vs_engine_on_classic_k2(setup):
    """k=2 index: the planner cannot fuse, results must still be exact."""
    corpus, _ = setup
    idx = build_classic(corpus.doc_terms,
                        IndexParams(n_hashes=2, fpr=0.3, kmer=15))
    eng = QueryEngine(idx)
    qs, _ = make_queries(corpus, n_pos=4, n_neg=4, length=120, seed=71)
    server = QueryServer(idx, ServerConfig(max_batch=4, max_wait_s=0.0,
                                           result_cache=0))
    ids = [server.submit(q, threshold=0.6) for q in qs]
    server.drain()
    resp = server.pop_responses()
    for rid, q in zip(ids, qs):
        want = eng.search(q, threshold=0.6)
        np.testing.assert_array_equal(resp[rid].result.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(resp[rid].result.scores, want.scores)
