"""The networked shard data plane (repro.serve.rpc): real RPC fan-out
with cancellable hedges.

Load-bearing invariants, mirroring the rest of the serving stack:

* anything that crosses the SHARD_QUERY/SHARD_RESULT wire must gather
  BIT-IDENTICALLY to a synchronous QueryEngine run — threshold and
  top-k alike;
* failure is loud and bounded: a worker killed mid-SHARD_RESULT fails
  every pending future with a distinct RpcError (never a hang), the
  channel goes unhealthy, backoff-redials after a restart, and in-flight
  queries fail over to replicas with ZERO lost queries;
* hedged backups are REAL duplicate requests on the wall clock, and the
  loser is observably cancelled: the straggling worker's
  ``cancelled_tiles`` counter moves.

The multi-process test at the bottom drives actual ``--worker``
subprocesses through launch.cluster (OS-assigned ports via --port-file)
and SIGKILLs one mid-load.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import IndexParams, QueryEngine, build_compact
from repro.data import make_corpus, make_queries
from repro.index import ShardPlacement, build_compact_streaming
from repro.serve import (FrontendConfig, NetClient, NetServer, RpcFrontend,
                         ServingLoop, ShardWorker, Status, WorkerChannel,
                         WorkerPool, WorkerServer)
from repro.serve.net import (MSG_PING, MSG_SHARD_QUERY, PROTO_VERSION,
                             SHARD_FAILED, SHARD_OK, decode_rid,
                             decode_shard_query, decode_shard_result,
                             encode_cancel, encode_hello, encode_ping,
                             encode_shard_query, encode_shard_result,
                             read_frame, write_frame)
from repro.serve.rpc import ChannelDown, RpcError

PARAMS = IndexParams(n_hashes=1, fpr=0.3, kmer=15)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    c = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=11)
    index = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = tmp_path_factory.mktemp("rpc-store") / "v2"
    mapped, _ = build_compact_streaming(c.doc_terms, store, PARAMS,
                                        block_docs=32, row_align=64)
    assert mapped.storage.n_shards >= 3
    return c, index, store


@pytest.fixture(scope="module")
def oracle(built):
    return QueryEngine(built[1])


def _assert_identical(got, want):
    assert np.array_equal(got.doc_ids, want.doc_ids)
    assert np.array_equal(got.scores, want.scores)


# --------------------------------------------------------------------------
# v4 wire frames: pure encode/decode round trips
# --------------------------------------------------------------------------

def test_shard_query_round_trip():
    rng = np.random.default_rng(0)
    buf = rng.integers(0, 2**32, size=(4, 64, 2), dtype=np.uint32)
    n_valid = np.array([40, 7, 0, 0], np.int32)
    cutoffs = np.array([30, 5, 0, 0], np.int32)
    topks = np.array([0, 10, 0, 0], np.int32)
    p = encode_shard_query(7, 2, buf, n_valid, cutoffs, topks, n_live=2)
    rid, gshard, b, nv, co, tk, n_live = decode_shard_query(p)
    assert (rid, gshard, n_live) == (7, 2, 2)
    assert np.array_equal(b, buf)
    assert np.array_equal(nv, n_valid)
    assert np.array_equal(co, cutoffs)
    assert np.array_equal(tk, topks)


def test_shard_query_rejects_torn_payload():
    buf = np.zeros((2, 8, 2), np.uint32)
    z = np.zeros(2, np.int32)
    p = encode_shard_query(1, 0, buf, z, z, z, 2)
    with pytest.raises(ConnectionError):
        decode_shard_query(p[:-4])


def test_shard_result_round_trip():
    cands = [(np.array([3, 1], np.int32), np.array([9, 5], np.int32)),
             (np.zeros(0, np.int32), np.zeros(0, np.int32))]
    prune = (10, 4, 2, 1000, 5000)
    p = encode_shard_result(42, SHARD_OK, "fused", cands, prune)
    rid, status, method, got, pr = decode_shard_result(p)
    assert (rid, status, method, pr) == (42, SHARD_OK, "fused", prune)
    assert len(got) == 2
    assert np.array_equal(got[0][0], cands[0][0])
    assert np.array_equal(got[0][1], cands[0][1])
    assert got[1][0].size == 0


def test_shard_result_failed_carries_error_text():
    p = encode_shard_result(5, SHARD_FAILED, "worker w1: shard gone")
    rid, status, method, cands, _ = decode_shard_result(p)
    assert (rid, status) == (5, SHARD_FAILED)
    assert method == "worker w1: shard gone"
    assert cands == []


def test_cancel_and_ping_round_trip():
    assert decode_rid(encode_cancel(99)) == 99
    assert decode_rid(encode_ping(7)) == 7
    assert decode_rid(encode_ping(7, pong=True)) == 7


# --------------------------------------------------------------------------
# In-process fleet: WorkerServer + WorkerPool + RpcFrontend
# --------------------------------------------------------------------------

def _fleet(store, nodes, *, replication=2, straggle=None, **cfg):
    """(frontend, servers) over in-process WorkerServers on ephemeral
    localhost ports."""
    placement = ShardPlacement.for_store(
        store, nodes, replication=min(replication, len(nodes)))
    held = placement.replica_assignment()
    straggle = straggle or {}
    servers = {n: WorkerServer(ShardWorker(n, store, held[n]),
                               straggle_s=straggle.get(n, 0.0)).start()
               for n in nodes if held[n]}
    pool = WorkerPool({n: s.address for n, s in servers.items()})
    pool.wait_connected()
    fe = RpcFrontend(pool, placement,
                     FrontendConfig(max_wait_s=0.0, **cfg))
    return fe, servers


def _shutdown(fe, servers):
    fe.close()
    for s in servers.values():
        s.close()


def test_rpc_bit_identical_threshold_and_topk(built, oracle):
    """Every result gathered over the wire matches the single-host
    engine bit for bit — threshold coverage-cutoff AND top-k."""
    c, _, store = built
    fe, servers = _fleet(store, ["w0", "w1", "w2"], hedge_after_s=30.0)
    try:
        assert fe.verify_placement() == {}
        qs, _ = make_queries(c, n_pos=8, n_neg=4, length=120, seed=3)
        ids = [fe.submit(q, threshold=0.75) for q in qs]
        ids += [fe.submit(q, top_k=5) for q in qs]
        fe.drain()
        resp = fe.pop_responses()
        for rid, q in zip(ids, qs + qs):
            r = resp[rid]
            assert r.status == Status.OK
        for rid, q in zip(ids[:len(qs)], qs):
            _assert_identical(resp[rid].result,
                              oracle.search(q, threshold=0.75))
        for rid, q in zip(ids[len(qs):], qs):
            _assert_identical(resp[rid].result, oracle.top_k(q, k=5))
        snap = fe.metrics.snapshot()
        assert snap.rpcs_sent >= fe.placement.n_shards
        assert snap.channels_up == len(servers)
    finally:
        _shutdown(fe, servers)


def test_hedge_fires_real_duplicate_and_cancels_loser(built, oracle):
    """An injected straggler makes the primary dawdle past hedge_after:
    a REAL duplicate RPC fires at the backup, wins, and the loser is
    observably cancelled — the straggling worker's cancelled_tiles
    counter moves (it did NOT silently complete the dispatch)."""
    c, _, store = built
    placement = ShardPlacement.for_store(store, ["w0", "w1"],
                                         replication=2)
    straggler = placement.owner(0)        # primary for shard 0
    fe, servers = _fleet(store, ["w0", "w1"],
                         straggle={straggler: 0.4},
                         hedge_after_s=0.05)
    try:
        qs, _ = make_queries(c, n_pos=4, n_neg=2, length=120, seed=5)
        # warmup: compile every kernel shape so the measured pass's
        # timing is dominated by the injected straggle, not jit
        for q in qs:
            fe.submit(q, threshold=0.75)
        fe.drain()
        fe.pop_responses()
        fe.reset_metrics()

        ids = [fe.submit(q, threshold=0.75) for q in qs]
        fe.drain()
        resp = fe.pop_responses()
        for rid, q in zip(ids, qs):
            assert resp[rid].status == Status.OK
            _assert_identical(resp[rid].result,
                              oracle.search(q, threshold=0.75))
        ex = fe.executor
        assert ex.hedges_fired > 0        # real duplicates went out
        assert ex.hedges_won > 0          # ... and won the race
        assert ex.hedges_cancelled > 0    # ... and the loser was told
        stats = fe.pool.channel(straggler).stats()
        assert stats["cancelled_tiles"] > 0
        snap = fe.metrics.snapshot()
        assert snap.hedges_cancelled == ex.hedges_cancelled
        # CANCEL frames actually went out on the wire
        assert fe.metrics.rpc_count("cancelled") > 0
    finally:
        _shutdown(fe, servers)


def test_worker_server_killed_mid_load_zero_lost(built, oracle):
    """Close a WorkerServer abruptly while queries flow: in-flight
    dispatches fail over to the replica, zero queries are lost, results
    stay bit-identical."""
    c, _, store = built
    fe, servers = _fleet(store, ["w0", "w1", "w2"], hedge_after_s=30.0)
    try:
        qs, _ = make_queries(c, n_pos=6, n_neg=2, length=120, seed=6)
        ids = [fe.submit(q, threshold=0.75) for q in qs]
        fe.drain()
        fe.pop_responses()                # warm: every shape compiled

        victim = fe.placement.owner(0)
        stop = threading.Event()

        def killer():
            time.sleep(0.02)              # land mid-load
            servers[victim].close(abort=True)
            stop.set()

        t = threading.Thread(target=killer)
        t.start()
        ids = []
        for rep in range(4):
            ids += [fe.submit(q, threshold=0.75) for q in qs]
            fe.drain()
        t.join()
        resp = fe.pop_responses()
        assert len(resp) == len(ids)
        for rid in ids:
            assert resp[rid].status == Status.OK, resp[rid]
        for rid, q in zip(ids, qs * 4):
            _assert_identical(resp[rid].result,
                              oracle.search(q, threshold=0.75))
        assert not fe.pool.channel(victim).healthy
    finally:
        _shutdown(fe, servers)


# --------------------------------------------------------------------------
# Channel failure modes against a scripted fake worker (no jax, no index)
# --------------------------------------------------------------------------

class _FakeWorker:
    """A scripted peer: HELLOs like a worker, then follows ``script`` on
    the first SHARD_QUERY — 'torn' dies mid-SHARD_RESULT, 'mute' never
    answers, 'ok' replies an empty result."""

    def __init__(self, script="ok", port=0):
        self.script = script
        self.dead = False
        self._live: set = set()
        self.listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.listener.bind(("127.0.0.1", port))
        self.listener.listen(8)
        self.address = self.listener.getsockname()
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self):
        while True:
            try:
                conn, _ = self.listener.accept()
            except OSError:
                return
            if self.dead:                 # a corpse accepts no one
                conn.close()
                continue
            self._live.add(conn)
            threading.Thread(target=self._conn, args=(conn,),
                             daemon=True).start()

    def _conn(self, conn):
        try:
            write_frame(conn, encode_hello(PARAMS, 96, PROTO_VERSION))
            while True:
                payload = read_frame(conn)
                if payload is None or self.dead:
                    return
                if payload[0] == MSG_PING:
                    write_frame(conn, encode_ping(decode_rid(payload),
                                                  pong=True))
                    continue
                if payload[0] != MSG_SHARD_QUERY:
                    continue              # e.g. a late CANCEL
                rid, _, _, nv, _, _, n_live = decode_shard_query(payload)
                if self.script == "torn":
                    # half a SHARD_RESULT: length prefix promises 4096
                    # bytes, the peer dies after 10 — the torn-frame
                    # case. The whole fake dies with it (listener too),
                    # like a killed process, so the redialer is refused.
                    self.dead = True
                    conn.sendall(struct.pack("!I", 4096) + b"\x01" * 10)
                    conn.close()
                    self.close()
                    return
                if self.script == "mute":
                    continue
                empty = [(np.zeros(0, np.int32), np.zeros(0, np.int32))
                         for _ in range(n_live)]
                write_frame(conn, encode_shard_result(
                    rid, SHARD_OK, "fake", empty))
        except (OSError, ConnectionError):
            pass
        finally:
            self._live.discard(conn)

    def close(self):
        """Die like a killed process: listener AND live connections."""
        self.dead = True
        try:
            self.listener.close()
        except OSError:
            pass
        for conn in list(self._live):
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass


def _submit_dummy(ch):
    buf = np.zeros((1, 8, 2), np.uint32)
    z = np.zeros(1, np.int32)
    return ch.submit_shard(0, buf, z, z, z, 1)


def test_torn_frame_fails_pending_fast_no_hang():
    """A peer dying mid-SHARD_RESULT fails every pending future with a
    distinct RpcError — promptly, never a hang — and marks the channel
    unhealthy so the next dispatch refuses with ChannelDown."""
    fake = _FakeWorker(script="torn")
    ch = WorkerChannel("t0", *fake.address)
    try:
        deadline = time.monotonic() + 5
        while not ch.healthy and time.monotonic() < deadline:
            time.sleep(0.01)
        assert ch.healthy
        fut = _submit_dummy(ch)
        with pytest.raises(RpcError, match="t0"):
            fut.result(timeout=5.0)       # bounded: fails, no hang
        assert not ch.healthy
        time.sleep(0.1)                   # redial is being refused
        with pytest.raises(ChannelDown):
            _submit_dummy(ch)
    finally:
        ch.close()
        fake.close()


def test_channel_backoff_reconnects_after_restart():
    """Kill the peer entirely, then restart it on the SAME port: the
    background redialer recovers the channel (exponential backoff) and
    RPCs flow again — connection reuse, no caller intervention."""
    fake = _FakeWorker(script="ok")
    host, port = fake.address
    ch = WorkerChannel("r0", host, port)
    try:
        deadline = time.monotonic() + 5
        while not ch.healthy and time.monotonic() < deadline:
            time.sleep(0.01)
        cands, method = _submit_dummy(ch).result(5.0)
        assert method == "fake"

        fake.close()                      # peer gone
        with pytest.raises((RpcError, ChannelDown)):
            _submit_dummy(ch).result(5.0)
        assert not ch.healthy

        deadline = time.monotonic() + 10
        while True:                       # old conn may linger in
            try:                          # FIN_WAIT a moment
                fake = _FakeWorker(script="ok", port=port)   # same port
                break
            except OSError:
                assert time.monotonic() < deadline
                time.sleep(0.1)
        while not ch.healthy and time.monotonic() < deadline:
            time.sleep(0.02)
        assert ch.healthy                 # backoff redial found it
        assert ch.reconnects >= 1
        cands, method = _submit_dummy(ch).result(5.0)
        assert method == "fake"
        assert ch.ping()
    finally:
        ch.close()
        fake.close()


def test_cancel_frame_reaches_the_wire():
    """cancel(rid) drops the pending future and sends a CANCEL frame the
    worker side can observe (the _FakeWorker 'mute' script never replies,
    so the only traffic after the query IS the cancel)."""
    fake = _FakeWorker(script="mute")
    ch = WorkerChannel("c0", *fake.address)
    try:
        deadline = time.monotonic() + 5
        while not ch.healthy and time.monotonic() < deadline:
            time.sleep(0.01)
        fut = _submit_dummy(ch)
        ch.cancel(fut.rid)
        # the future is forgotten: a late SHARD_RESULT for it would be
        # dropped, and the channel stays healthy for the next dispatch
        assert ch.healthy
        fut2 = _submit_dummy(ch)
        assert fut2.rid > fut.rid
    finally:
        ch.close()
        fake.close()


# --------------------------------------------------------------------------
# Multi-process: real --worker subprocesses, SIGKILL mid-load, restart
# --------------------------------------------------------------------------

def test_multiprocess_cluster_kill_and_reconnect(built, oracle):
    """The full acceptance path: 3 worker PROCESSES behind OS-assigned
    ports (discovered via --port-file), a frontend dialing the
    reconnecting pool behind a TCP front door, concurrent socket
    clients; one worker SIGKILLed mid-load -> zero FAILED queries, all
    results bit-identical; the killed worker restarts on the same port
    and its channel backoff-reconnects."""
    from repro.launch.cluster import WorkerCluster

    c, _, store = built
    qs, _ = make_queries(c, n_pos=6, n_neg=2, length=120, seed=21)
    with WorkerCluster(str(store), ["p0", "p1", "p2"],
                       replication=2) as cluster:
        placement = ShardPlacement.for_store(str(store),
                                             ["p0", "p1", "p2"],
                                             replication=2)
        pool = WorkerPool(cluster.addresses)
        pool.wait_connected(timeout_s=30.0)
        fe = RpcFrontend(pool, placement,
                         FrontendConfig(max_wait_s=0.0,
                                        hedge_after_s=30.0))
        net = NetServer(ServingLoop(fe, workers=2)).start()
        try:
            victim = placement.owner(0)

            def client(ci, out):
                cl = NetClient(*net.address, timeout_s=120.0)
                try:
                    for rep in range(3):
                        futs = [(q, cl.submit(q, threshold=0.75))
                                for q in qs]
                        for q, f in futs:
                            out.append((q, f.result(120.0)))
                finally:
                    cl.close()

            outs = [[] for _ in range(3)]
            threads = [threading.Thread(target=client, args=(i, outs[i]))
                       for i in range(3)]
            for t in threads:
                t.start()
            time.sleep(0.3)               # queries in flight
            cluster.kill(victim)          # SIGKILL, no drain
            for t in threads:
                t.join(timeout=180.0)
                assert not t.is_alive()

            n = 0
            for out in outs:
                for q, r in out:
                    assert r.status == Status.OK, (q, r.status)
                    _assert_identical(r.result,
                                      oracle.search(q, threshold=0.75))
                    n += 1
            assert n == 3 * 3 * len(qs)   # zero lost queries

            # restart on the SAME port: the channel must come back
            cluster.restart(victim)
            deadline = time.monotonic() + 30
            while (not pool.channel(victim).healthy
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert pool.channel(victim).healthy
            assert pool.channel(victim).reconnects >= 1
            snap = fe.metrics.snapshot()
            assert snap.channel_reconnects >= 1
        finally:
            net.close(drain=False)
            fe.close()
