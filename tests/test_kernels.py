"""Per-kernel validation: shape sweeps + hypothesis properties, asserting
EXACT equality against the pure-jnp oracles in repro.kernels.ref (outputs
are integer counts — allclose would hide off-by-ones)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels import bitslice_score as k

SHAPES = [(8, 8), (8, 128), (16, 128), (64, 256), (8, 384), (200, 96),
          (1, 8), (7, 130), (1000, 64)]


def _rand_rows(L, W, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2 ** 32, size=(L, W), dtype=np.uint32)


@pytest.mark.parametrize("L,W", SHAPES)
@pytest.mark.parametrize("method", ["unpack", "vertical"])
def test_score_kernels_match_ref(L, W, method):
    rows = _rand_rows(L, W, seed=L * 1000 + W)
    want = np.asarray(ref.bitslice_score_ref(jnp.asarray(rows)))
    got = np.asarray(ops.bitslice_score(jnp.asarray(rows), method=method))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("L,W", [(8, 8), (64, 128), (33, 40), (128, 256)])
def test_lookup_kernel_matches_ref(L, W):
    rng = np.random.default_rng(L + W)
    R = 4 * L
    arena = rng.integers(0, 2 ** 32, size=(R, W), dtype=np.uint32)
    idx = rng.integers(0, R, size=L).astype(np.int32)
    mask = rng.integers(0, 2, size=L).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)


def test_zero_rows_contribute_zero():
    rows = np.zeros((16, 64), dtype=np.uint32)
    out = np.asarray(ops.bitslice_score(jnp.asarray(rows)))
    assert (out == 0).all()


def test_all_ones_counts_L():
    L, W = 24, 32
    rows = np.full((L, W), 0xFFFFFFFF, dtype=np.uint32)
    for method in ("unpack", "vertical"):
        out = np.asarray(ops.bitslice_score(jnp.asarray(rows), method=method))
        assert (out == L).all()


def test_single_bit_isolation():
    """Exactly one document's score increments per set bit."""
    L, W = 8, 16
    rows = np.zeros((L, W), dtype=np.uint32)
    rows[3, 5] = np.uint32(1) << 17  # doc 5*32+17
    for method in ("unpack", "vertical"):
        out = np.asarray(ops.bitslice_score(jnp.asarray(rows), method=method))
        assert out[5 * 32 + 17] == 1 and out.sum() == 1


def test_and_rows():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 2 ** 32, size=(8, 3, 16), dtype=np.uint32)
    got = np.asarray(ops.and_rows(jnp.asarray(rows)))
    want = rows[:, 0] & rows[:, 1] & rows[:, 2]
    np.testing.assert_array_equal(want, got)


def test_vmap_batches():
    f = lambda r: ops.bitslice_score(r, method="vertical")
    rows = jnp.asarray(_rand_rows(16, 64, 1)).reshape(2, 8, 64)
    got = jax.vmap(f)(rows)
    want = jnp.stack([ref.bitslice_score_ref(rows[0]),
                      ref.bitslice_score_ref(rows[1])])
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_num_planes():
    assert k._num_planes(1) == 1
    assert k._num_planes(7) == 3
    assert k._num_planes(8) == 4
    assert k._num_planes(1023) == 10
    assert k._num_planes(1024) == 11


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 100), st.integers(1, 40), st.integers(0, 2 ** 31),
       st.sampled_from(["unpack", "vertical"]))
def test_property_kernel_equals_oracle(L, W, seed, method):
    rows = _rand_rows(L, W, seed)
    want = np.asarray(ref.bitslice_score_ref(jnp.asarray(rows)))
    got = np.asarray(ops.bitslice_score(jnp.asarray(rows), method=method))
    np.testing.assert_array_equal(want, got)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(1, 20), st.integers(0, 2 ** 31))
def test_property_lookup_equals_oracle(L, W, seed):
    rng = np.random.default_rng(seed)
    arena = rng.integers(0, 2 ** 32, size=(2 * L + 1, W), dtype=np.uint32)
    idx = rng.integers(0, arena.shape[0], size=L).astype(np.int32)
    mask = rng.integers(0, 2, size=L).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("nb,L,W", [(3, 17, 8), (5, 64, 40), (2, 100, 130),
                                    (1, 8, 128)])
def test_lookup_blocks_kernel_matches_ref(nb, L, W):
    rng = np.random.default_rng(nb * 100 + L)
    R = 4 * L
    arena = rng.integers(0, 2 ** 32, size=(R, W), dtype=np.uint32)
    idx = rng.integers(0, R, size=(nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_blocks_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_blocks(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("Q,nb,L,W", [(1, 1, 8, 8), (3, 2, 17, 8),
                                      (4, 1, 33, 130), (2, 3, 64, 40)])
def test_lookup_multi_kernel_matches_ref(Q, nb, L, W):
    rng = np.random.default_rng(Q * 1000 + nb * 100 + L)
    R = 4 * L
    arena = rng.integers(0, 2 ** 32, size=(R, W), dtype=np.uint32)
    idx = rng.integers(0, R, size=(Q, nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_multi_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_multi(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)


def test_lookup_multi_row_agrees_with_blocks():
    """Each query slice of the multi kernel must equal the single-query
    blocks kernel on the same indices (the fallback it replaces)."""
    rng = np.random.default_rng(9)
    Q, nb, L, W = 3, 2, 24, 16
    arena = rng.integers(0, 2 ** 32, size=(64, W), dtype=np.uint32)
    idx = rng.integers(0, 64, size=(Q, nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    multi = np.asarray(ops.bitslice_lookup_score_multi(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    for q in range(Q):
        single = np.asarray(ops.bitslice_lookup_score_blocks(
            jnp.asarray(arena), jnp.asarray(idx[q]), jnp.asarray(mask[q])))
        np.testing.assert_array_equal(single, multi[q])


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 33),
       st.integers(1, 20), st.integers(0, 2 ** 31))
def test_property_lookup_multi_equals_oracle(Q, nb, L, W, seed):
    rng = np.random.default_rng(seed)
    arena = rng.integers(0, 2 ** 32, size=(2 * L + 1, W), dtype=np.uint32)
    idx = rng.integers(0, arena.shape[0], size=(Q, nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(Q, nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_multi_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_multi(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 24),
       st.integers(0, 2 ** 31))
def test_property_lookup_blocks_equals_oracle(nb, L, W, seed):
    rng = np.random.default_rng(seed)
    arena = rng.integers(0, 2 ** 32, size=(2 * L + 1, W), dtype=np.uint32)
    idx = rng.integers(0, arena.shape[0], size=(nb, L)).astype(np.int32)
    mask = rng.integers(0, 2, size=(nb, L)).astype(np.int32)
    want = np.asarray(ref.bitslice_lookup_score_blocks_ref(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    got = np.asarray(ops.bitslice_lookup_score_blocks(
        jnp.asarray(arena), jnp.asarray(idx), jnp.asarray(mask)))
    np.testing.assert_array_equal(want, got)
