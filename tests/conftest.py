import os

# Smoke tests and benches must see ONE device; only launch/dryrun.py sets
# xla_force_host_platform_device_count (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    # The image has no hypothesis and nothing may be installed; alias the
    # deterministic stub so property tests still run a seeded sweep.
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data import make_corpus
    return make_corpus(64, k=15, mean_length=400, sigma=1.0, seed=7)


@pytest.fixture(scope="session")
def small_indexes(small_corpus):
    from repro.core import IndexParams, build_classic, build_compact
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    classic = build_classic(small_corpus.doc_terms, params)
    compact = build_compact(small_corpus.doc_terms, params,
                            block_docs=32, row_align=64)
    return classic, compact
