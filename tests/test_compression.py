"""Compressed-arena tests: codec round-trips, store build/open/migrate,
and bit-identity of every compressed score path against the raw kernels.

The load-bearing invariant: compression changes BYTES, never SCORES. A
store built (or migrated) under any codec must open to the exact same
decoded arena, and the fused-decode kernels — engine, server, paged
multi-host worker — must return results bit-identical to the raw paths.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IndexParams, QueryEngine
from repro.core import codec as codec_mod
from repro.core.query import (compile_pattern, coverage_cutoff,
                              pad_term_batch)
from repro.core.store import migrate_store_codec, open_store
from repro.data import make_corpus
from repro.index import build_compact_streaming

PARAMS = IndexParams(n_hashes=1, fpr=0.03, kmer=15)


def _redundant_terms(n_base=24, reps=8, seed=3):
    """A corpus with genuine row-level redundancy: every document is
    repeated ``reps`` times, so whole signature rows recur and the
    rowdict codec has something to find."""
    c = make_corpus(n_base, k=15, mean_length=160, min_length=120,
                    seed=seed)
    return c, [c.doc_terms[i % n_base] for i in range(n_base * reps)]


@pytest.fixture(scope="module")
def stores(tmp_path_factory):
    c, terms = _redundant_terms()
    root = tmp_path_factory.mktemp("comp-stores")
    idx_c, stats = build_compact_streaming(
        terms, root / "comp", PARAMS, block_docs=128, blocks_per_shard=1,
        codec="rowdict")
    idx_raw, _ = build_compact_streaming(
        terms, root / "raw", PARAMS, block_docs=128, blocks_per_shard=1,
        codec="raw")
    return c, root, idx_c, idx_raw, stats


def _patterns(c, n_random=8, seed=0):
    rng = np.random.default_rng(seed)
    pats = ["".join(rng.choice(list("ACGT"), size=60))
            for _ in range(n_random)]
    pats += [c.documents[i][10:90] for i in range(6)]
    return pats


# --------------------------------------------------------------------------
# Codec layer: encode/decode round-trips on arbitrary tiles
# --------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(1, 96), st.integers(1, 9), st.integers(0, 10 ** 6),
       st.sampled_from(codec_mod.CODECS + ("auto",)),
       st.sampled_from(["dense", "sparse", "redundant", "zero"]))
def test_encode_tile_roundtrip(rows, words, seed, codec, shape):
    rng = np.random.default_rng(seed)
    if shape == "dense":
        tile = rng.integers(0, 2 ** 32, size=(rows, words), dtype=np.uint32)
    elif shape == "sparse":
        tile = (rng.random((rows, words)) < 0.05).astype(np.uint32)
    elif shape == "zero":
        tile = np.zeros((rows, words), dtype=np.uint32)
    else:  # redundant: few distinct rows, many refs
        base = rng.integers(0, 2 ** 32, size=(max(1, rows // 8), words),
                            dtype=np.uint32)
        tile = base[rng.integers(0, base.shape[0], size=rows)]
    t = codec_mod.encode_tile(tile, codec)
    assert t.codec in codec_mod.CODECS
    np.testing.assert_array_equal(t.decode(), tile)
    assert t.raw_nbytes == tile.nbytes
    if t.codec != codec_mod.CODEC_RAW:
        # the encoder only keeps a coded form when it actually gains
        assert t.comp_nbytes < t.raw_nbytes
        assert t.ratio > 1.0
    if t.codec in codec_mod.DICT_CODECS:
        d, refs = t.dict_form()
        np.testing.assert_array_equal(d[refs], tile)
        assert refs.dtype == np.int32 and d.dtype == np.uint32


def test_rle_roundtrip_random_planes():
    rng = np.random.default_rng(11)
    for density in (0.0, 0.01, 0.2, 0.9):
        m = (rng.random((64, 8)) < density).astype(np.uint32) * rng.integers(
            1, 2 ** 32, size=(64, 8), dtype=np.uint32)
        np.testing.assert_array_equal(
            codec_mod.rle_decode(codec_mod.rle_encode(m)), m)


# --------------------------------------------------------------------------
# Store: build -> open -> migrate round-trips (hash-checked)
# --------------------------------------------------------------------------

def test_compressed_store_manifest_and_ratio(stores):
    _, root, idx_c, idx_raw, stats = stores
    manifest = json.loads((root / "comp" / "manifest.json").read_text())
    codecs = [s["codec"] for s in manifest["shards"]]
    assert all(c in codec_mod.CODECS for c in codecs)
    assert any(c in codec_mod.DICT_CODECS for c in codecs)
    # acceptance: >= 2x on the redundant corpus, visible in the manifest
    assert manifest["ratio"] >= 2.0
    assert manifest["comp_bytes"] < manifest["raw_bytes"]
    assert idx_c.storage.dict_ratio() >= 2.0
    assert idx_raw.storage.dict_ratio() is None
    # decoded arena identical to the raw store's
    np.testing.assert_array_equal(idx_c.storage.full_host(),
                                  idx_raw.storage.full_host())


def test_migrate_codec_roundtrip(stores):
    _, root, idx_c, idx_raw, _ = stores
    migrate_store_codec(root / "raw", root / "mig-comp", codec="auto")
    migrate_store_codec(root / "mig-comp", root / "mig-raw", codec="raw")
    src = json.loads((root / "raw" / "manifest.json").read_text())
    back = json.loads((root / "mig-raw" / "manifest.json").read_text())
    # hashes cover the DECODED tile: identical through the round trip
    assert ([s["hash"] for s in src["shards"]]
            == [s["hash"] for s in back["shards"]])
    for name in ("mig-comp", "mig-raw"):
        _, storage, _ = open_store(root / name, verify=True)
        np.testing.assert_array_equal(storage.full_host(),
                                      idx_raw.storage.full_host())


# --------------------------------------------------------------------------
# Fused-decode scoring: engine-level bit-identity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["lookup", "vertical"])
def test_engine_compressed_bit_identical(stores, method):
    c, _, idx_c, idx_raw, _ = stores
    raw = QueryEngine(idx_raw, method=method)
    comp = QueryEngine(idx_c, method=method, compressed=True)
    assert comp.compressed
    for pat in _patterns(c):
        a = raw.search(pat, threshold=0.4)
        b = comp.search(pat, threshold=0.4)
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    ta, tb = raw.top_k(c.documents[2][5:85], 7), \
        comp.top_k(c.documents[2][5:85], 7)
    np.testing.assert_array_equal(ta.doc_ids, tb.doc_ids)
    np.testing.assert_array_equal(ta.scores, tb.scores)
    pats = _patterns(c)[:5]
    for a, b in zip(raw.search_batch(pats, threshold=0.4),
                    comp.search_batch(pats, threshold=0.4)):
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    # compressed serving must not have staged any raw tile bytes
    assert comp.tiles.comp_bytes_staged > 0
    assert comp.tiles.raw_bytes_staged == 0


def test_engine_compressed_k2(tmp_path):
    """n_hashes=2: the general gather path (dict[refs[rows]] + AND) and
    the k>1 dedup tuple planner, both against the raw engine."""
    c, terms = _redundant_terms(n_base=16, reps=6, seed=9)
    p2 = IndexParams(n_hashes=2, fpr=0.05, kmer=15)
    idx_c, _ = build_compact_streaming(
        terms, tmp_path / "c2", p2, block_docs=128, blocks_per_shard=1,
        codec="rowdict")
    idx_r, _ = build_compact_streaming(
        terms, tmp_path / "r2", p2, block_docs=128, blocks_per_shard=1,
        codec="raw")
    raw = QueryEngine(idx_r, method="vertical")
    comp = QueryEngine(idx_c, method="vertical", compressed=True)
    assert comp.compressed
    for pat in _patterns(c, n_random=4, seed=5):
        a, b = raw.search(pat, threshold=0.4), comp.search(pat,
                                                           threshold=0.4)
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)


# --------------------------------------------------------------------------
# Serving: QueryServer dispatches, planner flag, metrics accounting
# --------------------------------------------------------------------------

def test_server_compressed_bit_identical_and_metrics(stores):
    from repro.serve.server import QueryServer, ServerConfig
    c, _, idx_c, idx_raw, _ = stores
    pats = _patterns(c)

    def run(index, **kw):
        srv = QueryServer(index, ServerConfig(result_cache=0, row_cache=0,
                                              **kw))
        rids = [srv.submit(p, threshold=0.4) for p in pats]
        srv.drain()
        return srv, srv.pop_responses(), rids

    srv_r, resp_r, rids_r = run(idx_raw)
    srv_c, resp_c, rids_c = run(idx_c, compressed=True)
    for rr, rc in zip(rids_r, rids_c):
        a, b = resp_r[rr].result, resp_c[rc].result
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    # planner: dict ratio clears the heuristic bar -> compressed plans
    assert srv_c.planner.compressed_enabled
    assert srv_c.planner.plan(64, 8).compressed
    assert not srv_r.planner.plan(64, 8).compressed
    # metrics: every staged byte was compressed-form, and it shows in
    # both the snapshot and the Prometheus exposition
    snap = srv_c.metrics.snapshot()
    assert snap.arena_comp_bytes > 0 and snap.arena_raw_bytes == 0
    from repro.obs import render_prometheus
    text = render_prometheus(srv_c.metrics.registry)
    assert 'serve_arena_bytes_total{form="comp"}' in text
    assert "serve_decode_seconds" in text


def test_server_compressed_flag_inert_on_raw_store(stores):
    from repro.serve.server import QueryServer, ServerConfig
    c, _, _, idx_raw, _ = stores
    srv = QueryServer(idx_raw, ServerConfig(result_cache=0, row_cache=0,
                                            compressed=True))
    assert not srv.planner.compressed_enabled
    rid = srv.submit(_patterns(c)[0], threshold=0.4)
    srv.drain()
    resp = srv.pop_responses()[rid]
    assert resp.result is not None
    assert srv.metrics.snapshot().arena_comp_bytes == 0


# --------------------------------------------------------------------------
# Paged multi-host: ShardWorker candidates under compressed dispatch
# --------------------------------------------------------------------------

def test_worker_compressed_candidates_identical(stores):
    from repro.serve.worker import ShardWorker
    c, root, idx_c, _, _ = stores
    ids = list(range(idx_c.storage.n_shards))
    w_raw = ShardWorker("w-raw", root / "comp", ids)
    w_c = ShardWorker("w-comp", root / "comp", ids, compressed=True)
    term_sets = [compile_pattern(p, PARAMS) for p in _patterns(c)[:6]]
    buf, ells = pad_term_batch(term_sets, 64)
    cuts = np.array([coverage_cutoff(0.4, int(e)) for e in ells], np.int32)
    topks = np.zeros(len(ells), np.int32)
    topks[3] = 5                      # mix selection modes in one batch
    td_r, nd_r = w_raw.stage_batch(buf, ells)
    td_c, nd_c = w_c.stage_batch(buf, ells)
    for g in ids:
        assert w_c.prefetch_shard(g)
        cand_r, m_r = w_raw.score_candidates(g, td_r, nd_r, cuts, topks,
                                             len(ells))
        cand_c, m_c = w_c.score_candidates(g, td_c, nd_c, cuts, topks,
                                           len(ells))
        assert m_r == m_c             # dispatch-mix comparability
        for (d0, s0), (d1, s1) in zip(cand_r, cand_c):
            np.testing.assert_array_equal(d0, d1)
            np.testing.assert_array_equal(s0, s1)
    assert w_c.compressed_dispatches == len(ids)
    assert w_c.tiles.comp_bytes_staged > 0
    assert w_c.tiles.raw_bytes_staged == 0
    assert w_raw.compressed_dispatches == 0


# --------------------------------------------------------------------------
# Autotuner: the lookup_c cost model
# --------------------------------------------------------------------------

def test_tuner_lookup_c_entries(stores):
    from repro.kernels.autotune import KernelTuner, TuningCache
    _, _, idx_c, idx_raw, _ = stores
    tuner = KernelTuner.for_index(idx_c, TuningCache(), enabled=True,
                                  repeats=1, word_blocks=(64,),
                                  grid_orders=("wq",))
    assert tuner.comp_ratio is not None and tuner.comp_ratio >= 2.0
    e = tuner.entry("lookup_c", 64, 4)
    assert e is not None and e.cost_us > 0
    assert f".cr{tuner.comp_ratio:.2f}" in tuner.key("lookup_c", 64, 4)
    # dedup break-even exists for the compressed path too
    assert e.dedup_threshold is not None
    # raw store: no ratio, lookup_c untunable
    raw_tuner = KernelTuner.for_index(idx_raw, TuningCache(), enabled=True)
    assert raw_tuner.comp_ratio is None
    assert raw_tuner.entry("lookup_c", 64, 4) is None
