from repro.index import HedgedExecutor, ShardSim, SimClock


def _mk(n=4, base=1.0, hedge_after=2.0, max_hedges=1):
    shards = {f"s{i}": ShardSim(f"s{i}", base_latency=base) for i in range(n)}
    return HedgedExecutor(shards=shards, hedge_after=hedge_after,
                          max_hedges=max_hedges)


def test_fast_path_no_hedge():
    ex = _mk()
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s0" and lat == 1.0
    assert ex.hedged_fraction() == 0.0


def test_straggler_triggers_hedge():
    ex = _mk(hedge_after=2.0)
    ex.shards["s0"].straggle_until = 1e9   # s0 stuck at 10x latency
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1"                    # backup wins
    assert lat == 2.0 + 1.0                 # hedge deadline + backup latency
    assert ex.hedged_fraction() == 1.0


def test_hedge_not_needed_when_straggle_mild():
    ex = _mk(hedge_after=5.0)
    ex.shards["s0"].straggle_until = 1e9
    ex.shards["s0"].straggle_factor = 3.0   # 3.0 < hedge_after
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s0" and lat == 3.0


def test_failover_on_dead_primary():
    ex = _mk()
    ex.shards["s0"].failed = True
    shard, _ = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1"


def test_all_dead_raises():
    ex = _mk()
    for s in ex.shards.values():
        s.failed = True
    try:
        ex.run_query(0, ["s0", "s1"])
        assert False
    except RuntimeError:
        pass


def test_tail_latency_improvement():
    """p99 with hedging stays bounded under 10% stragglers (the Tail-at-
    Scale effect the policy exists for)."""
    import random
    rng = random.Random(0)
    ex = _mk(n=8, hedge_after=2.0)
    for q in range(200):
        for s in ex.shards.values():
            s.straggle_until = -1.0
        if rng.random() < 0.10:  # straggling primary
            ex.shards["s0"].straggle_until = ex.clock.now + 100.0
        ex.run_query(q, ["s0", "s1", "s2"])
    assert ex.percentile(0.99) <= 3.0      # hedge bound, not 10.0
    assert ex.percentile(0.50) == 1.0


def test_clock_monotone():
    ex = _mk()
    t0 = ex.clock.now
    ex.run_query(0, ["s0"])
    assert ex.clock.now >= t0


# -- hedge budget walk (wrap-around regression) -------------------------------

def test_second_hedge_lands_on_third_replica():
    """Regression: with 3 replicas and a straggling primary, hedge budget
    2 must walk DISTINCT untried replicas — the old modulo indexing could
    wrap the walk back onto an already-issued attempt, burning the budget
    on a duplicate of the straggler instead of reaching replica 3."""
    ex = _mk(hedge_after=2.0, max_hedges=2)
    ex.shards["s0"].straggle_until = 1e9   # primary stuck at 10x
    ex.shards["s1"].straggle_until = 1e9   # first backup stuck too
    shard, lat = ex.run_query(0, ["s0", "s1", "s2"])
    assert shard == "s2"                   # second hedge, third replica
    assert ex.hedges_fired == 2
    assert ex.hedges_won == 1
    # hedge 1 at t=2 (s1), hedge 2 at t=4 (s2) + 1.0 base latency
    assert lat == 4.0 + 1.0


def test_hedge_budget_never_reissues_with_two_live():
    """With only 2 live replicas and budget 2, the walk exhausts after
    one backup: no wrap back onto the primary, and the single effective
    hedge still wins."""
    ex = _mk(n=2, hedge_after=2.0, max_hedges=2)
    ex.shards["s0"].straggle_until = 1e9
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1" and lat == 3.0
    assert ex.hedges_fired == 1            # budget wasn't burned twice


# -- failover vs skipped_dead split -------------------------------------------

def test_known_dead_primary_counts_skip_not_failover():
    """A replica already known dead (failed latency model) is filtered
    before dispatch: it must count as skipped_dead, NOT inflate the
    failover rate (the old counter lumped both together)."""
    ex = _mk()
    ex.shards["s0"].failed = True
    shard, _ = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1"
    assert ex.failovers == 0
    assert ex.skipped_dead == 1


def test_at_call_time_death_counts_failover():
    from repro.index.hedge import AttemptFailed

    ex = _mk()

    def call(node):
        if node == "s0":
            raise AttemptFailed(node)      # dies under the attempt
        return f"res-{node}"

    node, _, res = ex.run(0, ["s0", "s1"], call)
    assert node == "s1" and res == "res-s1"
    assert ex.failovers == 1
    assert ex.skipped_dead == 0


# -- run_async: wall-clock hedging over futures -------------------------------

def _resolved(value):
    from concurrent.futures import Future
    f = Future()
    f.set_result(value)
    return f


def test_run_async_primary_wins():
    ex = _mk(n=0)
    issued = []

    def begin(node):
        issued.append(node)
        return _resolved(f"res-{node}")

    node, lat, res = ex.run_async(0, ["a", "b"], begin)
    assert node == "a" and res == "res-a"
    assert issued == ["a"]                 # backup never launched
    assert ex.hedges_fired == 0 and ex.hedges_cancelled == 0


def test_run_async_hedge_fires_and_cancels_loser():
    """A dawdling primary future triggers a REAL duplicate request after
    hedge_after; the backup wins and the primary is cancelled through the
    cancel callback."""
    from concurrent.futures import Future

    ex = _mk(n=0, hedge_after=0.02, max_hedges=1)
    primary = Future()                     # never resolves: the straggler
    cancelled = []

    def begin(node):
        return primary if node == "a" else _resolved(f"res-{node}")

    node, lat, res = ex.run_async(0, ["a", "b"], begin,
                                  cancel=lambda n, f: cancelled.append(n))
    assert node == "b" and res == "res-b"
    assert ex.hedges_fired == 1 and ex.hedges_won == 1
    assert ex.hedges_cancelled == 1
    assert cancelled == ["a"]
    assert lat >= 0.02                     # waited out the hedge deadline


def test_run_async_failover_on_refused_begin():
    from repro.index.hedge import AttemptFailed

    ex = _mk(n=0)

    def begin(node):
        if node == "a":
            raise AttemptFailed(node)      # channel down at submit time
        return _resolved(f"res-{node}")

    node, _, res = ex.run_async(0, ["a", "b"], begin)
    assert node == "b" and res == "res-b"
    assert ex.failovers == 1 and ex.skipped_dead == 0


def test_run_async_failover_on_failed_future():
    from concurrent.futures import Future

    from repro.index.hedge import AttemptFailed

    ex = _mk(n=0)
    dead = Future()
    dead.set_exception(AttemptFailed("a"))

    def begin(node):
        return dead if node == "a" else _resolved(f"res-{node}")

    node, _, res = ex.run_async(0, ["a", "b"], begin)
    assert node == "b" and res == "res-b"
    assert ex.failovers == 1


def test_run_async_all_failed_raises():
    from repro.index.hedge import AllReplicasFailed, AttemptFailed

    ex = _mk(n=0)

    def begin(node):
        raise AttemptFailed(node)

    try:
        ex.run_async(0, ["a", "b"], begin)
        assert False
    except AllReplicasFailed:
        pass
    assert ex.failovers == 2


def test_run_async_skips_known_dead():
    ex = _mk(n=2)
    ex.shards["s0"].failed = True
    node, _, res = ex.run_async(0, ["s0", "s1"],
                                lambda n: _resolved(f"res-{n}"))
    assert node == "s1"
    assert ex.skipped_dead == 1 and ex.failovers == 0


def test_run_async_non_attempt_error_propagates():
    """A future failing with anything but AttemptFailed is the caller's
    bug domain — it must propagate, not silently fail over."""
    from concurrent.futures import Future

    ex = _mk(n=0)
    broken = Future()
    broken.set_exception(ValueError("kernel crash"))

    def begin(node):
        return broken if node == "a" else _resolved(f"res-{node}")

    try:
        ex.run_async(0, ["a", "b"], begin)
        assert False
    except ValueError:
        pass
