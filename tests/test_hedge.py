from repro.index import HedgedExecutor, ShardSim, SimClock


def _mk(n=4, base=1.0, hedge_after=2.0, max_hedges=1):
    shards = {f"s{i}": ShardSim(f"s{i}", base_latency=base) for i in range(n)}
    return HedgedExecutor(shards=shards, hedge_after=hedge_after,
                          max_hedges=max_hedges)


def test_fast_path_no_hedge():
    ex = _mk()
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s0" and lat == 1.0
    assert ex.hedged_fraction() == 0.0


def test_straggler_triggers_hedge():
    ex = _mk(hedge_after=2.0)
    ex.shards["s0"].straggle_until = 1e9   # s0 stuck at 10x latency
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1"                    # backup wins
    assert lat == 2.0 + 1.0                 # hedge deadline + backup latency
    assert ex.hedged_fraction() == 1.0


def test_hedge_not_needed_when_straggle_mild():
    ex = _mk(hedge_after=5.0)
    ex.shards["s0"].straggle_until = 1e9
    ex.shards["s0"].straggle_factor = 3.0   # 3.0 < hedge_after
    shard, lat = ex.run_query(0, ["s0", "s1"])
    assert shard == "s0" and lat == 3.0


def test_failover_on_dead_primary():
    ex = _mk()
    ex.shards["s0"].failed = True
    shard, _ = ex.run_query(0, ["s0", "s1"])
    assert shard == "s1"


def test_all_dead_raises():
    ex = _mk()
    for s in ex.shards.values():
        s.failed = True
    try:
        ex.run_query(0, ["s0", "s1"])
        assert False
    except RuntimeError:
        pass


def test_tail_latency_improvement():
    """p99 with hedging stays bounded under 10% stragglers (the Tail-at-
    Scale effect the policy exists for)."""
    import random
    rng = random.Random(0)
    ex = _mk(n=8, hedge_after=2.0)
    for q in range(200):
        for s in ex.shards.values():
            s.straggle_until = -1.0
        if rng.random() < 0.10:  # straggling primary
            ex.shards["s0"].straggle_until = ex.clock.now + 100.0
        ex.run_query(q, ["s0", "s1", "s2"])
    assert ex.percentile(0.99) <= 3.0      # hedge bound, not 10.0
    assert ex.percentile(0.50) == 1.0


def test_clock_monotone():
    ex = _mk()
    t0 = ex.clock.now
    ex.run_query(0, ["s0"])
    assert ex.clock.now >= t0
