"""Runs the multi-DEVICE sharded-serving checks in a subprocess (the rest
of the suite must see exactly ONE device, so the 4-device run is isolated
— same mechanism as test_distributed.py)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

_SCRIPT = Path(__file__).parent / "multihost_check.py"
_SRC = str(Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_multihost_frontend_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(_SCRIPT)], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-MULTIHOST-OK" in proc.stdout
