"""Minimal stand-in for the `hypothesis` API used by this test suite.

The container image does not ship hypothesis and nothing may be pip
installed, so conftest.py aliases this module into ``sys.modules`` when the
real package is missing. It implements exactly the surface the tests use —
``@given`` with ``st.integers`` / ``st.sampled_from`` and ``@settings(
max_examples=..., deadline=...)`` — as a deterministic seeded sweep: every
test still runs ``max_examples`` distinct drawn inputs, it just loses
hypothesis's shrinking and example database. With the real package
installed, conftest leaves it alone and this file is inert.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


class strategies:
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # Drawn params fill the TRAILING positions (real hypothesis
        # semantics for positional @given); only the leading ones are
        # pytest fixtures. Pytest passes fixtures by KEYWORD, so drawn
        # values must also go by name or they collide with fixture
        # kwargs at the leading positions.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        fixture_params = params[:-len(strats)] if strats else params
        drawn_names = [p.name for p in params[len(fixture_params):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            # Deterministic per-test seed so failures reproduce exactly
            # (crc32, not hash(): str hashing is salted per process).
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strats)}
                fn(*args, **drawn, **kwargs)

        # Hide the drawn params from pytest's collector.
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        del wrapper.__wrapped__
        return wrapper
    return deco
