"""Blockwise (flash-style) attention vs direct attention: exact-equality
sweeps over causal/window/GQA/padding regimes, incl. the sliding-window
block-skipping path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers
from repro.models.config import ModelConfig

CFG = ModelConfig(name="t", n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=64, vocab=128)


def _qkv(B, S, T, H=4, n_kv=2, hd=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, n_kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, n_kv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("S,window,bq,bk", [
    (300, None, 64, 96),     # causal, unaligned blocks
    (300, 64, 64, 96),       # window without skipping (nw >= nk)
    (700, 48, 64, 96),       # window WITH block skipping
    (257, 100, 32, 64),      # prime-ish sizes -> padding paths
])
def test_chunked_equals_direct_causal(S, window, bq, bk):
    q, k, v = _qkv(2, S, S, seed=S)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S)).astype(jnp.int32)
    want = layers.attention(q, k, v, CFG,
                            mask=layers.causal_mask(pos, pos, window))
    got = layers.chunked_attention(q, k, v, CFG, positions_q=pos,
                                   positions_kv=pos, causal=True,
                                   window=window, bq=bq, bk=bk)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_chunked_bidirectional_cross():
    """Encoder/cross attention: q and kv lengths differ, no causality."""
    q, k, v = _qkv(2, 150, 400, seed=7)
    pq = jnp.broadcast_to(jnp.arange(150)[None], (2, 150)).astype(jnp.int32)
    pk = jnp.broadcast_to(jnp.arange(400)[None], (2, 400)).astype(jnp.int32)
    want = layers.attention(q, k, v, CFG, mask=None)
    got = layers.chunked_attention(q, k, v, CFG, positions_q=pq,
                                   positions_kv=pk, causal=False,
                                   window=None, bq=64, bk=96)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_chunked_gradients_flow():
    q, k, v = _qkv(1, 130, 130, seed=3)
    pos = jnp.broadcast_to(jnp.arange(130)[None], (1, 130)).astype(jnp.int32)

    def f(q):
        return layers.chunked_attention(
            q, k, v, CFG, positions_q=pos, positions_kv=pos,
            causal=True, window=32, bq=32, bk=64).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()
    # padded-query guard must not produce NaNs anywhere
    assert bool(jnp.isfinite(f(q)))


def test_mqa_group_expansion():
    """n_kv = 1 (MQA, recurrentgemma): group expansion factor H."""
    q, k, v = _qkv(2, 200, 200, H=4, n_kv=1, seed=9)
    pos = jnp.broadcast_to(jnp.arange(200)[None], (2, 200)).astype(jnp.int32)
    want = layers.attention(q, k, v, CFG,
                            mask=layers.causal_mask(pos, pos, None))
    got = layers.chunked_attention(q, k, v, CFG, positions_q=pos,
                                   positions_kv=pos, causal=True,
                                   window=None, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
