import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              latest_step, load_pytree, save_pytree)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
            "nested": {"b": jnp.arange(5), "c": jnp.asarray(3.0)},
            "list": [jnp.ones((2, 2)), jnp.zeros((3,))]}


def _assert_tree_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "ck")
    t2 = load_pytree(t, tmp_path / "ck")
    _assert_tree_equal(t, t2)


def test_corruption_detected(tmp_path):
    import json
    t = _tree()
    save_pytree(t, tmp_path / "ck")
    man = json.loads((tmp_path / "ck" / "manifest.json").read_text())
    man["leaves"][0]["hash"] = "0" * 32
    (tmp_path / "ck" / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(IOError):
        load_pytree(t, tmp_path / "ck")


def test_shape_mismatch_detected(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "ck")
    bad = dict(t)
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        load_pytree(bad, tmp_path / "ck")


def test_atomic_no_partial_state(tmp_path):
    """A leftover .tmp dir (simulated crash) must not shadow a good save."""
    t = _tree()
    mgr = CheckpointManager(tmp_path, keep_last=2)
    mgr.save(0, t)
    (tmp_path / "step_1.tmp").mkdir()          # crashed writer
    assert latest_step(tmp_path) == 0
    restored, step = mgr.restore(t)
    assert step == 0
    _assert_tree_equal(t, restored)


def test_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in range(5):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    restored, step = mgr.restore(_tree())
    assert step == 4
    _assert_tree_equal(_tree(4), restored)


def test_async_checkpointer(tmp_path):
    mgr = CheckpointManager(tmp_path)
    ac = AsyncCheckpointer(mgr)
    t = _tree(1)
    ac.save(7, t)
    ac.wait()
    restored, step = mgr.restore(t)
    assert step == 7
    _assert_tree_equal(t, restored)


def test_async_snapshot_isolated_from_mutation(tmp_path):
    """The async writer must persist the state AT save() time even if the
    caller immediately mutates buffers (donated-buffer hazard)."""
    mgr = CheckpointManager(tmp_path)
    ac = AsyncCheckpointer(mgr)
    arr = np.ones((1000, 100), np.float32)
    tree = {"w": arr}
    ac.save(0, tree)
    arr *= 0.0                                  # mutate after save
    ac.wait()
    restored, _ = mgr.restore({"w": np.zeros_like(arr)})
    assert restored["w"].mean() == 1.0
