"""Fault-tolerance: checkpoint/restart reproduces the uninterrupted run
bit-for-bit; elastic re-splitting keeps global-batch coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.ft import ElasticBatchPlan, FailureInjector, run_with_restarts
from repro.models import build_model
from repro.train import AdamWConfig, make_init_state, make_train_step


@pytest.fixture(scope="module")
def tiny_training():
    cfg = configs.get("qwen3-4b", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    init = jax.jit(make_init_state(model, opt))
    step = jax.jit(make_train_step(model, opt))
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.integers(0, cfg.vocab, (64, 2, 12)), jnp.int32)

    def init_state():
        return init(jax.random.PRNGKey(0))

    def step_fn(state, i):
        batch = {"tokens": data[i % 64], "labels": data[i % 64]}
        state, m = step(state, batch)
        return state, {"loss": float(m["loss"])}

    return init_state, step_fn


def test_restart_reproduces_loss_trajectory(tmp_path, tiny_training):
    init_state, step_fn = tiny_training

    mgr_a = CheckpointManager(tmp_path / "a")
    _, log_a, restarts_a = run_with_restarts(
        init_state, step_fn, mgr_a, total_steps=12, checkpoint_every=4)
    assert restarts_a == 0

    mgr_b = CheckpointManager(tmp_path / "b")
    inj = FailureInjector(fail_at={5, 9})
    state_b, log_b, restarts_b = run_with_restarts(
        init_state, step_fn, mgr_b, total_steps=12, checkpoint_every=4,
        injector=inj)
    assert restarts_b == 2

    # the CHECKPOINT-VISIBLE trajectory must match the clean run exactly
    clean = {m["step"]: m["loss"] for m in log_a}
    crashed = {}
    for m in log_b:            # later entries (post-restart) overwrite
        crashed[m["step"]] = m["loss"]
    assert set(crashed) == set(clean)
    for s in clean:
        assert clean[s] == crashed[s], f"divergence at step {s}"


def test_restart_resumes_not_restarts(tmp_path, tiny_training):
    """After a crash at step 5 with checkpoint_every=4, the rerun must
    begin at step 4, not step 0."""
    init_state, step_fn = tiny_training
    mgr = CheckpointManager(tmp_path / "c")
    inj = FailureInjector(fail_at={5})
    _, log, _ = run_with_restarts(init_state, step_fn, mgr, total_steps=8,
                                  checkpoint_every=4, injector=inj)
    steps = [m["step"] for m in log]
    assert steps.count(0) == 1          # step 0 executed exactly once
    assert steps.count(4) == 2          # step 4 replayed after restore


def test_injector_exhausts_restarts(tmp_path, tiny_training):
    init_state, step_fn = tiny_training
    mgr = CheckpointManager(tmp_path / "d")
    inj = FailureInjector(fail_at={1})
    # fail_at fires once; with max_restarts=0 the supervisor re-raises
    with pytest.raises(RuntimeError):
        run_with_restarts(init_state, step_fn, mgr, total_steps=4,
                          checkpoint_every=2, injector=inj, max_restarts=0)


@pytest.mark.parametrize("world", [1, 3, 8, 24, 32])
def test_elastic_plan_coverage(world):
    plan = ElasticBatchPlan(global_batch=256, world_size=world)
    assert plan.coverage_ok(step=0)
    assert plan.coverage_ok(step=17)


def test_elastic_resize_preserves_global_batch():
    """Scaling 32 -> 24 replicas mid-run: same global examples per step."""
    a = ElasticBatchPlan(256, 32)
    b = ElasticBatchPlan(256, 24)
    step = 5
    ga = sorted(i for r in range(32) for i in a.indices_for(r, step) if i >= 0)
    gb = sorted(i for r in range(24) for i in b.indices_for(r, step) if i >= 0)
    assert ga == gb


def test_elastic_bad_replica():
    plan = ElasticBatchPlan(64, 8)
    with pytest.raises(ValueError):
        plan.indices_for(8, 0)
