"""Pruned-scoring tests: branch-and-bound early exit must be LOSSLESS.

The load-bearing invariant mirrors the compression suite's: pruning
changes BYTES (and kernel work), never SCORES. Every pruned path —
engine threshold search, engine top-k, compressed stores, the
QueryServer batch branch, the paged multi-host worker — must return
results bit-identical to the exhaustive oracle, while the PruneStats
accounting proves tiles were actually skipped (a pruned shard performs
ZERO tile-cache faults: nothing staged, nothing promoted).

Satellites covered here too: ratio-aware tile eviction (raw victims
before dict-coded), per-slice popcount sidecars in the v2 manifest
surviving codec migration, per-worker local dispatch-shape padding, and
the planner's break-even gating.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IndexParams, QueryEngine
from repro.core.arena import DeviceTileCache
from repro.core.query import (PruneStats, compile_pattern, coverage_cutoff,
                              pad_term_batch)
from repro.core.store import migrate_store_codec, open_store
from repro.data import make_corpus
from repro.index import build_compact_streaming

PARAMS = IndexParams(n_hashes=1, fpr=0.03, kmer=15)


def _redundant_terms(n_base=24, reps=6, seed=3):
    c = make_corpus(n_base, k=15, mean_length=160, min_length=120,
                    seed=seed)
    return c, [c.doc_terms[i % n_base] for i in range(n_base * reps)]


@pytest.fixture(scope="module")
def stores(tmp_path_factory):
    """paged raw, paged rowdict, and dense (single-shard) stores over the
    same corpus — the three executor regimes the pruned path must match."""
    c, terms = _redundant_terms()
    root = tmp_path_factory.mktemp("prune-stores")
    idx_raw, _ = build_compact_streaming(
        terms, root / "raw", PARAMS, block_docs=32, blocks_per_shard=1,
        codec="raw")
    idx_c, _ = build_compact_streaming(
        terms, root / "comp", PARAMS, block_docs=32, blocks_per_shard=1,
        codec="rowdict")
    idx_dense, _ = build_compact_streaming(
        terms, root / "dense", PARAMS, block_docs=32, blocks_per_shard=64,
        codec="raw")
    assert idx_raw.storage.n_shards > 2
    assert idx_dense.storage.n_shards == 1
    return c, root, idx_raw, idx_c, idx_dense


def _patterns(c, n_random=4, seed=0):
    rng = np.random.default_rng(seed)
    pats = ["".join(rng.choice(list("ACGT"), size=70))
            for _ in range(n_random)]
    pats += [c.documents[i][10:100] for i in range(4)]
    return pats


# --------------------------------------------------------------------------
# Engine: pruned == oracle (property over threshold x store x chunk)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([0.3, 0.5, 0.9, 1.0]),
       st.sampled_from(["raw", "comp", "dense"]),
       st.sampled_from([8, 32]),
       st.integers(0, 10 ** 6))
def test_pruned_matches_oracle(stores, threshold, kind, chunk, seed):
    c, _, idx_raw, idx_c, idx_dense = stores
    idx = {"raw": idx_raw, "comp": idx_c, "dense": idx_dense}[kind]
    oracle = QueryEngine(idx, method="lookup", compressed=(kind == "comp"))
    eng = QueryEngine(idx, method="lookup", compressed=(kind == "comp"),
                      prune_chunk=chunk)
    pats = _patterns(c, seed=seed)
    stats = PruneStats()
    got = eng.search_batch_pruned(pats, threshold=threshold, stats=stats)
    want = oracle.search_batch(pats, threshold=threshold)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    assert stats.blocks_total > 0


def test_pruned_top_k_matches_oracle(stores):
    c, _, idx_raw, idx_c, _ = stores
    for idx, comp in ((idx_raw, False), (idx_c, True)):
        oracle = QueryEngine(idx, method="lookup", compressed=comp)
        eng = QueryEngine(idx, method="lookup", compressed=comp,
                          prune_chunk=16)
        for k in (1, 5, 64):
            for pat in _patterns(c)[:4]:
                a = eng.top_k_pruned(pat, k=k)
                b = oracle.top_k(pat, k=k)
                np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
                np.testing.assert_array_equal(a.scores, b.scores)


def test_k2_pruned_matches_oracle(tmp_path):
    """n_hashes=2: the AND-of-hashes chunk kernel through the pruned
    executor."""
    c, terms = _redundant_terms(n_base=16, reps=4, seed=9)
    p2 = IndexParams(n_hashes=2, fpr=0.05, kmer=15)
    idx, _ = build_compact_streaming(
        terms, tmp_path / "k2", p2, block_docs=32, blocks_per_shard=1)
    oracle = QueryEngine(idx, method="vertical")
    eng = QueryEngine(idx, method="vertical", prune_chunk=16)
    pats = _patterns(c)[:5]
    for thr in (0.5, 1.0):
        for a, b in zip(eng.search_batch_pruned(pats, threshold=thr),
                        oracle.search_batch(pats, threshold=thr)):
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(a.scores, b.scores)


# --------------------------------------------------------------------------
# The point of pruning: skipped I/O, and ZERO tile fetches when pruned
# --------------------------------------------------------------------------

def test_all_blocks_pruned_negative_query(stores):
    """A pure-negative query at threshold 1.0 must kill every block
    after the first chunk and never stage a single tile."""
    c, _, idx_raw, _, _ = stores
    eng = QueryEngine(idx_raw, method="lookup", prune_chunk=8)
    rng = np.random.default_rng(42)
    neg = "".join(rng.choice(list("ACGT"), size=90))
    stats = PruneStats()
    res = eng.search_batch_pruned([neg], threshold=1.0, stats=stats)[0]
    assert res.doc_ids.size == 0
    assert stats.blocks_pruned > 0
    assert stats.prune_rate > 0.5
    # zero-tile-fetch regression: the pruned run gathers rows host-side
    # only — no demand staging, no prefetch, no promotion
    assert eng.tiles.faults == 0
    assert stats.tiles_promoted == 0
    assert stats.bytes_read < sum(
        int(idx_raw.storage.shard_hbm_nbytes(s))
        for s in range(idx_raw.storage.n_shards))


def test_pruned_reads_fewer_bytes_at_high_threshold(stores):
    c, _, idx_raw, _, _ = stores
    base = sum(int(idx_raw.storage.shard_hbm_nbytes(s))
               for s in range(idx_raw.storage.n_shards))
    eng = QueryEngine(idx_raw, method="lookup", prune_chunk=16)
    stats = PruneStats()
    eng.search_batch_pruned(_patterns(c), threshold=0.9, stats=stats)
    assert stats.bytes_read * 3 <= base          # the >=3x acceptance bar
    assert stats.shard_visits_skipped > 0 or stats.blocks_pruned > 0


# --------------------------------------------------------------------------
# Satellite: ratio-aware tile eviction (raw victims before dict-coded)
# --------------------------------------------------------------------------

def test_ratio_aware_eviction_prefers_raw_victims(tmp_path):
    # wide blocks so rowdict actually finds repeated rows (the pruning
    # stores' 32-doc blocks are too narrow to code)
    _, terms = _redundant_terms(n_base=24, reps=8, seed=3)
    idx_c, _ = build_compact_streaming(
        terms, tmp_path / "evict", PARAMS, block_docs=128,
        blocks_per_shard=1, codec="rowdict")
    storage = idx_c.storage
    dict_shards = [s for s in range(storage.n_shards)
                   if storage.shard_dict_host(s) is not None]
    assert dict_shards
    # smallest dict-coded shard vs the tallest other shard, so one
    # eviction always re-fits the cache (raw(d) < raw(other))
    d = min(dict_shards, key=storage.shard_nbytes)
    other = max((s for s in range(storage.n_shards) if s != d),
                key=storage.shard_nbytes)
    cache = DeviceTileCache(storage)
    cache.get_compressed(d)           # dict entry staged first: LRU head
    # capacity for the dict entry plus exactly one raw tile — staging a
    # second raw tile must evict, and plain LRU would kill the dict
    cache.capacity_bytes = (cache.resident_bytes
                            + cache._tile_nbytes(other) + 64)
    cache.get(other)
    assert not cache.shard_evictions  # both fit
    cache.get(d)                      # raw form of d: independent entry
    # ratio-aware victim selection: the raw tile of ``other`` was
    # evicted; the dict entry outlived it despite being least recently
    # used
    assert cache.shard_evictions == {other: 1}
    assert cache.has_compressed(d)
    assert any(isinstance(k, tuple) for k in cache._tiles)
    assert other not in cache.resident_shards


# --------------------------------------------------------------------------
# Satellite: per-slice popcount sidecars + migration round-trip
# --------------------------------------------------------------------------

def test_popcount_sidecar_values(stores):
    _, _, idx_raw, _, _ = stores
    storage = idx_raw.storage
    assert storage.has_popcounts()
    for s in range(storage.n_shards):
        tile = np.asarray(storage.shard_host(s), dtype=np.uint32)
        want = np.unpackbits(tile.view(np.uint8), axis=1).sum(
            axis=1).astype(np.uint32)
        np.testing.assert_array_equal(storage.shard_popcounts(s), want)
    assert 0.0 < storage.mean_popcount() <= 32 * storage.shape[1]


def test_popcounts_survive_codec_migration(stores, tmp_path):
    _, root, idx_raw, _, _ = stores
    migrate_store_codec(root / "raw", tmp_path / "mig-c", codec="auto")
    migrate_store_codec(tmp_path / "mig-c", tmp_path / "mig-r",
                        codec="raw")
    for name in ("mig-c", "mig-r"):
        _, storage, _ = open_store(tmp_path / name, verify=True)
        assert storage.has_popcounts()
        for s in range(storage.n_shards):
            np.testing.assert_array_equal(
                storage.shard_popcounts(s),
                idx_raw.storage.shard_popcounts(s))
        assert storage.mean_popcount() == idx_raw.storage.mean_popcount()


# --------------------------------------------------------------------------
# Planner: break-even gating (pruned only when predicted to win)
# --------------------------------------------------------------------------

def test_planner_prune_gating(stores):
    from repro.serve.planner import QueryPlanner, predict_prune_rate
    _, _, idx_raw, _, _ = stores
    pl = QueryPlanner(idx_raw, pruned=True, prune_chunk=16,
                      prune_min_rate=0.3)
    # selective coverage clears the break-even -> pruned plan
    p = pl.plan(64, 4, threshold=0.95)
    assert p.pruned and p.chunk_terms == 16 and p.predicted_prune > 0.3
    # no coverage hint (all-top-k batch): static prediction impossible
    assert not pl.plan(64, 4).pruned
    # coverage at/below the slice density: nothing can be pruned
    assert not pl.plan(64, 4, threshold=0.01).pruned
    # bucket no larger than one chunk: nothing to exit early from
    assert not pl.plan(16, 4, threshold=0.95).pruned
    # a break-even the predictor can never clear -> never pruned
    pl2 = QueryPlanner(idx_raw, pruned=True, prune_chunk=16,
                       prune_min_rate=2.0)
    assert not pl2.plan(64, 4, threshold=1.0).pruned
    # disabled planner never prunes
    pl3 = QueryPlanner(idx_raw, pruned=False)
    assert not pl3.plan(64, 4, threshold=0.95).pruned
    # the predictor itself: monotone in threshold, 0 below density
    d = 0.2
    assert predict_prune_rate(0.1, d) == 0.0
    assert predict_prune_rate(0.9, d) > predict_prune_rate(0.5, d)
    assert predict_prune_rate(1.0, d) == 1.0


def test_tuner_lookup_p_entry(stores):
    from repro.kernels.autotune import KernelTuner, TuningCache
    _, _, idx_raw, _, _ = stores
    tuner = KernelTuner.for_index(idx_raw, TuningCache(), enabled=True,
                                  repeats=1, word_blocks=(64,),
                                  grid_orders=("wq",))
    e = tuner.entry("lookup_p", 64, 4)
    assert e is not None and e.method == "lookup_p"
    assert e.term_block and e.term_block >= 1          # chunk size
    assert 0.0 <= e.dedup_threshold <= 2.0             # prune break-even


# --------------------------------------------------------------------------
# Serving: QueryServer pruned branch, mixed batches, metrics
# --------------------------------------------------------------------------

def test_server_pruned_bit_identical_and_metrics(stores):
    from repro.serve.server import QueryServer, ServerConfig
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw, method="lookup")
    srv = QueryServer(idx_raw, ServerConfig(
        pruned=True, prune_chunk=16, prune_min_rate=0.05,
        result_cache=0, row_cache=0, max_wait_s=0.0))
    pats = _patterns(c)
    rids = [srv.submit(p, threshold=0.9) for p in pats]
    srv.drain()
    got = srv.pop_responses()
    methods = {got[r].method for r in rids}
    assert "lookup_p" in methods
    for rid, p in zip(rids, pats):
        want = engine.search(p, threshold=0.9)
        np.testing.assert_array_equal(got[rid].result.doc_ids,
                                      want.doc_ids)
        np.testing.assert_array_equal(got[rid].result.scores,
                                      want.scores)
    snap = srv.metrics.snapshot()
    assert snap.pruned_blocks > 0
    assert snap.pruned_bytes_saved > 0
    assert "prune[" in snap.report()
    from repro.obs import render_prometheus
    text = render_prometheus(srv.metrics.registry)
    assert "serve_pruned_blocks_total" in text
    assert "serve_pruned_bytes_saved_total" in text


def test_server_pruned_mixed_batch(stores):
    from repro.serve.server import QueryServer, ServerConfig
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw, method="lookup")
    srv = QueryServer(idx_raw, ServerConfig(
        pruned=True, prune_chunk=16, prune_min_rate=0.05,
        result_cache=0, row_cache=0, max_wait_s=10.0))
    pats = _patterns(c)
    r1 = srv.submit(pats[0], threshold=0.9)
    r2 = srv.submit(pats[4], top_k=3)
    r3 = srv.submit(pats[5], threshold=0.8)
    srv.drain()
    got = srv.pop_responses()
    assert got[r1].method == "lookup_p"
    for rid, want in ((r1, engine.search(pats[0], threshold=0.9)),
                      (r2, engine.top_k(pats[4], k=3)),
                      (r3, engine.search(pats[5], threshold=0.8))):
        np.testing.assert_array_equal(got[rid].result.doc_ids,
                                      want.doc_ids)
        np.testing.assert_array_equal(got[rid].result.scores,
                                      want.scores)


# --------------------------------------------------------------------------
# Paged multi-host: worker pruned dispatch + local_pad shapes
# --------------------------------------------------------------------------

def test_worker_pruned_candidates_identical_zero_faults(stores):
    from repro.serve.worker import ShardWorker
    c, root, idx_raw, _, _ = stores
    ids = list(range(idx_raw.storage.n_shards))
    w_ref = ShardWorker("w-ref", root / "raw", ids)
    w_p = ShardWorker("w-prune", root / "raw", ids, pruned=True,
                      prune_chunk=16, prune_min_rate=0.05)
    term_sets = [compile_pattern(p, PARAMS) for p in _patterns(c)[:6]]
    buf, ells = pad_term_batch(term_sets, 64)
    cuts = np.array([coverage_cutoff(0.9, int(e)) for e in ells],
                    np.int32)
    topks = np.zeros(len(ells), np.int32)
    td_r, nd_r = w_ref.stage_batch(buf, ells)
    td_p, nd_p = w_p.stage_batch(buf, ells)
    for g in ids:
        assert w_ref.prefetch_shard(g)
        cand_r, m_r = w_ref.score_candidates(g, td_r, nd_r, cuts, topks,
                                             len(ells))
        cand_p, m_p = w_p.score_candidates(g, td_p, nd_p, cuts, topks,
                                           len(ells))
        for (d0, s0), (d1, s1) in zip(cand_r, cand_p):
            np.testing.assert_array_equal(d0, d1)
            np.testing.assert_array_equal(s0, s1)
    assert w_p.pruned_dispatches == len(ids)
    assert w_p.prune_stats.blocks_total > 0
    # pruned dispatch never touches the device tile cache
    assert w_p.tiles.faults == 0


def test_frontend_pruned_bit_identical(stores):
    from repro.serve.worker import ShardWorker
    from repro.serve.frontend import Frontend, FrontendConfig
    from repro.index.placement import ShardPlacement
    c, root, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw, method="lookup")
    n_sh = idx_raw.storage.n_shards
    placement = ShardPlacement(["w0", "w1"], n_sh, replication=1)
    workers = {
        node: ShardWorker(node, root / "raw",
                          placement.replica_assignment()[node],
                          pruned=True, prune_chunk=16,
                          prune_min_rate=0.05)
        for node in ("w0", "w1")
        if placement.replica_assignment()[node]}
    fe = Frontend(workers, placement,
                  FrontendConfig(max_wait_s=0.0, scatter_threads=1))
    pats = _patterns(c)
    rids = [fe.submit(p, threshold=0.9) for p in pats]
    fe.drain()
    got = fe.pop_responses()
    methods = {got[r].method for r in rids}
    assert "lookup_p" in methods
    for rid, p in zip(rids, pats):
        want = engine.search(p, threshold=0.9)
        np.testing.assert_array_equal(got[rid].result.doc_ids,
                                      want.doc_ids)
        np.testing.assert_array_equal(got[rid].result.scores,
                                      want.scores)
    snap = fe.metrics.snapshot()
    assert snap.pruned_blocks > 0
    # frontend top-k through pruned workers (shard-local bound soundness)
    rid = fe.submit(pats[5], top_k=4)
    fe.drain()
    r = fe.pop_responses()[rid]
    want = engine.top_k(pats[5], k=4)
    np.testing.assert_array_equal(r.result.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(r.result.scores, want.scores)


def test_worker_local_pad_dispatch_shapes(stores):
    from repro.serve.worker import ShardWorker
    _, root, idx_raw, _, _ = stores
    starts = idx_raw.storage.shard_row_starts
    heights = np.diff(starts)
    short = int(np.argmin(heights))
    assert heights[short] < heights.max()     # last block group is short
    w_local = ShardWorker("w-l", root / "raw", [short], local_pad=True)
    w_glob = ShardWorker("w-g", root / "raw", [short])
    # local padding sizes tiles to THIS worker's tallest shard only
    assert w_local.tiles.pad_rows_to == int(heights[short])
    assert w_glob.tiles.pad_rows_to == int(heights.max())
    assert w_local.tiles.pad_rows_to < w_glob.tiles.pad_rows_to
