"""Dry-run cell construction: every (arch x shape) either builds complete
abstract specs + shardings or is skipped for a documented reason — without
compiling anything (the real lower+compile runs in repro.launch.dryrun)."""
import jax
import pytest

from repro import configs
from repro.launch.mesh import make_mesh
from repro.launch.specs import SHAPES, cell_supported, make_cell

MESH = make_mesh((1, 1), ("data", "model"))
CELLS = [(a, s) for a in configs.list_archs() for s in SHAPES]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_constructs_or_documented_skip(arch, shape):
    cfg = configs.get(arch, smoke=True)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        assert why != ""
        assert shape == "long_500k" or not cfg.has_decoder
        return
    cell = make_cell(arch, shape, MESH, smoke=True)
    # abstract args: no leaf is a concrete array except tiny metadata
    flat_args = jax.tree.leaves(cell.args)
    assert all(hasattr(x, "shape") for x in flat_args)
    # sharding tree parallel to args
    flat_sh = jax.tree.leaves(cell.in_shardings,
                              is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_sh) > 0
    assert cell.shape.mode in ("train", "prefill", "decode")


def test_skip_matrix_matches_design():
    """DESIGN.md: long_500k runs ONLY for recurrentgemma + xlstm."""
    runners = [a for a in configs.list_archs()
               if cell_supported(configs.get(a), "long_500k")[0]]
    assert sorted(runners) == ["recurrentgemma-2b", "xlstm-125m"]


def test_full_cell_count():
    """40 LM cells: 10 archs x 4 shapes; 32 runnable + 8 documented skips."""
    ok = sk = 0
    for a, s in CELLS:
        good, _ = cell_supported(configs.get(a), s)
        ok += good
        sk += not good
    assert ok == 32 and sk == 8


def test_decode_cells_donate_cache():
    cell = make_cell("qwen3-4b", "decode_32k", MESH, smoke=True)
    assert cell.donate_argnums == (1,)


def test_train_cells_donate_state():
    cell = make_cell("qwen3-4b", "train_4k", MESH, smoke=True)
    assert cell.donate_argnums == (0,)
