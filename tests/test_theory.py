import math

import numpy as np
import pytest

from repro.core import theory


def test_bloom_size_paper_defaults():
    # k=1, p=0.3 -> w = v / -ln(0.7) ~= 2.804 v (paper section 2.2 / Fig. 4)
    w = theory.bloom_size(1_000_000, 0.3, 1)
    assert abs(w / 1_000_000 - 2.804) < 0.01


def test_bloom_fpr_inverts_size():
    for v in (100, 10_000, 1_000_000):
        for p in (0.05, 0.3, 0.5):
            for k in (1, 2, 4):
                w = theory.bloom_size(v, p, k)
                assert theory.bloom_fpr(w, k, v) <= p + 1e-9
                # minimality: one step smaller violates the target
                if w > 1:
                    assert theory.bloom_fpr(w - max(1, w // 100), k, v) > p - 0.02


def test_query_fpr_matches_bruteforce():
    """Theorem 1 against a direct binomial-tail computation."""
    for ell, p, theta in [(10, 0.3, 0.5), (70, 0.3, 0.5), (31, 0.1, 0.8)]:
        t = int(math.floor(theta * ell))
        direct = 0.0
        for i in range(t + 1, ell + 1):
            direct += math.comb(ell, i) * p ** i * (1 - p) ** (ell - i)
        assert abs(theory.query_fpr(ell, p, theta) - direct) < 1e-12


def test_query_fpr_paper_example():
    """Paper: ell=70, p=0.3, K=0.5 -> ~0.000143 (143 per million docs)."""
    fpr = theory.query_fpr(70, 0.3, 0.5)
    assert abs(fpr - 0.000143) < 0.00002
    exp = theory.expected_false_positive_docs(1_000_000, 70, 0.3, 0.5)
    assert 120 < exp < 165


def test_query_fpr_decays_with_length():
    vals = [theory.query_fpr(ell, 0.3, 0.5) for ell in (10, 30, 100, 300)]
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert vals[-1] < 1e-8


def test_chernoff_upper_bounds_exact():
    for ell in (20, 50, 100):
        exact = theory.query_fpr(ell, 0.3, 0.6)
        bound = theory.query_fpr_chernoff(ell, 0.3, 0.6)
        assert exact <= bound + 1e-12


def test_optimal_k():
    # w/v = 10 -> k_opt ~ 6.93 -> 7
    assert theory.optimal_k(1000, 100) == 7


def test_edge_cases():
    assert theory.bloom_fpr(100, 1, 0) == 0.0
    assert theory.query_fpr(0, 0.3, 0.5) == 0.0
    assert theory.query_fpr(10, 0.0, 0.5) == 0.0
    assert theory.query_fpr(10, 1.0, 0.5) == 1.0
    assert theory.bloom_size(0, 0.3, 1) == 1
    with pytest.raises(ValueError):
        theory.bloom_size(10, 1.5, 1)


def test_empirical_single_filter_fpr():
    """Build one real filter via the jit path and measure its FPR against
    the analytic prediction — validates the murmur-style hash substitution."""
    import jax.numpy as jnp
    from repro.core import bloom, hashing

    rng = np.random.default_rng(3)
    v, p = 5_000, 0.3
    w = theory.bloom_size(v, p, 1)
    terms = rng.integers(0, 2 ** 32, size=(1, v, 2), dtype=np.uint32)
    filt = np.asarray(bloom.build_filters(
        jnp.asarray(terms), jnp.asarray([v], np.int32), w, 1))[0]
    # fill rate check
    fill = filt.mean()
    assert abs(fill - theory.fill_rate(w, 1, v)) < 0.02
    # probe with fresh random terms (collisions with inserted set negligible)
    probes = rng.integers(0, 2 ** 32, size=(200_000, 2), dtype=np.uint32)
    h = hashing.hash_terms_np(probes, 1)[:, 0] % np.uint32(w)
    measured = filt[h].mean()
    assert abs(measured - theory.bloom_fpr(w, 1, v)) < 0.02
