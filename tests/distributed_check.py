"""Multi-device correctness check for DistributedIndex — executed in a
subprocess by test_distributed.py with XLA_FLAGS forcing 8 host devices
(other tests must see exactly 1 device, so this cannot run in-process).

Asserts, on a (pod=2, data=2, model=2) mesh:
  * doc-sharded scores == single-device QueryEngine scores (bit-exact)
  * doc+row (2D) sharded scores == single-device scores
  * distributed top-k returns the true top documents
  * search_batch hits == single-device hits
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

assert len(jax.devices()) == 8, jax.devices()

from repro.core import IndexParams, QueryEngine, build_compact, dna
from repro.data import make_corpus, make_queries
from repro.index import DistributedIndex
from repro.launch.mesh import make_mesh

corpus = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=21)
params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
index = build_compact(corpus.doc_terms, params, block_docs=32, row_align=64)
queries, origin = make_queries(corpus, n_pos=12, n_neg=8, length=80, seed=5)

single = QueryEngine(index, method="ref")

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

configs = {
    "doc-sharded": dict(doc_axes=("pod", "data"), row_axis=None),
    "2d-sharded": dict(doc_axes=("pod", "data"), row_axis="model"),
    "data-only": dict(doc_axes=("data",), row_axis="model"),
}

for name, kw in configs.items():
    dist = DistributedIndex(index, mesh, **kw)
    for q in queries[:6]:
        terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
        want = single.score_terms(terms)
        got = dist.scores_for(terms)
        np.testing.assert_array_equal(want, got), name
    print(f"OK scores {name}")

dist = DistributedIndex(index, mesh, doc_axes=("pod", "data"), row_axis="model")

# distributed top-k == host top-k
for q in queries[:6]:
    terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
    want = single.score_terms(terms)
    res = dist.search_batch([q], threshold=0.0, topk=8)[0]
    ids, vals = res
    order = np.argsort(-want, kind="stable")[:8]
    # same score multiset at the cut (ties may reorder ids)
    np.testing.assert_array_equal(np.sort(vals)[::-1],
                                  np.sort(want[order])[::-1])
print("OK distributed top-k")

# batched search agrees on true positives
batch = dist.search_batch(list(queries), threshold=0.9, topk=16)
for (ids, vals), o in zip(batch, origin):
    if o >= 0:
        assert o in set(ids.tolist()), (o, ids)
    else:
        assert len(ids) == 0, (o, ids)
print("OK search_batch hits")

print("ALL-DISTRIBUTED-OK")

# --- optimized scoring paths (§Perf cell C): fused lookup + int16 psum ----
import jax.numpy as jnp
for kw in (dict(score_method="lookup"),
           dict(score_method="lookup", score_dtype=jnp.int16)):
    dist_o = DistributedIndex(index, mesh, doc_axes=("pod", "data"),
                              row_axis="model", **kw)
    for q in queries[:4]:
        terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
        np.testing.assert_array_equal(single.score_terms(terms),
                                      dist_o.scores_for(terms))
print("OK optimized paths (lookup kernel, int16 psum) bit-exact")
print("ALL-DISTRIBUTED-OK")

# --- MoE local-capacity dispatch (§Perf cell A) == einsum baseline --------
import dataclasses
from repro import configs
from repro.models import build_model
from repro.models.partition import partitioning
from repro.launch import sharding as shd_rules

cfg_moe = configs.get("qwen3-moe-30b-a3b", smoke=True)   # cf=8 -> no drops
cfg_loc = dataclasses.replace(
    cfg_moe, moe=dataclasses.replace(cfg_moe.moe, dispatch="local"))
m_g, m_l = build_model(cfg_moe), build_model(cfg_loc)
mp, _ = m_g.init(jax.random.PRNGKey(0))
rngm = np.random.default_rng(1)
toksm = rngm.integers(0, cfg_moe.vocab, (4, 16)).astype("int32")
with mesh, partitioning(mesh, shd_rules.act_rules_for(mesh)):
    lg, _ = jax.jit(lambda p, t: m_g.forward_train(p, t))(mp, toksm)
    ll, _ = jax.jit(lambda p, t: m_l.forward_train(p, t))(mp, toksm)
np.testing.assert_allclose(np.asarray(lg), np.asarray(ll), rtol=3e-2, atol=3e-2)
print("OK moe local dispatch == einsum (no-drop regime)")
print("ALL-DISTRIBUTED-OK")
