"""Sharding rule engine + partitioning context unit tests (single device:
mesh axes of size 1, plus abstract divisibility logic)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models.partition import resolve_spec


class FakeMesh:
    """Duck-typed mesh for pure rule-resolution tests."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_shard():
    spec = shd.spec_for(("vocab", "embed"), (256000, 2560), MESH)
    assert spec == P("model", "data")


def test_indivisible_heads_fall_back_to_replicate():
    # phi4: 24 heads don't divide 16 -> heads dim unsharded
    spec = shd.spec_for(("embed", "heads", "head_dim"), (3072, 24, 128), MESH)
    assert spec == P("data")


def test_no_mesh_axis_reused():
    # both dims want "model": only the first gets it
    spec = shd.spec_for(("ff", "vocab"), (8192, 256000), MESH)
    assert spec == P("model")


def test_batch_uses_pod_and_data():
    spec = shd.spec_for(("batch", "seq"), (256, 4096), MESH)
    assert spec == P(("pod", "data"))


def test_batch_of_one_replicates():
    spec = shd.spec_for(("batch", "kv_seq"), (1, 524288), MESH)
    assert spec == P()


def test_cache_rules_head_dim_fallback():
    # kv=8 doesn't divide 16 -> cache shards head_dim instead
    spec = shd.spec_for(("batch", "kv_seq", "kv", "head_dim"),
                        (128, 32768, 8, 128), MESH, rules=shd.CACHE_RULES)
    assert spec == P(("pod", "data"), None, None, "model")


def test_param_rules_no_head_dim_tp():
    spec = shd.spec_for(("embed", "kv", "head_dim"), (3072, 8, 128), MESH)
    assert spec == P("data")


def test_missing_mesh_axis_filtered():
    single = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for(("batch",), (256,), single)
    assert spec == P("data")


def test_act_rules_for_filters():
    single = FakeMesh({"data": 4})
    rules = shd.act_rules_for(single)
    assert rules["batch"] == ("data",)
    assert rules["ff"] is None          # "model" absent
    assert rules["embed"] is None


def test_resolve_spec_rank_mismatch_returns_empty():
    assert resolve_spec(("batch", "seq", "embed"), (8, 16), MESH,
                        {"batch": ("data",)}) == P()


def test_real_mesh_tree_shardings():
    mesh = make_mesh((1,), ("data",))
    axes = {"w": ("embed", "ff"), "b": ("ff",)}

    class S:
        def __init__(self, shape):
            self.shape = shape

    shapes = {"w": S((64, 128)), "b": S((128,))}
    sh = shd.tree_shardings(axes, shapes, mesh)
    # size-1 axes shard nothing
    assert sh["w"].spec == P()
    assert sh["b"].spec == P()
