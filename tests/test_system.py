"""End-to-end behaviour of the whole system (the paper's workflow):
corpus -> build both index flavours -> labeled query batches -> accuracy,
size, and theory checks — plus persistence and ranking flows."""
import numpy as np
import pytest

from repro.core import (IndexParams, QueryEngine, build_classic,
                        build_compact, dna, load_index, save_index, theory)
from repro.data import make_corpus, make_queries, mutate


@pytest.fixture(scope="module")
def world():
    corpus = make_corpus(150, k=15, mean_length=1200, sigma=1.0, seed=8)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    classic = build_classic(corpus.doc_terms, params)
    compact = build_compact(corpus.doc_terms, params, block_docs=64)
    return corpus, params, classic, compact


def test_paper_workflow_end_to_end(world):
    corpus, params, classic, compact = world
    # Fig. 4: compaction shrinks the index on skewed corpora
    assert compact.size_bytes() < classic.size_bytes()

    # Table 3 semantics on a labeled batch
    queries, origin = make_queries(corpus, n_pos=15, n_neg=15, length=100,
                                   seed=3)
    for idx in (classic, compact):
        eng = QueryEngine(idx)
        results = eng.search_batch(queries, threshold=0.8)
        for r, o in zip(results, origin):
            ids = set(r.doc_ids.tolist())
            if o >= 0:
                assert o in ids                  # no false negatives, ever
            else:
                assert len(ids) == 0             # Theorem 1 at ell=86, K=.8


def test_mutated_queries_rank_origin_first(world):
    corpus, params, classic, compact = world
    rng = np.random.default_rng(11)
    eng = QueryEngine(compact)
    hits = trials = 0
    for _ in range(12):
        d = int(rng.integers(0, corpus.n_docs))
        doc = corpus.documents[d]
        if len(doc) < 150:
            continue
        start = int(rng.integers(0, len(doc) - 120))
        q = mutate(rng, doc[start:start + 120], 0.02)
        r = eng.top_k(q, k=3)
        trials += 1
        hits += int(r.doc_ids[0] == d)
    assert trials > 0 and hits >= trials - 1


def test_index_survives_disk_roundtrip_with_same_results(world, tmp_path):
    corpus, params, classic, compact = world
    save_index(compact, tmp_path / "idx")
    re = load_index(tmp_path / "idx")
    queries, _ = make_queries(corpus, n_pos=5, n_neg=5, length=80, seed=4)
    a = QueryEngine(compact).search_batch(queries, threshold=0.6)
    b = QueryEngine(re).search_batch(queries, threshold=0.6)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.doc_ids, y.doc_ids)
        np.testing.assert_array_equal(x.scores, y.scores)


def test_scores_scale_with_containment(world):
    """q-gram score ~ containment: longer shared spans -> higher scores."""
    corpus, params, classic, compact = world
    rng = np.random.default_rng(13)
    eng = QueryEngine(compact)
    d = next(i for i in range(corpus.n_docs)
             if len(corpus.documents[i]) >= 400)
    doc = corpus.documents[d]
    noise = rng.integers(0, 4, 200, dtype=np.uint8)
    scores_at = []
    for span in (40, 100, 180):
        q = np.concatenate([doc[:span], noise[:200 - span]])
        terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
        scores_at.append(int(eng.score_terms(terms)[d]))
    assert scores_at[0] < scores_at[1] < scores_at[2]


def test_expected_fp_documents_formula(world):
    corpus, params, classic, compact = world
    # the paper's '143 per million documents' example scales to < 1 here
    exp = theory.expected_false_positive_docs(corpus.n_docs, 70, 0.3, 0.5)
    assert exp < 1.0


def test_multi_index_frontend(world):
    """Paper section 4: a frontend querying multiple index files merges
    ranked results across datasets and supports attach/detach."""
    from repro.core import MultiIndexEngine, build_compact
    corpus, params, classic, compact = world
    other = make_corpus(30, k=15, mean_length=800, sigma=0.8, seed=99)
    idx2 = build_compact(other.doc_terms, params, block_docs=32)

    multi = MultiIndexEngine()
    multi.attach("main", compact)
    multi.attach("aux", idx2)
    assert multi.datasets == ("main", "aux")

    # a query from 'aux' must surface with dataset label, top-ranked
    doc = other.documents[3]
    hits = multi.search(doc[:90], threshold=0.9)
    assert hits and hits[0].dataset == "aux" and hits[0].doc_id == 3

    # dataset selection filters
    hits_main = multi.search(doc[:90], threshold=0.9, datasets=("main",))
    assert all(h.dataset == "main" for h in hits_main)

    multi.detach("aux")
    assert multi.datasets == ("main",)
    import pytest as _pt
    with _pt.raises(KeyError):
        multi.attach("main", compact)
