import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IndexParams, QueryEngine, build_classic, build_compact, dna
from repro.data import make_corpus, make_queries


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(64, k=15, mean_length=400, sigma=1.0, seed=7)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    classic = build_classic(corpus.doc_terms, params)
    compact = build_compact(corpus.doc_terms, params, block_docs=32,
                            row_align=64)
    queries, origin = make_queries(corpus, n_pos=25, n_neg=25, length=80,
                                   seed=11)
    return corpus, classic, compact, queries, origin


def brute_force_scores(corpus, query_codes):
    """Oracle: exact q-gram containment count per document."""
    q = dna.unique_terms(dna.pack_kmers(query_codes, corpus.k))
    q64 = set((q[:, 0].astype(np.uint64)
               | (q[:, 1].astype(np.uint64) << np.uint64(32))).tolist())
    out = np.zeros(corpus.n_docs, dtype=np.int32)
    for i, t in enumerate(corpus.doc_terms):
        d64 = (t[:, 0].astype(np.uint64)
               | (t[:, 1].astype(np.uint64) << np.uint64(32)))
        out[i] = sum(1 for v in d64.tolist() if v in q64)
    return out, len(q64)


def test_no_false_negatives_invariant(setup):
    """One-sided error: index score >= true containment count, ALWAYS."""
    corpus, classic, compact, queries, _ = setup
    for idx in (classic, compact):
        eng = QueryEngine(idx, method="ref")
        for q in queries[:10]:
            truth, _ = brute_force_scores(corpus, q)
            terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
            scores = eng.score_terms(terms)
            assert (scores >= truth).all()


def test_true_positives_found(setup):
    corpus, classic, compact, queries, origin = setup
    for idx in (classic, compact):
        eng = QueryEngine(idx)
        for q, o in zip(queries, origin):
            if o < 0:
                continue
            r = eng.search(q, threshold=1.0)  # exact substring -> full score
            assert o in set(r.doc_ids.tolist())


def test_score_of_origin_is_full(setup):
    corpus, classic, _, queries, origin = setup
    eng = QueryEngine(classic)
    for q, o in zip(queries, origin):
        if o < 0:
            continue
        terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
        scores = eng.score_terms(terms)
        assert scores[o] == terms.shape[0]


def test_methods_agree(setup):
    corpus, classic, compact, queries, _ = setup
    for idx in (classic, compact):
        engines = {m: QueryEngine(idx, method=m)
                   for m in ("ref", "unpack", "vertical", "lookup")}
        for q in queries[:6]:
            terms = dna.unique_terms(dna.pack_kmers(q, corpus.k))
            ref_scores = engines["ref"].score_terms(terms)
            for m in ("unpack", "vertical", "lookup"):
                np.testing.assert_array_equal(
                    ref_scores, engines[m].score_terms(terms), err_msg=m)


def test_batch_equals_single(setup):
    corpus, classic, compact, queries, _ = setup
    for idx in (classic, compact):
        eng = QueryEngine(idx)
        singles = [eng.search(q, threshold=0.8) for q in queries[:8]]
        batch = eng.search_batch(queries[:8], threshold=0.8)
        for s, b in zip(singles, batch):
            np.testing.assert_array_equal(s.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(s.scores, b.scores)


def test_batch_equals_single_lookup_method(setup):
    """Regression: batched method='lookup' used to silently score via the
    jnp ref oracle; it now runs the fused multi-query kernel and must match
    per-query fused scoring exactly, on classic AND compact layouts."""
    corpus, classic, compact, queries, _ = setup
    for idx in (classic, compact):
        eng = QueryEngine(idx, method="lookup")
        term_sets = [dna.unique_terms(dna.pack_kmers(q, corpus.k))
                     for q in queries[:8]]
        ells = np.array([t.shape[0] for t in term_sets], dtype=np.int32)
        pad = max(64, ((int(ells.max()) + 63) // 64) * 64)
        buf = np.zeros((8, pad, 2), dtype=np.uint32)
        for i, t in enumerate(term_sets):
            buf[i, : t.shape[0]] = t
        batched = eng.score_terms_batch(buf, ells)
        for i, t in enumerate(term_sets):
            np.testing.assert_array_equal(eng.score_terms(t), batched[i])
        singles = [eng.search(q, threshold=0.8) for q in queries[:8]]
        batch = eng.search_batch(queries[:8], threshold=0.8)
        for s, b in zip(singles, batch):
            np.testing.assert_array_equal(s.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(s.scores, b.scores)


def test_top_k_reports_actual_cutoff(setup):
    corpus, classic, _, queries, _ = setup
    eng = QueryEngine(classic)
    r = eng.top_k(queries[0], k=5)
    assert r.threshold == int(r.scores[-1])      # k-th best score
    assert (r.scores >= r.threshold).all()
    full = eng.score_terms(dna.unique_terms(
        dna.pack_kmers(queries[0], corpus.k)))
    # nothing outside the top-k beats the reported cutoff's rank boundary
    assert int(np.sort(full)[-5]) == r.threshold


def test_classic_compact_same_hits_at_threshold(setup):
    """Both layouts must report every true hit; false-positive sets may
    differ (different widths) but true positives never drop."""
    corpus, classic, compact, queries, origin = setup
    ec, ek = QueryEngine(classic), QueryEngine(compact)
    for q, o in zip(queries, origin):
        if o < 0:
            continue
        assert o in set(ec.search(q, 0.9).doc_ids.tolist())
        assert o in set(ek.search(q, 0.9).doc_ids.tolist())


def test_threshold_semantics(setup):
    corpus, classic, _, queries, _ = setup
    eng = QueryEngine(classic)
    q = queries[0]
    r_all = eng.search(q, threshold=0.0)
    r_half = eng.search(q, threshold=0.5)
    r_full = eng.search(q, threshold=1.0)
    assert len(r_full.doc_ids) <= len(r_half.doc_ids) <= len(r_all.doc_ids)
    if len(r_half.doc_ids):
        assert (r_half.scores >= r_half.threshold).all()
        # descending order
        assert (np.diff(r_half.scores) <= 0).all()


def test_top_k(setup):
    corpus, classic, _, queries, origin = setup
    eng = QueryEngine(classic)
    pos = [q for q, o in zip(queries, origin) if o >= 0][0]
    o = [o for o in origin if o >= 0][0]
    r = eng.top_k(pos, k=5)
    assert len(r.doc_ids) == 5
    assert r.doc_ids[0] == o or r.scores[0] == r.n_terms


def test_empty_query(setup):
    _, classic, _, _, _ = setup
    eng = QueryEngine(classic)
    r = eng.search("ACG", threshold=0.5)  # shorter than k=15
    assert len(r.doc_ids) == 0 and r.n_terms == 0


def test_string_query_interface(setup):
    corpus, classic, _, _, _ = setup
    doc = corpus.documents[0]
    s = dna.decode_dna(doc[:60])
    r = QueryEngine(classic).search(s, threshold=1.0)
    assert 0 in set(r.doc_ids.tolist())


def test_measured_fpr_near_prescribed(setup):
    """Paper Table 3: COBS returns ~the prescribed 0.3 FPR for single-k-mer
    queries; multi-k-mer queries (ell >= 100 terms) return ZERO false
    positives at K=0.8."""
    corpus, _, compact, _, _ = setup
    eng = QueryEngine(compact)
    rng = np.random.default_rng(5)
    # single k-mer probes that are true negatives
    universe = set()
    for t in corpus.doc_terms:
        u = t[:, 0].astype(np.uint64) | (t[:, 1].astype(np.uint64) << np.uint64(32))
        universe |= set(u.tolist())
    hits = total = 0
    for _ in range(400):
        kmer = rng.integers(0, 4, corpus.k, dtype=np.uint8)
        t = dna.pack_kmers(kmer, corpus.k)
        v = int(t[0, 0]) | (int(t[0, 1]) << 32)
        if v in universe:
            continue
        scores = eng.score_terms(t)
        hits += int((scores >= 1).sum())
        total += corpus.n_docs
    measured = hits / total
    expected = compact.expected_fpr().mean()
    assert abs(measured - expected) < 0.08
    assert measured < 0.35


def test_long_negative_queries_zero_false_positives(setup):
    corpus, _, compact, queries, origin = setup
    eng = QueryEngine(compact)
    for q, o in zip(queries, origin):
        if o >= 0:
            continue
        r = eng.search(q, threshold=0.8)
        assert len(r.doc_ids) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_property_search_is_deterministic(seed):
    corpus = make_corpus(8, k=9, mean_length=100, sigma=0.5, seed=3)
    idx = build_classic(corpus.doc_terms, IndexParams(kmer=9), row_align=64)
    eng = QueryEngine(idx)
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 4, 30, dtype=np.uint8)
    a, b = eng.search(q, 0.5), eng.search(q, 0.5)
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
    np.testing.assert_array_equal(a.scores, b.scores)
