import numpy as np

from repro.core import IndexParams, build_compact
from repro.data import make_corpus
from repro.index import build_compact_parallel


def _corpus():
    return make_corpus(80, k=15, mean_length=300, sigma=1.0, seed=13)


def test_parallel_build_bit_exact():
    c = _corpus()
    p = IndexParams(kmer=15)
    a = build_compact(c.doc_terms, p, block_docs=32, row_align=64)
    for workers in (1, 4):
        b = build_compact_parallel(c.doc_terms, p, block_docs=32,
                                   row_align=64, workers=workers)
        np.testing.assert_array_equal(np.asarray(a.arena), np.asarray(b.arena))
        np.testing.assert_array_equal(np.asarray(a.row_offset),
                                      np.asarray(b.row_offset))
        np.testing.assert_array_equal(np.asarray(a.doc_slot),
                                      np.asarray(b.doc_slot))


def test_checkpoint_resume(tmp_path):
    c = _corpus()
    p = IndexParams(kmer=15)
    full = build_compact_parallel(c.doc_terms, p, block_docs=32, row_align=64,
                                  workers=2, checkpoint_dir=tmp_path / "ck")
    # simulate a crash-and-restart: manifest + block files exist, build again
    resumed = build_compact_parallel(c.doc_terms, p, block_docs=32,
                                     row_align=64, workers=2,
                                     checkpoint_dir=tmp_path / "ck")
    np.testing.assert_array_equal(np.asarray(full.arena),
                                  np.asarray(resumed.arena))


def test_serial_build_writes_resume_manifest(tmp_path):
    """Regression: the workers<=1 path checkpointed block .npy files but
    never wrote blocks.json, so a restart resumed nothing."""
    import json
    c = _corpus()
    p = IndexParams(kmer=15)
    ck = tmp_path / "ck"
    build_compact_parallel(c.doc_terms, p, block_docs=32, row_align=64,
                           workers=1, checkpoint_dir=ck)
    manifest = ck / "blocks.json"
    assert manifest.exists()
    done = json.loads(manifest.read_text())["done"]
    assert done == sorted(done)
    assert len(done) == len(list(ck.glob("block*.npy")))
    # a restart must actually reuse the checkpoints: poison one block file
    # on disk; if resume reads it (instead of rebuilding), the arena drifts
    victim = ck / "block000001.npy"
    m = np.load(victim)
    m[0, 0] ^= np.uint32(1)
    np.save(victim, m)
    resumed = build_compact_parallel(c.doc_terms, p, block_docs=32,
                                     row_align=64, workers=1,
                                     checkpoint_dir=ck)
    ref = build_compact(c.doc_terms, p, block_docs=32, row_align=64)
    assert not np.array_equal(np.asarray(resumed.arena),
                              np.asarray(ref.arena))


def test_partial_checkpoint_resume(tmp_path):
    """Delete some block files (simulating blocks lost mid-build): resume
    must rebuild exactly those and produce the same index."""
    import json
    c = _corpus()
    p = IndexParams(kmer=15)
    ck = tmp_path / "ck"
    ref = build_compact_parallel(c.doc_terms, p, block_docs=32, row_align=64,
                                 workers=1, checkpoint_dir=ck)
    # corrupt: drop one block file, keep manifest stale
    victims = sorted(ck.glob("block*.npy"))[1:2]
    for v in victims:
        v.unlink()
    resumed = build_compact_parallel(c.doc_terms, p, block_docs=32,
                                     row_align=64, workers=1,
                                     checkpoint_dir=ck)
    np.testing.assert_array_equal(np.asarray(ref.arena),
                                  np.asarray(resumed.arena))
