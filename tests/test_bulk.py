"""Bulk-lane tests: the shard-major executor must be LOSSLESS.

The load-bearing invariant mirrors the pruning suite's: inverting the
loop order (stage each shard tile once, stream every query against it)
changes BYTES MOVED, never SCORES. Every bulk path — threshold and
top-k, raw and rowdict stores, dense and paged layouts, the multi-host
frontend sweep, the pruned per-shard reuse, checkpoint/resume, and the
BULK wire frame — must return results bit-identical to the QueryEngine
oracle, while BulkStats proves the staging amortization actually
happened.

Satellites covered here too: the adaptive micro-batch bucket fitting,
and the preemption contract (interactive requests keep completing while
a sweep is mid-flight).
"""
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IndexParams, QueryEngine
from repro.core.query import (compile_pattern, coverage_cutoff,
                              pad_term_batch, run_shard_major)
from repro.data import make_corpus
from repro.index import build_compact_streaming
from repro.serve import (BulkJob, BulkLane, BulkStatus, QueryServer,
                         ServerConfig, ServingLoop, Status)

PARAMS = IndexParams(n_hashes=1, fpr=0.03, kmer=15)


def _redundant_terms(n_base=24, reps=6, seed=3):
    c = make_corpus(n_base, k=15, mean_length=160, min_length=120,
                    seed=seed)
    return c, [c.doc_terms[i % n_base] for i in range(n_base * reps)]


@pytest.fixture(scope="module")
def stores(tmp_path_factory):
    """paged raw, paged rowdict, and dense (single-shard) stores over the
    same corpus — the three executor regimes the sweep must match."""
    c, terms = _redundant_terms()
    root = tmp_path_factory.mktemp("bulk-stores")
    idx_raw, _ = build_compact_streaming(
        terms, root / "raw", PARAMS, block_docs=32, blocks_per_shard=1,
        codec="raw")
    idx_c, _ = build_compact_streaming(
        terms, root / "comp", PARAMS, block_docs=32, blocks_per_shard=1,
        codec="rowdict")
    idx_dense, _ = build_compact_streaming(
        terms, root / "dense", PARAMS, block_docs=32, blocks_per_shard=64,
        codec="raw")
    assert idx_raw.storage.n_shards > 2
    assert idx_dense.storage.n_shards == 1
    return c, root, idx_raw, idx_c, idx_dense


def _patterns(c, n_random=4, seed=0):
    rng = np.random.default_rng(seed)
    pats = ["".join(rng.choice(list("ACGT"), size=70))
            for _ in range(n_random)]
    pats += [c.documents[i][10:100] for i in range(4)]
    return pats


def _assert_job_matches(job, engine, pats, *, threshold=None, top_k=0):
    assert job.status is BulkStatus.DONE, job.error
    assert len(job.results) == len(pats)
    for pat, got in zip(pats, job.results):
        want = (engine.top_k(pat, k=top_k) if top_k
                else engine.search(pat, threshold=threshold))
        np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(got.scores, want.scores)


# --------------------------------------------------------------------------
# Shard-major executor: bit-identical to the oracle (property)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([0.3, 0.5, 0.9, 1.0]),
       st.sampled_from(["raw", "comp", "dense"]),
       st.booleans(),
       st.integers(0, 10 ** 6))
def test_bulk_threshold_matches_oracle(stores, threshold, kind, pruned,
                                       seed):
    c, _, idx_raw, idx_c, idx_dense = stores
    idx = {"raw": idx_raw, "comp": idx_c, "dense": idx_dense}[kind]
    engine = QueryEngine(idx, compressed=(kind == "comp"))
    server = QueryServer(idx, ServerConfig(result_cache=0, row_cache=0))
    lane = BulkLane(server, chunk_terms=16)
    pats = _patterns(c, seed=seed)
    job = lane.submit(pats, threshold=threshold, pruned=pruned)
    lane.drain()
    _assert_job_matches(job, engine, pats, threshold=threshold)
    assert job.stats.shards_swept == idx.storage.n_shards
    assert job.stats.kernel_dispatches > 0 or pruned


def test_bulk_top_k_matches_oracle(stores):
    c, _, idx_raw, idx_c, _ = stores
    pats = _patterns(c)
    for idx, comp in ((idx_raw, False), (idx_c, True)):
        engine = QueryEngine(idx, compressed=comp)
        server = QueryServer(idx, ServerConfig(result_cache=0,
                                               row_cache=0))
        lane = BulkLane(server, chunk_terms=16)
        for k in (1, 3, 64):
            job = lane.submit(pats, top_k=k)
            lane.drain()
            _assert_job_matches(job, engine, pats, top_k=k)


def test_bulk_k2_hashes_matches_oracle(tmp_path):
    """n_hashes=2: the device gather+AND promotion path end to end."""
    c, terms = _redundant_terms(n_base=16, reps=4, seed=9)
    p2 = IndexParams(n_hashes=2, fpr=0.05, kmer=15)
    idx, _ = build_compact_streaming(
        terms, tmp_path / "k2", p2, block_docs=32, blocks_per_shard=1)
    engine = QueryEngine(idx, method="vertical")
    server = QueryServer(idx, ServerConfig(result_cache=0, row_cache=0))
    lane = BulkLane(server, chunk_terms=16)
    pats = _patterns(c)[:5]
    for thr in (0.5, 1.0):
        job = lane.submit(pats, threshold=thr)
        lane.drain()
        _assert_job_matches(job, engine, pats, threshold=thr)
    job = lane.submit(pats, top_k=3)
    lane.drain()
    _assert_job_matches(job, engine, pats, top_k=3)


def test_bulk_multihost_matches_oracle(stores):
    from repro.index.placement import ShardPlacement
    from repro.serve.frontend import Frontend, FrontendConfig
    from repro.serve.worker import ShardWorker
    c, root, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw)
    n_sh = idx_raw.storage.n_shards
    placement = ShardPlacement(["w0", "w1"], n_sh, replication=1)
    workers = {
        node: ShardWorker(node, root / "raw",
                          placement.replica_assignment()[node])
        for node in ("w0", "w1")
        if placement.replica_assignment()[node]}
    fe = Frontend(workers, placement,
                  FrontendConfig(max_wait_s=0.0, scatter_threads=1))
    lane = BulkLane(fe, chunk_terms=16)
    pats = _patterns(c)
    job = lane.submit(pats, threshold=0.5)
    lane.drain()
    _assert_job_matches(job, engine, pats, threshold=0.5)
    job = lane.submit(pats, top_k=4)
    lane.drain()
    _assert_job_matches(job, engine, pats, top_k=4)


# --------------------------------------------------------------------------
# The point of the lane: each tile staged once, amortized over the set
# --------------------------------------------------------------------------

def test_bulk_stages_each_tile_once(stores):
    c, _, idx_raw, _, _ = stores
    storage = idx_raw.storage
    # interactive baseline: one-shard cache, several batches -> restaging
    tile_bytes = max(storage.shard_nbytes(s)
                     for s in range(storage.n_shards))
    srv_i = QueryServer(idx_raw, ServerConfig(
        max_batch=2, tile_cache_bytes=tile_bytes, result_cache=0,
        row_cache=0))
    pats = _patterns(c)
    for i in range(0, len(pats), 2):
        for p in pats[i:i + 2]:
            srv_i.submit(p, threshold=0.3)
        srv_i.drain()
    inter = srv_i.tiles.raw_bytes_staged + srv_i.tiles.comp_bytes_staged

    srv_b = QueryServer(idx_raw, ServerConfig(
        tile_cache_bytes=tile_bytes, result_cache=0, row_cache=0))
    lane = BulkLane(srv_b)
    job = lane.submit(pats, threshold=0.3)
    lane.drain()
    assert job.status is BulkStatus.DONE, job.error
    # one (padded) staging per shard, never more — and a multiple less
    # traffic than the restaging interactive lane moved for the same set
    # (both lanes stage through the same DeviceTileCache padding)
    assert job.stats.tiles_staged == storage.n_shards
    assert 0 < job.stats.bytes_staged * 2 <= inter
    assert job.staged_bytes_per_query * len(pats) == job.stats.bytes_staged


# --------------------------------------------------------------------------
# Checkpoint / resume: finished shards are never rescored
# --------------------------------------------------------------------------

def test_bulk_checkpoint_resume(stores, tmp_path):
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw)
    pats = _patterns(c)
    server = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                               row_cache=0))
    lane = BulkLane(server, chunk_terms=16)
    job = lane.submit(pats, threshold=0.5,
                      checkpoint_path=tmp_path / "ck.npz")
    caches, plans = lane._targets()
    job.shards_total = len(plans)
    lane._step(job, caches, plans)       # sweep exactly one shard
    assert job.next_shard == 1
    ck = BulkJob.load(tmp_path / "ck.npz")     # written by _step
    assert ck["next_shard"] == 1
    # a fresh lane resumes from the persisted state and only sweeps the
    # remaining shards
    server2 = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                                row_cache=0))
    lane2 = BulkLane(server2, chunk_terms=16)
    job2 = lane2.submit(pats, threshold=0.5, resume=ck)
    lane2.drain()
    assert job2.stats.shards_swept == idx_raw.storage.n_shards - 1
    _assert_job_matches(job2, engine, pats, threshold=0.5)
    # in-memory checkpoint dict round-trips the same way
    ck2 = job.checkpoint()
    server3 = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                                row_cache=0))
    lane3 = BulkLane(server3, chunk_terms=16)
    job3 = lane3.submit(pats, threshold=0.5, resume=ck2)
    lane3.drain()
    _assert_job_matches(job3, engine, pats, threshold=0.5)


def test_run_shard_major_suspend_resume(stores):
    """The executor itself suspends at any shard boundary and picks up
    from the returned state."""
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw)
    pats = _patterns(c)
    term_sets = [compile_pattern(p, PARAMS) for p in pats]
    buf, ells = pad_term_batch(term_sets, 8)
    ells = np.asarray(ells, np.int32)
    required = np.array([coverage_cutoff(0.5, int(e)) for e in ells],
                        np.int64)
    topk = np.zeros(len(ells), np.int32)
    server = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                               row_cache=0))
    plans = server.planner.shard_plans
    out, nxt, req = None, 0, required
    hops = 0
    while nxt < len(plans):
        out, nxt, req = run_shard_major(
            server.tiles, plans, buf, ells, req, topk,
            n_hashes=PARAMS.n_hashes, start_shard=nxt, out=out,
            should_yield=lambda: True)      # stop after every shard
        hops += 1
    assert hops == len(plans)
    host_slot = np.asarray(idx_raw.layout.doc_slot)
    from repro.core.query import select_hits
    for i, pat in enumerate(pats):
        want = engine.search(pat, threshold=0.5)
        got = select_hits(out[i][host_slot], int(ells[i]), 0.5)
        np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(got.scores, want.scores)


# --------------------------------------------------------------------------
# Preemption: interactive traffic keeps flowing mid-sweep
# --------------------------------------------------------------------------

def test_bulk_preemption_interactive_liveness(stores):
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw)
    server = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                               row_cache=0,
                                               max_wait_s=0.0))
    loop = ServingLoop(server).start()
    lane = BulkLane(server, loop, chunk_terms=8).start()
    try:
        pats = _patterns(c)
        # a wide sweep: many queries so every shard does real work
        job = lane.submit(pats * 8, threshold=0.5)
        done = threading.Event()
        inter: list = []

        def on_done(resp, _l=inter):
            _l.append(resp)
            if len(_l) == len(pats):
                done.set()

        for p in pats:
            loop.submit(p, threshold=0.5, on_done=on_done)
        assert done.wait(60.0), "interactive queries starved by the sweep"
        assert all(r.status == Status.OK for r in inter)
        assert job.wait(120.0), "bulk sweep never finished"
        _assert_job_matches(job, engine, pats * 8, threshold=0.5)
        snap = server.metrics.snapshot()
        assert snap.bulk_jobs == 1
        assert snap.bulk_queries == len(pats) * 8
        assert snap.bulk_shards_swept == idx_raw.storage.n_shards
        assert snap.bulk_staged_bytes == job.stats.bytes_staged
        assert "bulk[" in snap.report()
    finally:
        loop.stop()
    assert lane._thread is None          # loop.stop() halted the lane


def test_bulk_lane_stop_requeues_running_job(stores):
    c, _, idx_raw, _, _ = stores
    server = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                               row_cache=0))
    lane = BulkLane(server, chunk_terms=8)
    pats = _patterns(c)
    job = lane.submit(pats, threshold=0.5)
    caches, plans = lane._targets()
    job.shards_total = len(plans)
    job.status = BulkStatus.RUNNING
    lane._step(job, caches, plans)       # mid-sweep state exists
    assert 0 < job.next_shard < job.shards_total
    # cancel only works on queued jobs; the running one keeps its state
    assert not lane.cancel(job.job_id)
    job2 = lane.submit(pats, top_k=2)
    assert lane.cancel(job2.job_id)
    assert job2.status is BulkStatus.CANCELLED
    assert job2.done.is_set()


def test_bulk_submit_validation(stores):
    _, _, idx_raw, _, _ = stores
    server = QueryServer(idx_raw)
    lane = BulkLane(server)
    with pytest.raises(ValueError):
        lane.submit(term_sets=[np.zeros((4, 2), np.uint32)], top_k=3,
                    pruned=True)


# --------------------------------------------------------------------------
# Satellite: adaptive micro-batch bucket edges
# --------------------------------------------------------------------------

def test_fit_bucket_edges_properties():
    from repro.serve import fit_bucket_edges
    assert fit_bucket_edges([]) == []
    lengths = [17, 18, 19, 20, 21, 22, 23, 150]
    edges = fit_bucket_edges(lengths, max_buckets=4, quantum=8)
    assert edges == sorted(set(edges))             # ascending, unique
    assert all(e % 8 == 0 for e in edges)
    assert len(edges) <= 4
    assert edges[-1] >= max(lengths)               # covers the maximum
    assert edges[0] <= 24                          # cluster got its edge


def test_adaptive_batcher_densifies_clustered_lengths():
    from repro.serve import MicroBatcher
    from repro.serve.request import QueryRequest

    def req(i, n):
        return QueryRequest(request_id=i, terms=np.zeros((n, 2),
                                                         np.uint32),
                            n_terms=n, threshold=0.5, submitted_at=0.0)

    fixed = MicroBatcher(term_pad=64, adaptive=False)
    adap = MicroBatcher(term_pad=64, adaptive=True, adapt_every=32,
                        adapt_quantum=8)
    # a workload clustered at ~20 terms: the fixed grid pads to 64,
    # the adaptive one converges on a 24-wide bucket
    for i in range(64):
        fixed.submit(req(i, 20))
        adap.submit(req(i, 20))
    assert fixed.bucket_of(20) == 64
    assert adap.bucket_edges                      # a fit happened
    assert adap.bucket_of(20) <= 24
    # queued requests keep their stamped bucket even after a refit
    r = req(999, 20)
    adap.submit(r)
    stamped = r.bucket
    adap.fit([100, 200, 300])
    assert r.bucket == stamped
    # beyond the largest fitted edge: fixed-grid fallback
    assert adap.bucket_of(10 ** 4) == 64 * (10 ** 4 // 64 + 1)
    # explicit fit from a known histogram (a bulk job's term counts)
    m = MicroBatcher(term_pad=64)
    m.fit([30, 31, 33])
    assert m.bucket_of(31) == 32
    assert m.bucket_of(33) == 40


# --------------------------------------------------------------------------
# BULK wire frame: whole query sets over the wire
# --------------------------------------------------------------------------

def test_bulk_frame_roundtrip():
    from repro.serve.net import decode_bulk, encode_bulk
    rng = np.random.default_rng(0)
    sets = [rng.integers(0, 2 ** 32, size=(n, 2), dtype=np.uint32)
            for n in (3, 1, 7)]
    rid, back, th, tk = decode_bulk(encode_bulk(41, sets, 0.75, 0))
    assert rid == 41 and th == 0.75 and tk == 0
    for a, b in zip(sets, back):
        np.testing.assert_array_equal(a, b)
    _, _, th, tk = decode_bulk(encode_bulk(0, sets, None, 5))
    assert th is None and tk == 5
    with pytest.raises(ConnectionError):
        decode_bulk(encode_bulk(0, sets, None, 5)[:-3])


def test_bulk_over_the_wire(stores):
    from repro.serve import NetClient, NetServer
    c, _, idx_raw, _, _ = stores
    engine = QueryEngine(idx_raw)
    server = QueryServer(idx_raw, ServerConfig(result_cache=0,
                                               row_cache=0))
    loop = ServingLoop(server)
    lane = BulkLane(server, loop, chunk_terms=16).start()
    net = NetServer(loop).start()
    host, port = net.address
    pats = _patterns(c)
    try:
        with NetClient(host, port) as cl:
            assert cl.proto_version >= 3
            res = cl.bulk(pats, threshold=0.5, timeout_s=120.0)
            # an interactive query interleaves on the same session
            one = cl.search(pats[0], threshold=0.5)
            res_k = cl.bulk(pats, top_k=3, timeout_s=120.0)
        for pat, r in zip(pats, res):
            assert r.status == Status.OK and r.method == "bulk"
            want = engine.search(pat, threshold=0.5)
            np.testing.assert_array_equal(r.result.doc_ids, want.doc_ids)
            np.testing.assert_array_equal(r.result.scores, want.scores)
        for pat, r in zip(pats, res_k):
            want = engine.top_k(pat, k=3)
            np.testing.assert_array_equal(r.result.doc_ids, want.doc_ids)
            np.testing.assert_array_equal(r.result.scores, want.scores)
        assert one.status == Status.OK
    finally:
        net.close()


def test_bulk_frame_without_lane_rejected(stores):
    from repro.serve import NetClient, NetServer
    c, _, idx_raw, _, _ = stores
    server = QueryServer(idx_raw)
    loop = ServingLoop(server)                 # no BulkLane attached
    net = NetServer(loop).start()
    host, port = net.address
    try:
        with NetClient(host, port) as cl:
            res = cl.bulk(_patterns(c)[:3], threshold=0.5,
                          timeout_s=30.0)
        assert all(r.status == Status.REJECTED for r in res)
    finally:
        net.close()
