"""Multi-host sharded serving tests: ShardPlacement elasticity, sub-store
views, the ShardWorker/Frontend scatter-gather data plane, hedged dispatch
+ failover, and the double-buffered tile prefetch.

The load-bearing invariant: the sharded frontend's gathered results —
threshold hits AND top-k — are BIT-IDENTICAL to the single-host
QueryEngine across random placements, replication factors, and one failed
worker (property-tested below), because blocks partition the document
slots and the final gather sorts under the engine's exact total order.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (IndexParams, QueryEngine, build_compact,
                        open_substore)
from repro.core.query import plan_shards_subset
from repro.data import make_corpus, make_queries
from repro.index import ShardPlacement, ShardSim, build_compact_streaming
from repro.serve import Frontend, FrontendConfig, ShardWorker, Status

PARAMS = IndexParams(n_hashes=1, fpr=0.3, kmer=15)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    c = make_corpus(96, k=15, mean_length=400, sigma=1.0, seed=7)
    dense = build_compact(c.doc_terms, PARAMS, block_docs=32, row_align=64)
    store = tmp_path_factory.mktemp("mh-store") / "v2"
    mapped, _ = build_compact_streaming(c.doc_terms, store, PARAMS,
                                        block_docs=32, row_align=64)
    assert mapped.storage.n_shards >= 3      # queries cross host boundaries
    return c, dense, mapped, store


def _frontend(store, n_hosts, replication, *, latency_models=None,
              hedge_after_s=1e9, max_batch=8, verify=False) -> Frontend:
    nodes = [f"h{i}" for i in range(n_hosts)]
    place = ShardPlacement.for_store(store, nodes, replication=replication)
    held = place.replica_assignment()
    workers = {n: ShardWorker(n, store, held[n], verify=verify)
               for n in nodes if held[n]}
    return Frontend(workers, place,
                    FrontendConfig(max_batch=max_batch, max_wait_s=0.0,
                                   hedge_after_s=hedge_after_s),
                    latency_models=latency_models)


# --------------------------------------------------------------------------
# ShardPlacement
# --------------------------------------------------------------------------

def test_shard_placement_for_store(built):
    _, _, mapped, store = built
    p = ShardPlacement.for_store(store, ["a", "b"], replication=2)
    assert p.n_shards == mapped.storage.n_shards
    a = p.assignment()
    assert sorted(s for ss in a.values() for s in ss) == \
        list(range(p.n_shards))
    # every node must replicate its full assignment set
    ra = p.replica_assignment()
    for n, owned in a.items():
        assert set(owned) <= set(ra[n])


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 8), st.integers(10, 60), st.integers(1, 3))
def test_placement_elasticity_property(n_nodes, n_shards, replication):
    """HRW elasticity: adding a node moves ~replication * n_shards /
    (n_nodes + 1) shard replica slots in expectation — never the bulk of
    the index — and removing a node re-homes exactly its replica set."""
    replication = min(replication, n_nodes)
    nodes = [f"n{i}" for i in range(n_nodes)]
    p = ShardPlacement(nodes, n_shards, replication=replication)

    moved = p.add_node("fresh")
    frac = replication / (n_nodes + 1)
    expected = n_shards * frac
    # mean + 4 sigma of the per-shard Bernoulli(frac) bound
    bound = expected + 4.0 * np.sqrt(n_shards * frac * (1 - frac)) + 1
    assert len(moved) <= bound, (len(moved), bound)
    assert p.is_covered()
    # every moved shard now replicates on the new node
    assert all("fresh" in p.replicas(s) for s in moved)

    victim = nodes[n_shards % n_nodes]
    its_replicas = {s for s in range(n_shards)
                    if victim in p.replicas(s)}
    rehomed = p.remove_node(victim)
    assert set(rehomed) == its_replicas
    assert p.is_covered()


# --------------------------------------------------------------------------
# Sub-store views
# --------------------------------------------------------------------------

def test_substore_view_matches_dense_rows(built):
    _, dense, mapped, store = built
    n = mapped.storage.n_shards
    ids = [0, n - 1]
    sub = open_substore(store, ids)
    assert sub.shard_ids == tuple(ids)
    assert sub.n_shards_total == n
    arena = np.asarray(dense.arena)
    for local, g in enumerate(sub.shard_ids):
        r0 = int(sub.global_row_starts[g])
        r1 = int(sub.global_row_starts[g + 1])
        np.testing.assert_array_equal(sub.storage.shard_host(local),
                                      arena[r0:r1])
    # per-placement plans: global block ranges, shard-local row offsets
    plans = plan_shards_subset(sub.layout, sub.global_row_starts,
                               sub.shard_ids)
    assert [pl.shard for pl in plans] == [0, 1]
    assert plans[-1].block_end == dense.n_blocks
    for pl in plans:
        assert int(pl.row_offset[0]) == 0


def test_substore_rejects_bad_ids(built):
    *_, store = built
    with pytest.raises(ValueError):
        open_substore(store, [])
    with pytest.raises(ValueError):
        open_substore(store, [999])


def test_substore_verify_catches_corruption(tmp_path):
    """A flipped arena byte must be REFUSED at worker open, not silently
    mis-score queries on that host."""
    c = make_corpus(48, k=15, mean_length=300, sigma=1.0, seed=17)
    store = tmp_path / "v2"
    build_compact_streaming(c.doc_terms, store, PARAMS, block_docs=32,
                            row_align=64)
    victim = sorted(store.glob("shard-*.npy"))[1]
    a = np.load(victim)
    a[0, 0] ^= np.uint32(1)
    np.save(victim, a)
    open_substore(store, [0], verify=True)          # clean shard: fine
    with pytest.raises(IOError):
        open_substore(store, [0, 1], verify=True)
    with pytest.raises(IOError):
        ShardWorker("w", store, [1], verify=True)
    ShardWorker("w", store, [1])                    # lazy open: unchecked


# --------------------------------------------------------------------------
# Frontend == single-host engine (the acceptance property)
# --------------------------------------------------------------------------

_BUILT: dict = {}


@pytest.fixture(scope="module", autouse=True)
def _stash_built(built):
    # the @given property test below cannot take pytest fixtures (drawn
    # args are positional in both real hypothesis and the stub), so the
    # module fixture parks the shared store here
    _BUILT["x"] = built


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 5), st.integers(1, 3), st.integers(0, 10**6),
       st.integers(0, 1))
def test_frontend_bit_identical_property(n_hosts, replication, seed,
                                         fail_one):
    """Scatter/gather results — threshold hits and top-k — equal the
    single-host engine byte for byte, across placements, replication
    factors, and one failed worker."""
    c, dense, _, store = _BUILT["x"]
    replication = min(replication, n_hosts)
    eng = QueryEngine(dense)
    fe = _frontend(store, n_hosts, replication)
    if fail_one and replication >= 2:
        victim = fe.placement.owner(seed % fe.placement.n_shards)
        fe.fail_worker(victim)
        assert fe.placement.is_covered()

    qs, _ = make_queries(c, n_pos=3, n_neg=2, length=100,
                         seed=seed % 1000)
    tids = [fe.submit(q, threshold=0.7) for q in qs]
    kids = [fe.submit(q, top_k=1 + seed % 7) for q in qs]
    fe.drain()
    resp = fe.pop_responses()
    for rid, q in zip(tids, qs):
        want = eng.search(q, threshold=0.7)
        got = resp[rid].result
        np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(got.scores, want.scores)
        assert (got.n_terms, got.threshold) == (want.n_terms, want.threshold)
    for rid, q in zip(kids, qs):
        want = eng.top_k(q, k=1 + seed % 7)
        got = resp[rid].result
        np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
        np.testing.assert_array_equal(got.scores, want.scores)
        assert got.threshold == want.threshold


def test_frontend_failover_counts_and_recovery(built):
    c, dense, _, store = built
    fe = _frontend(store, 3, 2)
    eng = QueryEngine(dense)
    qs, _ = make_queries(c, n_pos=2, n_neg=1, length=90, seed=31)
    victim = fe.placement.owner(0)
    moved = fe.fail_worker(victim)
    assert moved and fe.placement.is_covered()
    ids = [fe.submit(q, threshold=0.7) for q in qs]
    fe.drain()
    resp = fe.pop_responses()
    for rid, q in zip(ids, qs):
        assert resp[rid].status == Status.OK
        np.testing.assert_array_equal(resp[rid].result.doc_ids,
                                      eng.search(q, 0.7).doc_ids)
    snap = fe.metrics.snapshot()
    assert snap.failovers > 0
    assert victim not in snap.worker_p99_ms     # dead host served nothing
    fe.recover_worker(victim)
    assert not fe.workers[victim].failed


def test_frontend_total_loss_answers_failed(built):
    """Coverage loss must not lose requests: a batch hitting a shard with
    no live replica comes back Status.FAILED, not an exception that eats
    the rids mid-serving-loop."""
    c, _, _, store = built
    fe = _frontend(store, 2, 1)                  # replication 1: no backup
    victim = fe.placement.owner(0)
    fe.fail_worker(victim)
    assert not fe.placement.is_covered()
    qs, _ = make_queries(c, n_pos=2, n_neg=0, length=90, seed=41)
    ids = [fe.submit(q, threshold=0.7) for q in qs]
    fe.drain()
    resp = fe.pop_responses()
    for rid in ids:
        assert resp[rid].status == Status.FAILED
        assert resp[rid].result is None
    assert fe.metrics.snapshot().failed == len(ids)


def test_frontend_rejects_missing_replica_worker(built):
    *_, store = built
    nodes = ["a", "b"]
    place = ShardPlacement.for_store(store, nodes, replication=2)
    held = place.replica_assignment()
    workers = {"a": ShardWorker("a", store, held["a"])}
    with pytest.raises(ValueError):
        Frontend(workers, place)


# --------------------------------------------------------------------------
# Hedged dispatch (deterministic clock)
# --------------------------------------------------------------------------

def test_hedging_cuts_p99_with_straggler(built):
    """The Tail-at-Scale acceptance: one straggling worker, deterministic
    latency models — hedging must pull p99 down to the hedge bound and
    results must stay bit-identical."""
    c, dense, _, store = built
    eng = QueryEngine(dense)
    qs, _ = make_queries(c, n_pos=4, n_neg=2, length=100, seed=51)
    p99 = {}
    for label, hedge_after in (("off", 1e9), ("on", 2e-3)):
        nodes = [f"h{i}" for i in range(3)]
        models = {n: ShardSim(n, base_latency=1e-3) for n in nodes}
        fe = _frontend(store, 3, 2, latency_models=models,
                       hedge_after_s=hedge_after)
        victim = fe.placement.owner(0)
        models[victim].straggle_until = 1e9
        models[victim].straggle_factor = 50.0
        ids = [fe.submit(q, threshold=0.7) for q in qs]
        fe.drain()
        resp = fe.pop_responses()
        for rid, q in zip(ids, qs):
            np.testing.assert_array_equal(resp[rid].result.doc_ids,
                                          eng.search(q, 0.7).doc_ids)
        snap = fe.metrics.snapshot()
        p99[label] = snap.p99_ms
        if label == "on":
            assert snap.hedges_fired > 0 and snap.hedges_won > 0
            assert snap.hedge_fire_rate > 0
        else:
            assert snap.hedges_fired == 0
    assert p99["on"] < p99["off"] / 2, p99


def test_hedge_latency_is_deterministic(built):
    c, _, _, store = built
    qs, _ = make_queries(c, n_pos=2, n_neg=0, length=90, seed=61)

    def run_once():
        models = {f"h{i}": ShardSim(f"h{i}", base_latency=1e-3)
                  for i in range(3)}
        fe = _frontend(store, 3, 2, latency_models=models,
                       hedge_after_s=5e-3)
        for q in qs:
            fe.submit(q, threshold=0.7)
        fe.drain()
        fe.pop_responses()
        return fe.metrics.snapshot()

    a, b = run_once(), run_once()
    assert (a.p50_ms, a.p99_ms) == (b.p50_ms, b.p99_ms)
    assert a.worker_p99_ms == b.worker_p99_ms


# --------------------------------------------------------------------------
# Double-buffered tile prefetch
# --------------------------------------------------------------------------

def test_engine_prefetches_next_shard(built):
    _, _, _, store = built
    from repro.core import load_index
    idx = load_index(store)
    eng = QueryEngine(idx)
    n = idx.storage.n_shards
    c, *_ = built
    q, _ = make_queries(c, n_pos=1, n_neg=0, length=100, seed=71)
    eng.search(q[0], 0.7)
    # cold pass: shard 0 demand-faults, every later shard was staged by the
    # double-buffer prefetch and consumed as a prefetch hit
    assert eng.tiles.faults == n
    assert eng.tiles.prefetched == n - 1
    assert eng.tiles.prefetch_hits == n - 1
    eng.search(q[0], 0.7)                        # warm: everything resident
    assert eng.tiles.faults == n and eng.tiles.prefetched == n - 1


def test_server_reports_prefetch_hit_rate(built):
    from repro.core import load_index
    from repro.serve import QueryServer, ServerConfig
    c, _, _, store = built
    server = QueryServer(load_index(store),
                         ServerConfig(max_batch=4, max_wait_s=0.0,
                                      result_cache=0, row_cache=0))
    qs, _ = make_queries(c, n_pos=3, n_neg=1, length=100, seed=81)
    for q in qs:
        server.submit(q, threshold=0.7)
    server.drain()
    snap = server.metrics.snapshot()
    assert snap.prefetched_tiles > 0
    assert snap.prefetch_hits == snap.prefetched_tiles
    assert snap.prefetch_hit_rate == 1.0
    assert "prefetch_hit_rate" in snap.report()


def test_frontend_prefetches_across_hosts(built):
    c, _, _, store = built
    fe = _frontend(store, 3, 2)
    qs, _ = make_queries(c, n_pos=2, n_neg=1, length=100, seed=91)
    for q in qs:
        fe.submit(q, threshold=0.7)
    fe.drain()
    snap = fe.metrics.snapshot()
    assert snap.prefetched_tiles > 0
    assert snap.prefetch_hit_rate > 0


def test_frontend_concurrent_scatter_matches_sequential(built):
    """Wall-clock dispatch through the scatter thread pool must gather
    bit-identically to sequential dispatch — same candidates, same shard
    order, failover included (one dead primary)."""
    c, _, _, store = built

    def run(threads):
        nodes = ["h0", "h1", "h2"]
        place = ShardPlacement.for_store(store, nodes, replication=2)
        held = place.replica_assignment()
        workers = {n: ShardWorker(n, store, held[n])
                   for n in nodes if held[n]}
        fe = Frontend(workers, place,
                      FrontendConfig(max_batch=8, max_wait_s=0.0,
                                     scatter_threads=threads))
        assert (fe._pool is not None) == (threads > 1)
        fe.fail_worker(place.owner(0))       # failover mid-scatter
        qs, _ = make_queries(c, n_pos=3, n_neg=2, length=100, seed=93)
        ids = [fe.submit(q, threshold=0.7) for q in qs]
        ids += [fe.submit(q, top_k=3) for q in qs]
        fe.drain()
        resp = fe.pop_responses()
        snap = fe.metrics.snapshot()
        return [(tuple(resp[i].result.doc_ids.tolist()),
                 tuple(resp[i].result.scores.tolist())) for i in ids], snap

    seq, snap_seq = run(1)
    con, snap_con = run(4)
    assert seq == con
    assert snap_con.failovers == snap_seq.failovers > 0


def test_frontend_concurrent_total_loss_answers_failed(built):
    """Every replica of a shard down -> the batch answers FAILED through
    the concurrent path too (no exception escapes the pool)."""
    c, _, _, store = built
    fe = _frontend(store, 2, 1)              # replication 1: no failover
    assert fe._pool is not None
    victim = fe.placement.owner(0)
    fe.workers[victim].fail()                # dead at call time
    qs, _ = make_queries(c, n_pos=2, n_neg=0, length=100, seed=95)
    ids = [fe.submit(q, threshold=0.7) for q in qs]
    fe.drain()
    resp = fe.pop_responses()
    assert all(resp[i].status == Status.FAILED for i in ids)
