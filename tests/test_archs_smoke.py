"""Per-architecture smoke tests (reduced configs): one forward + one train
step + prefill->decode consistency on CPU, asserting shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import make_decode_step, make_prefill_step
from repro.train import AdamWConfig, make_init_state, make_train_step

ARCHS = configs.list_archs()


def _batch(cfg, B=2, S=12, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.n_enc_layers:
        b["enc_feats"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get(arch, smoke=True)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: model.forward_train(
        p, b["tokens"], enc_feats=b.get("enc_feats")))(params, batch)
    assert logits.shape == (2, 12, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # every param has a logical-axes annotation of matching rank
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert p.ndim == len(a), (p.shape, a)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_decreases(arch):
    cfg = configs.get(arch, smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    state = jax.jit(make_init_state(model, opt))(jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]          # memorizes a fixed batch
    assert int(state.step) == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = configs.get(arch, smoke=True)
    if not cfg.has_decoder:
        pytest.skip("encoder-only arch has no decode step")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    toks, enc = batch["tokens"], batch.get("enc_feats")
    full, _ = jax.jit(lambda p, t: model.forward_train(
        p, t, enc_feats=enc))(params, toks)
    logits_pre, caches = jax.jit(make_prefill_step(model, S + 4,
                                                   last_only=False))(
        params, {"tokens": toks[:, :S - 2], "enc_feats": enc})
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full[:, :S - 2]),
                               rtol=2e-2, atol=2e-2)
    dec = jax.jit(make_decode_step(model))
    lg1, caches = dec(params, caches, toks[:, S - 2:S - 1],
                      jnp.asarray(S - 2, jnp.int32))
    lg2, _ = dec(params, caches, toks[:, S - 1:S],
                 jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1[:, 0]), np.asarray(full[:, S - 2]),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(full[:, S - 1]),
                               rtol=5e-2, atol=5e-2)


def test_full_configs_construct():
    """Full configs build (dataclass validation incl. layer-count math) and
    report sane parameter counts — no allocation happens here."""
    expected = {
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "phi4-mini-3.8b": (3.0e9, 4.6e9),
        "qwen3-4b": (3.0e9, 5.0e9),
        "qwen2.5-3b": (2.5e9, 3.9e9),
        "granite-3-8b": (7.0e9, 9.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),
        "qwen2-vl-7b": (6.5e9, 9e9),
        "xlstm-125m": (0.10e9, 0.30e9),
    }
    for arch in ARCHS:
        cfg = configs.get(arch)
        lo, hi = expected[arch]
        n = cfg.param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller():
    cfg = configs.get("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()


def test_vision_stub_prefix_embedding():
    cfg = configs.get("qwen2-vl-7b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S, P = 2, 12, 4
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    vis = jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32)
    with_vis, _ = jax.jit(lambda p: model.forward_train(
        p, toks, vis_embeds=vis))(params)
    without, _ = jax.jit(lambda p: model.forward_train(p, toks))(params)
    # causal: suffix logits must differ (vision prefix attended), shapes equal
    assert with_vis.shape == without.shape
    assert not np.allclose(np.asarray(with_vis[:, -1]),
                           np.asarray(without[:, -1]))
