import pytest

from repro.index import BlockPlacement


def test_deterministic_assignment():
    p1 = BlockPlacement(["n0", "n1", "n2"], n_blocks=50)
    p2 = BlockPlacement(["n0", "n1", "n2"], n_blocks=50)
    assert p1.assignment() == p2.assignment()


def test_all_blocks_assigned_and_balanced():
    p = BlockPlacement([f"n{i}" for i in range(8)], n_blocks=400)
    a = p.assignment()
    total = sum(len(v) for v in a.values())
    assert total == 400
    sizes = [len(v) for v in a.values()]
    assert min(sizes) > 20 and max(sizes) < 90  # ~50 each, HRW-balanced


def test_replicas_distinct():
    p = BlockPlacement([f"n{i}" for i in range(5)], n_blocks=100, replication=3)
    for b in range(100):
        r = p.replicas(b)
        assert len(r) == 3 and len(set(r)) == 3


def test_failover_keeps_coverage():
    p = BlockPlacement([f"n{i}" for i in range(6)], n_blocks=200, replication=2)
    moved = p.fail("n2")
    assert p.is_covered()
    assert all(p.owner(b) != "n2" for b in range(200))
    # only blocks whose primary was n2 moved
    assert all("n2" in p.replicas(b) for b in moved)


def test_double_failure_may_lose_coverage():
    p = BlockPlacement(["a", "b"], n_blocks=20, replication=2)
    p.fail("a")
    p.fail("b")
    assert not p.is_covered()
    with pytest.raises(RuntimeError):
        p.owner(0)


def test_recover_restores_primary():
    p = BlockPlacement([f"n{i}" for i in range(4)], n_blocks=100)
    before = p.assignment()
    p.fail("n1")
    rebuild = p.recover("n1")
    assert p.assignment() == before
    # rebuild set is exactly n1's replica blocks
    assert all("n1" in p.replicas(b) for b in rebuild)


def test_elastic_add_moves_minority():
    p = BlockPlacement([f"n{i}" for i in range(8)], n_blocks=800, replication=2)
    moved = p.add_node("n8")
    # HRW: expected moved fraction ~ replication/(n+1) = 2/9 ~ 178 blocks
    assert 0.10 * 800 < len(moved) < 0.35 * 800
    assert p.is_covered()


def test_elastic_remove_rehomes_only_its_blocks():
    p = BlockPlacement([f"n{i}" for i in range(8)], n_blocks=800, replication=2)
    served = set()
    for b in range(800):
        if "n3" in p.replicas(b):
            served.add(b)
    moved = p.remove_node("n3")
    assert set(moved) == served
    assert p.is_covered()


def test_validation():
    with pytest.raises(ValueError):
        BlockPlacement([], n_blocks=10)
    with pytest.raises(ValueError):
        BlockPlacement(["a"], n_blocks=10, replication=0)
    p = BlockPlacement(["a"], n_blocks=10)
    with pytest.raises(KeyError):
        p.fail("nope")
