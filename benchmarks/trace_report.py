"""Replay a slow-query trace log (JSONL) into human-readable reports.

The serving stack's Tracer emits every finished trace slower than
``--trace-slow-ms`` to an EventLog (``launch/serve.py --trace-log``).
Each event carries the request's FLAT span list — stages run on
different threads, so the stack never materializes a tree — and this
tool reconstructs the hierarchy from the span intervals:

    python -m benchmarks.trace_report /tmp/slow.jsonl
    python -m benchmarks.trace_report /tmp/slow.jsonl --top 5
    python -m benchmarks.trace_report /tmp/slow.jsonl --summary

* default: the slowest ``--top`` traces rendered as indented span
  trees (a span nests under the smallest span that encloses it), with
  per-span duration, self-time, and tags;
* ``--summary``: per-stage totals across every trace in the log —
  where did the slow requests actually spend their time?
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path


def load_traces(path) -> list[dict]:
    """slow_query events from a JSONL event log (other kinds skipped,
    torn trailing lines tolerated)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue                    # torn tail from a live writer
            if ev.get("kind") == "slow_query":
                out.append(ev)
    return out


def build_tree(spans: list[dict]) -> list[dict]:
    """Nest each span under the SMALLEST span that encloses it (ties
    break to the earlier-listed span); returns the forest of roots.
    Same-name spans never nest — a parallel fan-out stage (hedged
    ``shard_dispatch``) emits overlapping intervals that are siblings,
    not ancestry. Every node gains ``children`` and ``self_ms``
    (duration minus the children's coverage)."""
    nodes = [dict(s, children=[]) for s in spans]
    order = sorted(range(len(nodes)),
                   key=lambda i: (nodes[i]["start_s"], -nodes[i]["end_s"]))
    roots: list[dict] = []
    for idx in order:
        n = nodes[idx]
        parent = None
        for jdx in order:
            if jdx == idx:
                continue
            c = nodes[jdx]
            if c["name"] == n["name"]:
                continue
            if c["start_s"] <= n["start_s"] and n["end_s"] <= c["end_s"]:
                if (c["end_s"] - c["start_s"]) >= (n["end_s"] - n["start_s"]):
                    if parent is None or (
                            (c["end_s"] - c["start_s"])
                            < (parent["end_s"] - parent["start_s"])):
                        parent = c
        if parent is not None and parent is not n:
            parent["children"].append(n)
        else:
            roots.append(n)
    for n in nodes:
        dur = n["end_s"] - n["start_s"]
        covered = sum(c["end_s"] - c["start_s"] for c in n["children"])
        n["self_ms"] = max(0.0, dur - covered) * 1e3
    return roots


def _fmt_tags(tags: dict) -> str:
    if not tags:
        return ""
    body = " ".join(f"{k}={v}" for k, v in tags.items())
    return f"  [{body}]"


def render_tree(trace: dict) -> str:
    lines = [f"trace {trace['trace_id']} request {trace['request_id']} "
             f"— {trace['duration_ms']:.3f} ms, "
             f"{len(trace['spans'])} spans"]

    def walk(node: dict, depth: int) -> None:
        dur_ms = (node["end_s"] - node["start_s"]) * 1e3
        off_ms = (node["start_s"] - trace["started_s"]) * 1e3
        lines.append(f"  {'  ' * depth}{node['name']:<16} "
                     f"+{off_ms:8.3f} ms  {dur_ms:9.3f} ms "
                     f"(self {node['self_ms']:.3f})"
                     f"{_fmt_tags(node.get('tags', {}))}")
        for c in sorted(node["children"], key=lambda s: s["start_s"]):
            walk(c, depth + 1)

    for root in sorted(build_tree(trace["spans"]),
                       key=lambda s: s["start_s"]):
        walk(root, 0)
    return "\n".join(lines)


def stage_summary(traces: list[dict]) -> str:
    """Aggregate per-stage attribution across the whole log."""
    total_ms: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    for t in traces:
        for s in t["spans"]:
            total_ms[s["name"]] += (s["end_s"] - s["start_s"]) * 1e3
            count[s["name"]] += 1
    grand = sum(t["duration_ms"] for t in traces) or 1.0
    lines = [f"{len(traces)} slow traces, {grand:.1f} ms total",
             f"{'stage':<18}{'spans':>7}{'total ms':>12}{'mean ms':>10}"
             f"{'% of wall':>11}"]
    for name in sorted(total_ms, key=total_ms.get, reverse=True):
        lines.append(f"{name:<18}{count[name]:>7}{total_ms[name]:>12.3f}"
                     f"{total_ms[name] / count[name]:>10.3f}"
                     f"{100.0 * total_ms[name] / grand:>10.1f}%")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("log", type=Path, help="slow-query JSONL event log")
    ap.add_argument("--top", type=int, default=3,
                    help="render the N slowest traces (default 3)")
    ap.add_argument("--summary", action="store_true",
                    help="per-stage totals across the whole log instead "
                         "of individual trace trees")
    args = ap.parse_args()
    traces = load_traces(args.log)
    if not traces:
        raise SystemExit(f"no slow_query events in {args.log}")
    if args.summary:
        print(stage_summary(traces))
        return
    worst = sorted(traces, key=lambda t: t["duration_ms"],
                   reverse=True)[: args.top]
    for i, t in enumerate(worst):
        if i:
            print()
        print(render_tree(t))


if __name__ == "__main__":
    main()
