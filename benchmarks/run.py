"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name] [--quick]

Prints ``name,us_per_call,derived`` CSV rows (also written to
results/benchmarks.csv). Paper analogues:
    construction -> Table 2       query  -> Table 3 (times)
    fpr          -> Table 3 (FPR) scaling -> Fig. 6/7
    compaction   -> Fig. 4        kernel -> engineering section 2.3 (SIMD)
    hedging      -> DESIGN.md straggler mitigation
Roofline terms (deliverable g) come from the dry-run artifacts:
    PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.jsonl
    PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora (CI)")
    args = ap.parse_args()

    from . import common
    from . import (bulk, compaction, compression, construction, fpr,
                   hedging, kernel_micro, outofcore, pruning, query,
                   scaling, serving)

    n = 128 if args.quick else 512
    suites = {
        "construction": lambda: construction.run(n),
        "query": lambda: query.run(n),
        "fpr": lambda: fpr.run(n, n_probes=100 if args.quick else 300),
        "scaling": lambda: scaling.run((64, 128) if args.quick
                                       else (64, 128, 256, 512)),
        "compaction": lambda: compaction.run(64 if args.quick else 256),
        "kernel": lambda: kernel_micro.run(quick=args.quick),
        "hedging": hedging.run,
        "serving": lambda: serving.run(64 if args.quick else 256,
                                       n_queries=48 if args.quick else 96),
        "serving_multihost": lambda: serving.run_multihost(
            96 if args.quick else 256,
            n_queries=24 if args.quick else 64,
            max_hosts=2 if args.quick else 3),
        "outofcore": lambda: outofcore.run(64 if args.quick else 256,
                                           n_queries=8 if args.quick else 16),
        "compression": lambda: compression.run(
            16 if args.quick else 24,
            n_queries=12 if args.quick else 24,
            reps_levels=(1, 4) if args.quick else (1, 4, 8)),
        "pruning": lambda: pruning.run(
            96 if args.quick else 128,
            n_queries=6 if args.quick else 8,
            thresholds=(0.5, 0.8, 1.0) if args.quick
            else (0.3, 0.5, 0.8, 0.9, 1.0),
            selectivities=(0.0, 0.25) if args.quick else (0.0, 0.05, 0.25),
            chunks=(16,) if args.quick else (16, 32)),
        "bulk": lambda: bulk.run(
            96 if args.quick else 160,
            n_queries=64 if args.quick else 256,
            codecs=("raw",) if args.quick else ("raw", "rowdict"),
            max_batch=8 if args.quick else 32,
            p99_queries=24 if args.quick else 48),
    }
    print("name,us_per_call,derived")
    kernel_report = None
    compression_report = None
    pruning_report = None
    bulk_report = None
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        res = fn()
        if name == "kernel":
            kernel_report = res
        elif name == "compression":
            compression_report = res
        elif name == "pruning":
            pruning_report = res
        elif name == "bulk":
            bulk_report = res

    out = Path("results")
    out.mkdir(exist_ok=True)
    with (out / "benchmarks.csv").open("w") as f:
        f.write("name,us_per_call,derived\n")
        for row in common.ROWS:
            f.write(f"{row[0]},{row[1]:.1f},{row[2]}\n")
    print(f"# wrote results/benchmarks.csv ({len(common.ROWS)} rows)",
          file=sys.stderr)
    if kernel_report is not None:
        import json
        kernel_json = out / "BENCH_kernels.json"
        kernel_json.write_text(json.dumps(kernel_report, indent=2))
        print(f"# wrote {kernel_json} (overlap sweep + DMA accounting)",
              file=sys.stderr)
    if compression_report is not None:
        import json
        comp_json = out / "BENCH_compression.json"
        comp_json.write_text(json.dumps(compression_report, indent=2))
        print(f"# wrote {comp_json} (ratio x decode x e2e sweep)",
              file=sys.stderr)
    if pruning_report is not None:
        import json
        prune_json = out / "BENCH_pruning.json"
        prune_json.write_text(json.dumps(pruning_report, indent=2))
        print(f"# wrote {prune_json} (threshold x selectivity x chunk sweep)",
              file=sys.stderr)
    if bulk_report is not None:
        import json
        bulk_json = out / "BENCH_bulk.json"
        bulk_json.write_text(json.dumps(bulk_report, indent=2))
        print(f"# wrote {bulk_json} (staged-bytes amortization + p99 "
              f"protection)", file=sys.stderr)


if __name__ == "__main__":
    main()
