"""Pruned scoring: threshold x selectivity x chunk-size sweep with exact
bytes-read accounting.

The tentpole claim of threshold-driven pruned scoring is that block-level
early exit turns the coverage threshold into an I/O budget: once a
block's running count plus its remaining term budget cannot reach
``ceil(threshold * ell)``, that block's tile rows are never read, staged,
or scored again. The win must show up in BYTES, not just kernel time —
so each cell of the sweep runs the chunked executor against a fresh
engine (cold tile cache) and reports:

  bytes_read  — exact host arena bytes the pruned run touched
                (``PruneStats.bytes_read``: row gathers + any promoted
                full-tile stagings);
  baseline    — what exhaustive paged scoring stages for the same batch
                with a cold cache: every shard tile once,
                ``sum(shard_hbm_nbytes)``;
  reduction   — baseline / bytes_read (the headline: >= 3x at
                threshold >= 0.8 on a selective corpus);
  prune_rate  — fraction of (query, block) cells eliminated early;
  identical   — pruned hits AND scores bit-equal to the exhaustive
                QueryEngine oracle (hard assertion, threshold and top-k).

Selectivity levels plant a shared motif in a fraction of the corpus: a
query drawn from the motif matches that fraction of documents, so "sel"
is the fraction of docs a query is designed to hit (0 = pure negative
queries, the most prunable workload).

``--json`` writes results/BENCH_pruning.json for CI trend tracking.
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

import numpy as np

from repro.core import IndexParams, QueryEngine
from repro.core.query import PruneStats
from repro.index import build_compact_streaming

from .common import emit, timeit

_BASES = "ACGT"


def _rand_seq(rng, n: int) -> str:
    return "".join(_BASES[i] for i in rng.integers(0, 4, size=n))


def _build_corpus(n_docs: int, doc_len: int, sel: float, seed: int = 0
                  ) -> tuple[list[str], str]:
    """Corpus where ``sel * n_docs`` documents share a planted motif.
    Returns (documents, motif)."""
    rng = np.random.default_rng(seed)
    motif = _rand_seq(rng, doc_len // 2)
    n_hit = int(round(sel * n_docs))
    docs = []
    for i in range(n_docs):
        if i < n_hit:
            pad = _rand_seq(rng, doc_len - len(motif))
            docs.append(pad[: len(pad) // 2] + motif + pad[len(pad) // 2:])
        else:
            docs.append(_rand_seq(rng, doc_len))
    return docs, motif


def _queries(docs: list[str], motif: str, n_queries: int, q_len: int,
             seed: int = 7) -> list[str]:
    """Half motif-derived (hit the planted fraction), half random
    negatives (hit nothing above noise)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_queries):
        if i % 2 == 0 and len(motif) >= q_len:
            j = int(rng.integers(0, len(motif) - q_len + 1))
            out.append(motif[j: j + q_len])
        else:
            out.append(_rand_seq(rng, q_len))
    return out


def run(n_docs: int = 128, n_queries: int = 8, *,
        thresholds: tuple[float, ...] = (0.3, 0.5, 0.8, 0.9, 1.0),
        selectivities: tuple[float, ...] = (0.0, 0.05, 0.25),
        chunks: tuple[int, ...] = (16, 32)) -> dict:
    params = IndexParams(n_hashes=1, fpr=0.03, kmer=15)
    report: dict = {"params": {"n_docs": n_docs, "n_queries": n_queries},
                    "cells": [], "identical": True}
    for sel in selectivities:
        docs, motif = _build_corpus(n_docs, 320, sel)
        pats = _queries(docs, motif, n_queries, 140)
        tmp = Path(tempfile.mkdtemp(prefix="cobs-prune-"))
        try:
            from repro.core import dna
            terms = [dna.unique_terms(dna.pack_kmers(
                dna.encode_dna(d), params.kmer, params.canonical))
                for d in docs]
            index, _ = build_compact_streaming(
                terms, tmp / "store", params, block_docs=32,
                blocks_per_shard=1)
            storage = index.storage
            baseline = sum(int(storage.shard_hbm_nbytes(s))
                           for s in range(storage.n_shards))
            oracle_eng = QueryEngine(index, method="lookup")
            t_base = timeit(lambda: oracle_eng.search_batch(
                pats, threshold=0.8), repeats=3)
            for thr in thresholds:
                oracle = oracle_eng.search_batch(pats, threshold=thr)
                for chunk in chunks:
                    # fresh engine per cell: cold tile cache, so the
                    # byte accounting is exact and unshared
                    eng = QueryEngine(index, method="lookup",
                                     prune_chunk=chunk)
                    stats = PruneStats()
                    pruned = eng.search_batch_pruned(pats, threshold=thr,
                                                     stats=stats)
                    same = all(
                        np.array_equal(a.doc_ids, b.doc_ids)
                        and np.array_equal(a.scores, b.scores)
                        for a, b in zip(pruned, oracle))
                    assert same, (f"pruned != oracle at thr={thr} "
                                  f"sel={sel} chunk={chunk}")
                    eng_t = QueryEngine(index, method="lookup",
                                        prune_chunk=chunk)
                    t_pruned = timeit(lambda: eng_t.search_batch_pruned(
                        pats, threshold=thr), repeats=3)
                    reduction = baseline / max(1, stats.bytes_read)
                    tag = (f"thr={thr};sel={sel};chunk={chunk};"
                           f"reduction={reduction:.1f}x;"
                           f"prune_rate={stats.prune_rate:.2f}")
                    emit(f"pruning/t{thr}_s{sel}_c{chunk}",
                         t_pruned * 1e6 / len(pats), tag)
                    report["cells"].append({
                        "threshold": thr, "selectivity": sel,
                        "chunk": chunk,
                        "bytes_read": int(stats.bytes_read),
                        "baseline_bytes": baseline,
                        "bytes_reduction": round(reduction, 2),
                        "prune_rate": round(stats.prune_rate, 4),
                        "blocks_pruned": int(stats.blocks_pruned),
                        "blocks_total": int(stats.blocks_total),
                        "tiles_promoted": int(stats.tiles_promoted),
                        "shard_visits_skipped":
                            int(stats.shard_visits_skipped),
                        "pruned_us_per_query":
                            round(t_pruned * 1e6 / len(pats), 1),
                        "exhaustive_us_per_query":
                            round(t_base * 1e6 / len(pats), 1),
                        "identical": bool(same),
                    })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    # acceptance: >= 3x bytes reduction at threshold >= 0.8 on the most
    # selective corpus, with bit-identical results everywhere
    best = max((c["bytes_reduction"] for c in report["cells"]
                if c["threshold"] >= 0.8), default=0.0)
    report["best_reduction_thr_ge_0.8"] = round(best, 2)
    emit("pruning/best_reduction", best * 1000,
         f"best_bytes_reduction_at_thr>=0.8={best:.1f}x;unit=milli")
    return report


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the sweep report to this path")
    args = ap.parse_args()
    report = run(n_docs=96 if args.quick else 128,
                 n_queries=6 if args.quick else 8,
                 thresholds=(0.5, 0.8, 1.0) if args.quick
                 else (0.3, 0.5, 0.8, 0.9, 1.0),
                 selectivities=(0.0, 0.25) if args.quick
                 else (0.0, 0.05, 0.25),
                 chunks=(16,) if args.quick else (16, 32))
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
