"""Out-of-core query execution: cold mmap paging vs warm device tile cache.

The paper's scaling claim is that COBS streams its index instead of
holding it in RAM; the cost model for the reproduction is

  cold  — v2 store just opened, nothing resident: every shard is a page
          fault (OS reads the .npy) plus a host->device stage.
  warm  — all tiles resident in the DeviceTileCache: queries only gather
          and score, identical to the dense in-HBM engine.
  evict — tile budget of ONE shard: steady-state thrash, the worst case
          (every shard re-paged per query) that bounds cold latency.

Reported ratios quantify what the LRU tile cache buys at serve time.
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

from repro.core import DeviceTileCache, IndexParams, QueryEngine
from repro.core.store import load_index_v2
from repro.data import make_queries
from repro.index import build_compact_streaming

from .common import corpus, emit, timeit


def run(n_docs: int = 256, n_queries: int = 16) -> dict:
    c = corpus(n_docs)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    tmp = Path(tempfile.mkdtemp(prefix="cobs-ooc-"))
    try:
        # block_docs=32 keeps several shards even at --quick corpus sizes
        # (paging behavior is the thing under test)
        _, stats = build_compact_streaming(c.doc_terms, tmp, params,
                                           block_docs=32)
        qs, _ = make_queries(c, n_pos=n_queries // 2, n_neg=n_queries // 2,
                             length=120, seed=5)
        queries = list(qs)

        def run_queries(engine):
            for q in queries:
                engine.search(q, threshold=0.7)

        # warmup one engine for jit compilation so timings are paging, not
        # tracing (every variant below reuses the same compiled kernels)
        warm_idx = load_index_v2(tmp)
        warm_eng = QueryEngine(warm_idx, method="lookup")
        run_queries(warm_eng)

        def cold():
            idx = load_index_v2(tmp)      # fresh mmaps, empty tile cache
            run_queries(QueryEngine(idx, method="lookup"))

        t_cold = timeit(cold, repeats=2, warmup=0)
        t_warm = timeit(lambda: run_queries(warm_eng), repeats=2, warmup=1)

        evict_idx = load_index_v2(tmp)
        evict_eng = QueryEngine(
            evict_idx, method="lookup",
            tile_cache=DeviceTileCache(evict_idx.storage,
                                       capacity_bytes=stats.max_shard_bytes))
        run_queries(evict_eng)            # warm the jit, thrash the tiles
        t_evict = timeit(lambda: run_queries(evict_eng), repeats=2, warmup=0)

        per_q = 1e6 / len(queries)
        emit("outofcore/query_cold_mmap", t_cold * per_q,
             f"n_docs={n_docs};shards={stats.n_shards}")
        emit("outofcore/query_warm_tiles", t_warm * per_q,
             f"n_docs={n_docs};resident={len(warm_eng.tiles)}")
        emit("outofcore/query_tile_thrash", t_evict * per_q,
             f"n_docs={n_docs};budget=1_shard;"
             f"faults={evict_eng.tiles.faults}")
        emit("outofcore/cold_over_warm", t_cold / max(t_warm, 1e-12),
             "paging_cost_ratio")
        return {"t_cold": t_cold, "t_warm": t_warm, "t_evict": t_evict}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
