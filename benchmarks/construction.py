"""Paper Table 2: construction wall-clock time, memory, and index size,
ClaBS (classic) vs COBS (compact), plus the parallel/block-checkpointed
builder. Times scale with corpus size; the paper's qualitative claims to
reproduce are (i) compact builds are not slower than classic, and (ii) the
compact index is substantially smaller on size-skewed corpora."""
from __future__ import annotations

from repro.core import IndexParams, build_classic, build_compact
from repro.index import build_compact_parallel

from .common import corpus, emit, timeit


def run(n_docs: int = 512) -> dict:
    c = corpus(n_docs)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)

    t_classic = timeit(lambda: build_classic(c.doc_terms, params), repeats=2)
    t_compact = timeit(lambda: build_compact(c.doc_terms, params,
                                             block_docs=64), repeats=2)
    t_parallel = timeit(lambda: build_compact_parallel(
        c.doc_terms, params, block_docs=64, workers=4), repeats=2)

    classic = build_classic(c.doc_terms, params)
    compact = build_compact(c.doc_terms, params, block_docs=64)

    emit("construction/classic_build", t_classic * 1e6,
         f"n_docs={n_docs};index_MiB={classic.size_bytes()/2**20:.1f}")
    emit("construction/compact_build", t_compact * 1e6,
         f"n_docs={n_docs};index_MiB={compact.size_bytes()/2**20:.1f}")
    emit("construction/compact_parallel_build", t_parallel * 1e6,
         f"n_docs={n_docs};workers=4")
    ratio = classic.size_bytes() / compact.size_bytes()
    emit("construction/size_ratio_classic_over_compact", ratio,
         "paper_fig4_expect>1.5")
    return {"t_classic": t_classic, "t_compact": t_compact,
            "size_ratio": ratio}
