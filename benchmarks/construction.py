"""Paper Table 2: construction wall-clock time, memory, and index size,
ClaBS (classic) vs COBS (compact), plus the parallel/block-checkpointed
builder and the STREAMING (out-of-core) builder. Times scale with corpus
size; the paper's qualitative claims to reproduce are (i) compact builds
are not slower than classic, (ii) the compact index is substantially
smaller on size-skewed corpora, and (iii) streaming construction's peak
host memory is one block group, not the arena."""
from __future__ import annotations

import resource
import shutil
import tempfile
from pathlib import Path

from repro.core import IndexParams, build_classic, build_compact
from repro.index import build_compact_parallel, build_compact_streaming

from .common import corpus, emit, timeit


def _rss_mib() -> float:
    """Process high-water RSS in MiB (ru_maxrss is KiB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def run(n_docs: int = 512) -> dict:
    c = corpus(n_docs)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)

    tmp = Path(tempfile.mkdtemp(prefix="cobs-stream-"))

    def stream_once():
        shutil.rmtree(tmp, ignore_errors=True)
        return build_compact_streaming(c.doc_terms, tmp, params,
                                       block_docs=64)

    # Stream FIRST: ru_maxrss is a process-lifetime high-water mark, so the
    # delta is only meaningful before the dense builders materialize the
    # whole arena in this process.
    rss_before = _rss_mib()
    t_stream = timeit(stream_once, repeats=2)
    _, stats = stream_once()
    rss_after = _rss_mib()
    shutil.rmtree(tmp, ignore_errors=True)

    t_classic = timeit(lambda: build_classic(c.doc_terms, params), repeats=2)
    t_compact = timeit(lambda: build_compact(c.doc_terms, params,
                                             block_docs=64), repeats=2)
    t_parallel = timeit(lambda: build_compact_parallel(
        c.doc_terms, params, block_docs=64, workers=4), repeats=2)

    classic = build_classic(c.doc_terms, params)
    compact = build_compact(c.doc_terms, params, block_docs=64)

    emit("construction/classic_build", t_classic * 1e6,
         f"n_docs={n_docs};index_MiB={classic.size_bytes()/2**20:.1f}")
    emit("construction/compact_build", t_compact * 1e6,
         f"n_docs={n_docs};index_MiB={compact.size_bytes()/2**20:.1f}")
    emit("construction/compact_parallel_build", t_parallel * 1e6,
         f"n_docs={n_docs};workers=4")
    emit("construction/compact_streaming_build", t_stream * 1e6,
         f"n_docs={n_docs};peak_block_MiB={stats.peak_block_bytes/2**20:.2f};"
         f"arena_MiB={stats.total_arena_bytes/2**20:.2f};"
         f"rss_delta_MiB={max(0.0, rss_after - rss_before):.1f};"
         f"shards={stats.n_shards}")
    ratio = classic.size_bytes() / compact.size_bytes()
    emit("construction/size_ratio_classic_over_compact", ratio,
         "paper_fig4_expect>1.5")
    oo_ratio = stats.total_arena_bytes / max(stats.peak_block_bytes, 1)
    emit("construction/arena_over_streaming_peak", oo_ratio,
         "out_of_core_bound:peak_host=one_block_group")
    return {"t_classic": t_classic, "t_compact": t_compact,
            "t_stream": t_stream, "size_ratio": ratio,
            "stream_peak_ratio": oo_ratio}
