"""Paper Table 3: query wall-clock time by query length, cold (r0) vs warm
(r2) jit caches, across scoring methods (ref oracle, paper-faithful unpack
kernel, beyond-paper vertical kernel, fused lookup kernel)."""
from __future__ import annotations

import numpy as np

from repro.core import QueryEngine
from repro.data import make_queries

from .common import built_indexes, emit, timeit


def run(n_docs: int = 512) -> dict:
    c, classic, compact = built_indexes(n_docs)
    out = {}
    for ell in (15, 100, 1000):
        n_q = 64 if ell <= 100 else 16
        queries, _ = make_queries(c, n_pos=n_q // 2, n_neg=n_q // 2,
                                  length=max(ell, c.k), seed=ell)
        for idx_name, idx in (("classic", classic), ("compact", compact)):
            for method in ("ref", "unpack", "vertical"):
                eng = QueryEngine(idx, method=method)
                # r0: cold (includes jit compile); r2: warm
                import time
                t0 = time.perf_counter()
                eng.search_batch(queries, threshold=0.8)
                r0 = time.perf_counter() - t0
                r2 = timeit(lambda: eng.search_batch(queries, threshold=0.8),
                            repeats=2, warmup=0)
                per_q = r2 / len(queries)
                emit(f"query/{idx_name}/{method}/len{ell}", per_q * 1e6,
                     f"r0_s={r0:.2f};r2_s={r2:.3f};n_q={len(queries)}")
                out[(idx_name, method, ell)] = per_q
    return out
