"""Paper Fig. 4: the staircase — compact index size vs classic as a
function of document-size skew (sigma of the log-normal). At sigma=0
(uniform sizes) compaction buys nothing; the win grows with skew."""
from __future__ import annotations

from repro.core import IndexParams, build_classic, build_compact
from repro.data import make_corpus

from .common import emit


def run(n_docs: int = 256) -> dict:
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    out = {}
    for sigma in (0.0, 0.5, 1.0, 1.5):
        c = make_corpus(n_docs, k=15, mean_length=1000, sigma=max(sigma, 1e-6),
                        seed=42)
        classic = build_classic(c.doc_terms, params, row_align=64)
        compact = build_compact(c.doc_terms, params, block_docs=32,
                                row_align=64)
        ratio = classic.size_bytes() / compact.size_bytes()
        emit(f"compaction/size_ratio/sigma{sigma}", ratio,
             f"classic_MiB={classic.size_bytes()/2**20:.2f};"
             f"compact_MiB={compact.size_bytes()/2**20:.2f}")
        out[sigma] = ratio
    return out
