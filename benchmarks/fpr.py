"""Paper Table 3 (bottom): document false-positive rate for single-k-mer
queries vs the prescribed 0.3, and the Theorem 1 zero-FP prediction for
long queries — the paper's core accuracy claims."""
from __future__ import annotations

import numpy as np

from repro.core import QueryEngine, dna, theory
from repro.data import make_queries

from .common import built_indexes, emit


def run(n_docs: int = 512, n_probes: int = 300) -> dict:
    c, classic, compact = built_indexes(n_docs)
    rng = np.random.default_rng(9)
    universe = set()
    for t in c.doc_terms:
        u = t[:, 0].astype(np.uint64) | (t[:, 1].astype(np.uint64) << np.uint64(32))
        universe |= set(u.tolist())

    out = {}
    for name, idx in (("classic", classic), ("compact", compact)):
        eng = QueryEngine(idx)
        hits = total = 0
        probes = 0
        while probes < n_probes:
            kmer = rng.integers(0, 4, c.k, dtype=np.uint8)
            t = dna.pack_kmers(kmer, c.k)
            if (int(t[0, 0]) | (int(t[0, 1]) << 32)) in universe:
                continue
            probes += 1
            scores = eng.score_terms(t)
            hits += int((scores >= 1).sum())
            total += idx.n_docs
        measured = hits / total
        predicted = float(idx.expected_fpr().mean())
        emit(f"fpr/{name}/single_kmer_measured", measured * 1e6,
             f"predicted={predicted:.4f};prescribed=0.3")
        out[name] = (measured, predicted)

    # long queries: zero false positives at K=0.8 (paper: ell >= 100)
    queries, origin = make_queries(c, n_pos=0, n_neg=30, length=100, seed=77)
    eng = QueryEngine(compact)
    fps = sum(len(r.doc_ids) for r in eng.search_batch(queries, threshold=0.8))
    thm = theory.query_fpr(100 - c.k + 1, 0.3, 0.8) * compact.n_docs * len(queries)
    emit("fpr/compact/long_query_false_positives", float(fps),
         f"theorem1_expected={thm:.2e}")
    out["long_fp"] = fps
    return out
