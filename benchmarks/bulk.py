"""Bulk lane: staged-bytes amortization and interactive-p99 protection.

The tentpole claim of the offline bulk lane is that inverting the loop
order — stage each shard tile into HBM once and stream the WHOLE query
set against it, instead of restaging tiles for every micro-batch — cuts
arena bytes staged per query by the number of micro-batches the
interactive lane would have needed. The win must show up in BYTES, so
each cell runs the same query set down both lanes against a fresh
server (cold, one-shard-sized tile cache so interactive restaging is
real) and reports:

  interactive_B_per_q — tile-cache bytes staged by the query-major lane
                        (max_batch-sized micro-batches, each sweeping
                        every shard) divided by the query count;
  bulk_B_per_q        — BulkStats.bytes_staged for the shard-major
                        sweep of the same set (each tile staged once);
  amortization        — interactive / bulk (the headline: >= 5x for a
                        scan-sized set);
  identical           — bulk hits AND scores bit-equal to the
                        QueryEngine oracle (hard assertion, threshold
                        and top-k, raw and rowdict codecs).

The second table measures the scheduling contract: interactive p99 with
a bulk sweep running (yield points at shard boundaries) versus with the
lane idle — the sweep must not blow up tail latency.

``--json`` writes results/BENCH_bulk.json for CI trend tracking.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import IndexParams, QueryEngine
from repro.data import make_corpus
from repro.index import build_compact_streaming
from repro.serve import (BulkLane, BulkStatus, QueryServer, ServerConfig,
                         ServingLoop, Status)

from .common import emit

PARAMS = IndexParams(n_hashes=1, fpr=0.03, kmer=15)


def _corpus_and_queries(n_docs: int, n_queries: int, seed: int = 0):
    c = make_corpus(max(16, n_docs // 4), k=15, mean_length=200,
                    min_length=150, seed=seed)
    terms = [c.doc_terms[i % len(c.doc_terms)] for i in range(n_docs)]
    rng = np.random.default_rng(seed + 1)
    pats = []
    for i in range(n_queries):
        if i % 2 == 0:
            d = c.documents[int(rng.integers(len(c.documents)))]
            j = int(rng.integers(0, max(1, len(d) - 70)))
            pats.append(d[j: j + 70])
        else:
            pats.append("".join(rng.choice(list("ACGT"), size=70)))
    return terms, pats


def _interactive_staged(index, pats, threshold, *, max_batch, tile_bytes
                        ) -> tuple[int, float]:
    """(bytes staged, wall seconds) for the query-major lane with a
    one-shard cache — every micro-batch restages every shard."""
    srv = QueryServer(index, ServerConfig(
        max_batch=max_batch, tile_cache_bytes=tile_bytes,
        result_cache=0, row_cache=0))
    t0 = time.perf_counter()
    for i in range(0, len(pats), max_batch):
        for p in pats[i:i + max_batch]:
            srv.submit(p, threshold=threshold)
        srv.drain()
    wall = time.perf_counter() - t0
    return srv.tiles.raw_bytes_staged + srv.tiles.comp_bytes_staged, wall


def _latencies(loop, pats, threshold) -> np.ndarray:
    done = threading.Event()
    lat: list[float] = []
    lock = threading.Lock()
    for p in pats:
        t0 = time.perf_counter()

        def cb(resp, t0=t0):
            with lock:
                lat.append(time.perf_counter() - t0)
                if len(lat) == len(pats):
                    done.set()
        loop.submit(p, threshold=threshold, on_done=cb)
        time.sleep(0.002)
    assert done.wait(300.0), "interactive queries never completed"
    return np.asarray(lat)


def run(n_docs: int = 128, n_queries: int = 256, *,
        codecs: tuple[str, ...] = ("raw", "rowdict"),
        threshold: float = 0.5, max_batch: int = 32,
        p99_queries: int = 48) -> dict:
    report: dict = {"params": {"n_docs": n_docs, "n_queries": n_queries,
                               "max_batch": max_batch,
                               "threshold": threshold},
                    "cells": [], "identical": True}
    terms, pats = _corpus_and_queries(n_docs, n_queries)
    for codec in codecs:
        tmp = Path(tempfile.mkdtemp(prefix="cobs-bulk-"))
        try:
            index, _ = build_compact_streaming(
                terms, tmp / "store", PARAMS, block_docs=32,
                blocks_per_shard=1, codec=codec)
            storage = index.storage
            tile_bytes = max(storage.shard_nbytes(s)
                             for s in range(storage.n_shards))
            comp = codec != "raw"
            oracle = QueryEngine(index, compressed=comp).search_batch(
                pats, threshold=threshold)

            inter_bytes, inter_wall = _interactive_staged(
                index, pats, threshold, max_batch=max_batch,
                tile_bytes=tile_bytes)

            srv = QueryServer(index, ServerConfig(
                tile_cache_bytes=tile_bytes, result_cache=0,
                row_cache=0))
            lane = BulkLane(srv)
            t0 = time.perf_counter()
            job = lane.submit(pats, threshold=threshold)
            lane.drain()
            bulk_wall = time.perf_counter() - t0
            assert job.status is BulkStatus.DONE, job.error
            same = all(np.array_equal(a.doc_ids, b.doc_ids)
                       and np.array_equal(a.scores, b.scores)
                       for a, b in zip(job.results, oracle))
            assert same, f"bulk != oracle for codec={codec}"
            # top-k down the same lane, same bit-identity bar
            k_oracle = [QueryEngine(index, compressed=comp).top_k(p, k=5)
                        for p in pats[:16]]
            job_k = lane.submit(pats[:16], top_k=5)
            lane.drain()
            assert job_k.status is BulkStatus.DONE, job_k.error
            same_k = all(np.array_equal(a.doc_ids, b.doc_ids)
                         and np.array_equal(a.scores, b.scores)
                         for a, b in zip(job_k.results, k_oracle))
            assert same_k, f"bulk top-k != oracle for codec={codec}"

            inter_pq = inter_bytes / len(pats)
            bulk_pq = job.staged_bytes_per_query
            amort = inter_pq / max(1.0, bulk_pq)
            tag = (f"codec={codec};amortization={amort:.1f}x;"
                   f"tiles_staged={job.stats.tiles_staged};"
                   f"prune_rate={job.stats.prune_rate:.2f}")
            emit(f"bulk/staged_{codec}", bulk_wall * 1e6 / len(pats), tag)
            report["cells"].append({
                "codec": codec,
                "interactive_bytes": int(inter_bytes),
                "bulk_bytes": int(job.stats.bytes_staged),
                "interactive_B_per_q": round(inter_pq, 1),
                "bulk_B_per_q": round(bulk_pq, 1),
                "amortization": round(amort, 2),
                "tiles_staged": int(job.stats.tiles_staged),
                "shards": int(storage.n_shards),
                "query_chunks": int(job.stats.query_chunks),
                "kernel_dispatches": int(job.stats.kernel_dispatches),
                "prune_rate": round(job.stats.prune_rate, 4),
                "interactive_wall_s": round(inter_wall, 3),
                "bulk_wall_s": round(bulk_wall, 3),
                "identical": bool(same and same_k),
            })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    # -- preemption: interactive p99 with and without a sweep in flight --
    tmp = Path(tempfile.mkdtemp(prefix="cobs-bulk-p99-"))
    try:
        index, _ = build_compact_streaming(
            terms, tmp / "store", PARAMS, block_docs=32,
            blocks_per_shard=1, codec="raw")
        srv = QueryServer(index, ServerConfig(
            result_cache=0, row_cache=0, max_wait_s=0.0))
        loop = ServingLoop(srv).start()
        lane = BulkLane(srv, loop, chunk_terms=16).start()
        ipats = pats[:p99_queries]
        try:
            _latencies(loop, ipats, threshold)        # warm compile
            base = _latencies(loop, ipats, threshold)
            job = lane.submit(pats * 2, threshold=threshold)
            under = _latencies(loop, ipats, threshold)
            assert job.wait(600.0), "bulk sweep never finished"
            assert job.status is BulkStatus.DONE, job.error
        finally:
            loop.stop()
        p99_off = float(np.percentile(base, 99))
        p99_on = float(np.percentile(under, 99))
        snap = srv.metrics.snapshot()
        report["preemption"] = {
            "p99_ms_bulk_off": round(p99_off * 1e3, 2),
            "p99_ms_bulk_on": round(p99_on * 1e3, 2),
            "p99_ratio": round(p99_on / max(p99_off, 1e-9), 3),
            "bulk_yields": int(snap.bulk_yields),
            "bulk_shards_swept": int(snap.bulk_shards_swept),
        }
        emit("bulk/p99_protection", p99_on * 1e6,
             f"p99_off_us={p99_off * 1e6:.0f};"
             f"ratio={p99_on / max(p99_off, 1e-9):.2f};"
             f"yields={snap.bulk_yields}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    best = max((c["amortization"] for c in report["cells"]), default=0.0)
    report["best_amortization"] = round(best, 2)
    emit("bulk/best_amortization", best * 1000,
         f"best_staged_bytes_amortization={best:.1f}x;unit=milli")
    return report


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the sweep report to this path")
    args = ap.parse_args()
    report = run(n_docs=96 if args.quick else 160,
                 n_queries=64 if args.quick else 256,
                 codecs=("raw",) if args.quick else ("raw", "rowdict"),
                 max_batch=8 if args.quick else 32,
                 p99_queries=24 if args.quick else 48)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
