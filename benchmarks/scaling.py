"""Paper Fig. 6/7: construction time, index size, and query time as |D|
grows — the scalability claims. Fig. 7's key observation to reproduce:
COBS' per-document index size DECREASES with |D| (better block packing)
while classic grows with the maximum document."""
from __future__ import annotations

from repro.core import IndexParams, QueryEngine, build_classic, build_compact
from repro.data import make_queries

from .common import corpus, emit, timeit


def run(sizes=(64, 128, 256, 512)) -> dict:
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    out = {}
    for n in sizes:
        c = corpus(n)
        t_build = timeit(lambda: build_compact(c.doc_terms, params,
                                               block_docs=64), repeats=1)
        compact = build_compact(c.doc_terms, params, block_docs=64)
        classic = build_classic(c.doc_terms, params)
        queries, _ = make_queries(c, n_pos=16, n_neg=16, length=100,
                                  seed=n)
        eng = QueryEngine(compact)
        t_query = timeit(lambda: eng.search_batch(queries, threshold=0.8),
                         repeats=2)
        emit(f"scaling/build_per_doc/n{n}", t_build / n * 1e6,
             f"total_s={t_build:.2f}")
        emit(f"scaling/compact_bytes_per_doc/n{n}",
             compact.size_bytes() / n,
             f"classic_bytes_per_doc={classic.size_bytes() / n:.0f}")
        emit(f"scaling/query_per_batch32/n{n}",
             t_query / len(queries) * 32 * 1e6, "")
        out[n] = {"build": t_build, "query": t_query,
                  "compact_bytes": compact.size_bytes(),
                  "classic_bytes": classic.size_bytes()}
    return out
