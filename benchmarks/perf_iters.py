import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (device count must be locked before any jax import — same as dryrun.py)

import argparse
import dataclasses
import json
import time

import jax

from repro import configs
from repro.launch import analysis, sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, make_cell
from repro.models.partition import partitioning

"""§Perf hillclimb driver: lowers VARIANT configurations of the three chosen
cells and reports the roofline-term deltas vs the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iters --cell moe|granite|cobs|all

Each iteration is a (hypothesis, change) pair; results append to
results/perf_iters.jsonl and are written up in EXPERIMENTS.md §Perf.
"""


def lower_cell(arch, shape_name, cfg_override=None, mesh=None):
    mesh = mesh or make_production_mesh()
    cell = make_cell(arch, shape_name, mesh)
    if cfg_override is not None:
        new_cfg = cfg_override(cell.cfg)
        from repro.launch import specs as specs_mod
        import repro.configs as cfgs
        orig_get = cfgs.get
        try:
            cfgs.get = lambda a, smoke=False: new_cfg
            cell = make_cell(arch, shape_name, mesh)
        finally:
            cfgs.get = orig_get
    t0 = time.time()
    with mesh, partitioning(mesh, shd.act_rules_for(mesh)):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        compiled = jitted.lower(*cell.args).compile()
    roof = analysis.analyze(compiled, cell.cfg, cell.shape,
                            chips=mesh.devices.size)
    mem = analysis.memory_analysis_dict(compiled)
    return {"roofline": roof.as_dict(), "memory": mem,
            "compile_s": round(time.time() - t0, 1)}


def report(tag, rec):
    rf = rec["roofline"]
    print(f"{tag:40s} t_comp={rf['t_compute_s']:.3f}s "
          f"t_mem={rf['t_memory_s']:.4f}s t_coll={rf['t_collective_s']:.3f}s "
          f"temp={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.1f}GiB "
          f"[{rf['bottleneck']}]")
    with open("results/perf_iters.jsonl", "a") as f:
        f.write(json.dumps({"tag": tag, **rec}) + "\n")


def cell_moe():
    from repro.launch.mesh import make_mesh
    print("== Cell A: qwen3-moe-30b-a3b x train_4k ==")
    local = lambda c: dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, dispatch="local"))
    report("A0 baseline einsum-dispatch", lower_cell(
        "qwen3-moe-30b-a3b", "train_4k"))
    report("A1 local shard_map dispatch", lower_cell(
        "qwen3-moe-30b-a3b", "train_4k", local))
    # A2: same 256 chips, refactored logical mesh (data=32, model=8):
    # TP activation all-reduces halve; experts still divide (128/8=16).
    report("A2 local dispatch + mesh(32,8)", lower_cell(
        "qwen3-moe-30b-a3b", "train_4k", local,
        mesh=make_mesh((32, 8), ("data", "model"))))
    report("A3 local dispatch + mesh(64,4)", lower_cell(
        "qwen3-moe-30b-a3b", "train_4k", local,
        mesh=make_mesh((64, 4), ("data", "model"))))


def cell_granite():
    from repro.launch.mesh import make_mesh
    print("== Cell B: granite-3-8b x prefill_32k ==")
    report("B1 flat-head + pinned kv-block layout", lower_cell(
        "granite-3-8b", "prefill_32k"))
    # B2: logical mesh refactor (data=32, model=8): kv=8 now DIVIDES the
    # model axis -> cache shards on kv (not head_dim), TP AR bytes halve.
    report("B2 + mesh(32,8)", lower_cell(
        "granite-3-8b", "prefill_32k",
        mesh=make_mesh((32, 8), ("data", "model"))))
    report("B3 + mesh(64,4)", lower_cell(
        "granite-3-8b", "prefill_32k",
        mesh=make_mesh((64, 4), ("data", "model"))))


def cell_cobs():
    print("== Cell C: cobs-index distributed query ==")
    import jax.numpy as jnp
    from repro.launch.dryrun import run_cobs_cell
    mesh = make_production_mesh()
    variants = [
        ("C0 baseline gather+vertical/int32", dict()),
        ("C1 fused lookup kernel", dict(score_method="lookup")),
        ("C2 fused lookup + int16 psum", dict(score_method="lookup",
                                              score_dtype=jnp.int16)),
    ]
    for tag, kw in variants:
        rec = run_cobs_cell(mesh, "single-pod-16x16", **kw)
        if rec["status"] != "ok":
            print(tag, "ERROR", rec.get("error"))
            continue
        print(f"{tag:40s} flops/chip={rec['flops_per_chip']:.3e} "
              f"bytes/chip={rec['bytes_per_chip']:.3e} "
              f"coll/chip={rec['coll_bytes_per_chip']:.3e} "
              f"t_mem={rec['bytes_per_chip']/819e9*1e3:.3f}ms "
              f"t_coll={rec['coll_bytes_per_chip']/50e9*1e6:.1f}us")
        with open("results/perf_iters.jsonl", "a") as f:
            f.write(json.dumps({"tag": tag, **rec}) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["moe", "granite", "cobs", "all"])
    args = ap.parse_args()
    os.makedirs("results", exist_ok=True)
    if args.cell in ("moe", "all"):
        cell_moe()
    if args.cell in ("granite", "all"):
        cell_granite()
    if args.cell in ("cobs", "all"):
        cell_cobs()


if __name__ == "__main__":
    main()
