"""Tail-latency benchmark for the straggler-mitigation policy (DESIGN.md
§4): p50/p99 with and without hedged execution under 10% stragglers."""
from __future__ import annotations

import random

from repro.index import HedgedExecutor, ShardSim

from .common import emit


def _run(hedge_after: float, max_hedges: int, n=2000) -> tuple[float, float]:
    rng = random.Random(0)
    shards = {f"s{i}": ShardSim(f"s{i}", base_latency=1.0) for i in range(8)}
    ex = HedgedExecutor(shards=shards, hedge_after=hedge_after,
                        max_hedges=max_hedges)
    for q in range(n):
        for s in ex.shards.values():
            s.straggle_until = -1.0
        if rng.random() < 0.10:
            ex.shards["s0"].straggle_until = ex.clock.now + 1e9
        ex.run_query(q, ["s0", "s1", "s2"])
    return ex.percentile(0.5), ex.percentile(0.99)


def run() -> dict:
    p50_off, p99_off = _run(hedge_after=1e9, max_hedges=0)
    p50_on, p99_on = _run(hedge_after=2.0, max_hedges=1)
    emit("hedge/off/p99_latency", p99_off * 1e6, f"p50={p50_off}")
    emit("hedge/on/p99_latency", p99_on * 1e6,
         f"p50={p50_on};p99_improvement={p99_off / p99_on:.1f}x")
    return {"off": (p50_off, p99_off), "on": (p50_on, p99_on)}
