"""Serving benchmark: throughput and latency through the full
micro-batching stack (repro.serve) vs offered load.

Closed-loop sweeps measure capacity at several concurrency windows;
open-loop replays Poisson arrivals at increasing qps until the measured
latency shows queueing. Also reports the batched fused-lookup kernel
against the old per-query path (the regression the multi-query kernel
exists to fix: batched compact-index lookups used to fall back to the
pure-jnp ref scorer)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import QueryEngine
from repro.data import make_queries
from repro.launch.serve import make_workload, run_closed, run_open
from repro.serve import QueryServer, ServerConfig

from .common import built_indexes, emit


def _fresh_server(index, max_batch: int = 32) -> QueryServer:
    return QueryServer(index, ServerConfig(max_batch=max_batch,
                                           max_wait_s=0.0))


def _warm(server: QueryServer, run_once) -> None:
    """Replay the measured routine once so the timed run pays no jit
    compiles (closed-loop batch formation is deterministic; open-loop is
    near-identical), then clear the caches it filled."""
    run_once()
    server.pop_responses()
    server.reset_metrics(clear_caches=True)


def run(n_docs: int = 256, n_queries: int = 96) -> dict:
    c, classic, compact = built_indexes(n_docs)
    queries, _ = make_workload(c, n_queries, seed=71)
    out = {}

    # -- closed loop: capacity vs concurrency window ------------------------
    for conc in (1, 8, 32):
        server = _fresh_server(compact)
        _warm(server, lambda: run_closed(server, queries, 0.8, conc))
        t0 = time.perf_counter()
        run_closed(server, queries, 0.8, conc)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/closed/conc{conc}", wall / snap.served * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};occ={snap.mean_occupancy:.2f}")
        out[("closed", conc)] = qps

    # -- open loop: latency vs offered load ---------------------------------
    base_qps = out[("closed", 32)]
    for frac in (0.25, 0.75):
        offered = max(10.0, base_qps * frac)
        server = _fresh_server(compact)
        _warm(server, lambda: run_open(server, queries, 0.8, offered))
        t0 = time.perf_counter()
        run_open(server, queries, 0.8, offered)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        emit(f"serving/open/load{int(frac * 100)}",
             wall / snap.served * 1e6,
             f"offered_qps={offered:.0f};achieved_qps={snap.served / wall:.0f};"
             f"p50_ms={snap.p50_ms:.2f};p99_ms={snap.p99_ms:.2f}")
        out[("open", frac)] = snap.served / wall

    # -- fused multi-query kernel vs vmapped gather on batched lookups ------
    batch, _ = make_queries(c, n_pos=16, n_neg=16, length=120, seed=5)
    for method in ("lookup", "vertical"):
        eng = QueryEngine(compact, method=method)
        eng.search_batch(batch, threshold=0.8)      # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            eng.search_batch(batch, threshold=0.8)
        per_q = (time.perf_counter() - t0) / reps / len(batch)
        emit(f"serving/batch32/{method}", per_q * 1e6,
             f"n_q={len(batch)}")
        out[("batch", method)] = per_q
    return out
