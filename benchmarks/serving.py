"""Serving benchmark: throughput and latency through the full
micro-batching stack (repro.serve) vs offered load.

Closed-loop sweeps measure capacity at several concurrency windows;
open-loop replays Poisson arrivals at increasing qps until the measured
latency shows queueing. Also reports the batched fused-lookup kernel
against the old per-query path (the regression the multi-query kernel
exists to fix: batched compact-index lookups used to fall back to the
pure-jnp ref scorer).

``run_multihost`` drives the sharded data plane (ShardWorker + Frontend
over a v2 store): wall-clock scale-out 1 -> N fake hosts, plus the
deterministic-clock tail-latency scenario — one worker straggles 20x and
the hedged dispatch path must pull p99 back to the hedge bound ('The
Tail at Scale' win, measured end to end through the serving stack rather
than in pure simulation like benchmarks/hedging.py).

    PYTHONPATH=src python -m benchmarks.serving --hosts 3 \\
        --json results/serving_multihost.json
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import QueryEngine
from repro.data import make_queries
from repro.index.hedge import ShardSim
from repro.launch.serve import (make_multihost_frontend, make_workload,
                                run_closed, run_open)

from repro.serve import QueryServer, ServerConfig

from .common import built_indexes, corpus, emit


def _fresh_server(index, max_batch: int = 32) -> QueryServer:
    return QueryServer(index, ServerConfig(max_batch=max_batch,
                                           max_wait_s=0.0))


def _warm(server: QueryServer, run_once) -> None:
    """Replay the measured routine once so the timed run pays no jit
    compiles (closed-loop batch formation is deterministic; open-loop is
    near-identical), then clear the caches it filled."""
    run_once()
    server.pop_responses()
    server.reset_metrics(clear_caches=True)


def run(n_docs: int = 256, n_queries: int = 96) -> dict:
    c, classic, compact = built_indexes(n_docs)
    queries, _ = make_workload(c, n_queries, seed=71)
    out = {}

    # -- closed loop: capacity vs concurrency window ------------------------
    for conc in (1, 8, 32):
        server = _fresh_server(compact)
        _warm(server, lambda: run_closed(server, queries, 0.8, conc))
        t0 = time.perf_counter()
        run_closed(server, queries, 0.8, conc)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/closed/conc{conc}", wall / snap.served * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};occ={snap.mean_occupancy:.2f}")
        out[("closed", conc)] = qps

    # -- open loop: latency vs offered load ---------------------------------
    base_qps = out[("closed", 32)]
    for frac in (0.25, 0.75):
        offered = max(10.0, base_qps * frac)
        server = _fresh_server(compact)
        _warm(server, lambda: run_open(server, queries, 0.8, offered))
        t0 = time.perf_counter()
        run_open(server, queries, 0.8, offered)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        emit(f"serving/open/load{int(frac * 100)}",
             wall / snap.served * 1e6,
             f"offered_qps={offered:.0f};achieved_qps={snap.served / wall:.0f};"
             f"p50_ms={snap.p50_ms:.2f};p99_ms={snap.p99_ms:.2f}")
        out[("open", frac)] = snap.served / wall

    # -- fused multi-query kernel vs vmapped gather on batched lookups ------
    batch, _ = make_queries(c, n_pos=16, n_neg=16, length=120, seed=5)
    for method in ("lookup", "vertical"):
        eng = QueryEngine(compact, method=method)
        eng.search_batch(batch, threshold=0.8)      # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            eng.search_batch(batch, threshold=0.8)
        per_q = (time.perf_counter() - t0) / reps / len(batch)
        emit(f"serving/batch32/{method}", per_q * 1e6,
             f"n_q={len(batch)}")
        out[("batch", method)] = per_q
    return out


def _build_store(n_docs: int, root):
    """A v2 shard store for the multi-host benches (shard-per-block),
    written under the caller-owned ``root`` directory."""
    from pathlib import Path

    from repro.core import IndexParams
    from repro.index import build_compact_streaming

    c = corpus(n_docs)
    store = Path(root) / "v2"
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    build_compact_streaming(c.doc_terms, store, params, block_docs=32,
                            row_align=64)
    return c, store


def run_multihost(n_docs: int = 256, n_queries: int = 64,
                  max_hosts: int = 3) -> dict:
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        return _run_multihost(td, n_docs, n_queries, max_hosts)


def _run_multihost(tmp_root, n_docs: int, n_queries: int,
                   max_hosts: int) -> dict:
    c, store = _build_store(n_docs, tmp_root)
    queries, _ = make_workload(c, n_queries, seed=73)
    out = {}

    # -- wall-clock scale-out: 1 -> N fake hosts ----------------------------
    for hosts in range(1, max_hosts + 1):
        fe = make_multihost_frontend(
            store, hosts=hosts, replication=min(2, hosts),
            max_batch=32, max_wait_s=0.0,
            hedge_after_s=1e9)                # capacity run: no hedges
        _warm(fe, lambda: run_closed(fe, queries, 0.8, 32))
        t0 = time.perf_counter()
        run_closed(fe, queries, 0.8, 32)
        wall = time.perf_counter() - t0
        snap = fe.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/multihost/hosts{hosts}", wall / snap.served * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};shards={fe.placement.n_shards};"
             f"prefetch_hit_rate={snap.prefetch_hit_rate:.2f}")
        out[("hosts", hosts)] = qps

    # -- deterministic-clock tail latency: one straggling worker ------------
    # Every dispatch latency is simulated (injected SimClock), so the p99
    # numbers are exact policy outcomes, not host noise: without hedging a
    # query whose shard lands on the straggler eats the 20x latency; with
    # hedging the backup replica answers at hedge_after + base.
    base, straggle = 1e-3, 20.0
    for label, hedge_after in (("hedge_off", 1e9), ("hedge_on", 2e-3)):
        nodes = [f"host{i}" for i in range(max(3, max_hosts))]
        models = {n: ShardSim(n, base_latency=base) for n in nodes}
        fe = make_multihost_frontend(
            store, hosts=len(nodes), replication=2,
            max_batch=8, max_wait_s=0.0, hedge_after_s=hedge_after,
            latency_models=models)
        # straggle a node that actually OWNS a shard (the executor shares
        # the ShardSim objects, so mutating the model after wiring works)
        victim = fe.placement.owner(0)
        models[victim].straggle_until = 1e9
        models[victim].straggle_factor = straggle
        run_closed(fe, queries, 0.8, 8)       # results real, time simulated
        snap = fe.metrics.snapshot()
        emit(f"serving/multihost/{label}/p99", snap.p99_ms * 1e3,
             f"p50_ms={snap.p50_ms:.3f};p99_ms={snap.p99_ms:.3f};"
             f"hedge_rate={snap.hedge_fire_rate:.3f};"
             f"hedges_won={snap.hedges_won}")
        out[label] = (snap.p50_ms, snap.p99_ms)
    p99_off, p99_on = out["hedge_off"][1], out["hedge_on"][1]
    if p99_on > 0:
        emit("serving/multihost/hedge_p99_improvement", p99_off / p99_on,
             f"off={p99_off:.3f}ms;on={p99_on:.3f}ms")
    return out


def main() -> None:
    """CLI for CI artifacts: run the multi-host scale-out + hedging bench
    and dump the emitted rows as a BENCH json."""
    import argparse
    import json
    from pathlib import Path

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=3,
                    help="scale-out sweep upper bound (1..N fake hosts)")
    ap.add_argument("--n-docs", type=int, default=128)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--json", default=None,
                    help="write emitted rows as a json artifact here")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    run_multihost(args.n_docs, args.queries, max_hosts=args.hosts)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        rows = [{"name": n, "us_per_call": v, "derived": d}
                for n, v, d in common.ROWS]
        out.write_text(json.dumps({"bench": "serving_multihost",
                                   "hosts": args.hosts,
                                   "rows": rows}, indent=2))
        print(f"# wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
