"""Serving benchmark: throughput and latency through the full
micro-batching stack (repro.serve) vs offered load.

Closed-loop sweeps measure capacity at several concurrency windows;
open-loop replays Poisson arrivals at increasing qps until the measured
latency shows queueing. Also reports the batched fused-lookup kernel
against the old per-query path (the regression the multi-query kernel
exists to fix: batched compact-index lookups used to fall back to the
pure-jnp ref scorer).

``run_multihost`` drives the sharded data plane (ShardWorker + Frontend
over a v2 store): wall-clock scale-out 1 -> N fake hosts, plus the
deterministic-clock tail-latency scenario — one worker straggles 20x and
the hedged dispatch path must pull p99 back to the hedge bound ('The
Tail at Scale' win, measured end to end through the serving stack rather
than in pure simulation like benchmarks/hedging.py).

``run_net`` measures the NETWORK path: an in-process NetServer (active
ServingLoop + wire protocol on an ephemeral TCP port) under N concurrent
NetClient sessions. Closed loop: every client pipelines a window of
queries — concurrent independent clients must coalesce into shared
micro-batches (coalesce rate > 1 is the acceptance datum). Overload: a
small-queue-cap server takes a burst several times its cap, and every
single request must come back with SOME status (OK or the 429-style
REJECTED) — nothing silently lost, nothing hung. Open loop: Poisson
arrivals across the client fleet.

``run_rpc`` measures the RPC data plane (``--rpc``): the same queries
through the in-process scatter frontend vs an RpcFrontend whose every
shard dispatch is a real SHARD_QUERY/SHARD_RESULT socket round trip to
an in-process WorkerServer fleet (dispatch-overhead delta), then the
hedged-cancel win — a wall-clock straggling worker, hedge-off vs
hedge-on p99, with the loser's worker-side ``cancelled_tiles`` counter
reported as the 'observably cancelled' datum.

    PYTHONPATH=src python -m benchmarks.serving --hosts 3 \\
        --json results/serving_multihost.json
    PYTHONPATH=src python -m benchmarks.serving --listen \\
        --json results/BENCH_net_serving.json
    PYTHONPATH=src python -m benchmarks.serving --rpc \\
        --json results/BENCH_rpc.json
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import QueryEngine
from repro.data import make_queries
from repro.index.hedge import ShardSim
from repro.launch.serve import (make_multihost_frontend, make_workload,
                                run_closed, run_open)

from repro.serve import QueryServer, ServerConfig

from .common import built_indexes, corpus, emit


def _fresh_server(index, max_batch: int = 32) -> QueryServer:
    return QueryServer(index, ServerConfig(max_batch=max_batch,
                                           max_wait_s=0.0))


def _warm(server: QueryServer, run_once) -> None:
    """Replay the measured routine once so the timed run pays no jit
    compiles (closed-loop batch formation is deterministic; open-loop is
    near-identical), then clear the caches it filled."""
    run_once()
    server.pop_responses()
    server.reset_metrics(clear_caches=True)


def run(n_docs: int = 256, n_queries: int = 96) -> dict:
    c, classic, compact = built_indexes(n_docs)
    queries, _ = make_workload(c, n_queries, seed=71)
    out = {}

    # -- closed loop: capacity vs concurrency window ------------------------
    for conc in (1, 8, 32):
        server = _fresh_server(compact)
        _warm(server, lambda: run_closed(server, queries, 0.8, conc))
        t0 = time.perf_counter()
        run_closed(server, queries, 0.8, conc)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/closed/conc{conc}", wall / snap.served * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};occ={snap.mean_occupancy:.2f}")
        out[("closed", conc)] = qps

    # -- open loop: latency vs offered load ---------------------------------
    base_qps = out[("closed", 32)]
    for frac in (0.25, 0.75):
        offered = max(10.0, base_qps * frac)
        server = _fresh_server(compact)
        _warm(server, lambda: run_open(server, queries, 0.8, offered))
        t0 = time.perf_counter()
        run_open(server, queries, 0.8, offered)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        emit(f"serving/open/load{int(frac * 100)}",
             wall / snap.served * 1e6,
             f"offered_qps={offered:.0f};achieved_qps={snap.served / wall:.0f};"
             f"p50_ms={snap.p50_ms:.2f};p99_ms={snap.p99_ms:.2f}")
        out[("open", frac)] = snap.served / wall

    # -- fused multi-query kernel vs vmapped gather on batched lookups ------
    batch, _ = make_queries(c, n_pos=16, n_neg=16, length=120, seed=5)
    for method in ("lookup", "vertical"):
        eng = QueryEngine(compact, method=method)
        eng.search_batch(batch, threshold=0.8)      # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            eng.search_batch(batch, threshold=0.8)
        per_q = (time.perf_counter() - t0) / reps / len(batch)
        emit(f"serving/batch32/{method}", per_q * 1e6,
             f"n_q={len(batch)}")
        out[("batch", method)] = per_q
    return out


def _build_store(n_docs: int, root):
    """A v2 shard store for the multi-host benches (shard-per-block),
    written under the caller-owned ``root`` directory."""
    from pathlib import Path

    from repro.core import IndexParams
    from repro.index import build_compact_streaming

    c = corpus(n_docs)
    store = Path(root) / "v2"
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    build_compact_streaming(c.doc_terms, store, params, block_docs=32,
                            row_align=64)
    return c, store


def run_multihost(n_docs: int = 256, n_queries: int = 64,
                  max_hosts: int = 3) -> dict:
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        return _run_multihost(td, n_docs, n_queries, max_hosts)


def _run_multihost(tmp_root, n_docs: int, n_queries: int,
                   max_hosts: int) -> dict:
    c, store = _build_store(n_docs, tmp_root)
    queries, _ = make_workload(c, n_queries, seed=73)
    out = {}

    # -- wall-clock scale-out: 1 -> N fake hosts ----------------------------
    for hosts in range(1, max_hosts + 1):
        fe = make_multihost_frontend(
            store, hosts=hosts, replication=min(2, hosts),
            max_batch=32, max_wait_s=0.0,
            hedge_after_s=1e9)                # capacity run: no hedges
        _warm(fe, lambda: run_closed(fe, queries, 0.8, 32))
        t0 = time.perf_counter()
        run_closed(fe, queries, 0.8, 32)
        wall = time.perf_counter() - t0
        snap = fe.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/multihost/hosts{hosts}", wall / snap.served * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};shards={fe.placement.n_shards};"
             f"prefetch_hit_rate={snap.prefetch_hit_rate:.2f}")
        out[("hosts", hosts)] = qps

    # -- deterministic-clock tail latency: one straggling worker ------------
    # Every dispatch latency is simulated (injected SimClock), so the p99
    # numbers are exact policy outcomes, not host noise: without hedging a
    # query whose shard lands on the straggler eats the 20x latency; with
    # hedging the backup replica answers at hedge_after + base.
    base, straggle = 1e-3, 20.0
    for label, hedge_after in (("hedge_off", 1e9), ("hedge_on", 2e-3)):
        nodes = [f"host{i}" for i in range(max(3, max_hosts))]
        models = {n: ShardSim(n, base_latency=base) for n in nodes}
        fe = make_multihost_frontend(
            store, hosts=len(nodes), replication=2,
            max_batch=8, max_wait_s=0.0, hedge_after_s=hedge_after,
            latency_models=models)
        # straggle a node that actually OWNS a shard (the executor shares
        # the ShardSim objects, so mutating the model after wiring works)
        victim = fe.placement.owner(0)
        models[victim].straggle_until = 1e9
        models[victim].straggle_factor = straggle
        run_closed(fe, queries, 0.8, 8)       # results real, time simulated
        snap = fe.metrics.snapshot()
        emit(f"serving/multihost/{label}/p99", snap.p99_ms * 1e3,
             f"p50_ms={snap.p50_ms:.3f};p99_ms={snap.p99_ms:.3f};"
             f"hedge_rate={snap.hedge_fire_rate:.3f};"
             f"hedges_won={snap.hedges_won}")
        out[label] = (snap.p50_ms, snap.p99_ms)
    p99_off, p99_on = out["hedge_off"][1], out["hedge_on"][1]
    if p99_on > 0:
        emit("serving/multihost/hedge_p99_improvement", p99_off / p99_on,
             f"off={p99_off:.3f}ms;on={p99_on:.3f}ms")
    return out


# --------------------------------------------------------------------------
# RPC data plane: real per-shard sockets, cancellable hedges
# --------------------------------------------------------------------------

def _rpc_fleet(store, nodes, *, straggle=None, **cfg):
    """(frontend, servers) over in-process WorkerServers on ephemeral
    localhost ports — same wire protocol, channels and hedged dispatch
    as separate ``--worker`` processes, minus the process-spawn cost, so
    the delta against the in-process scatter path isolates pure RPC
    overhead (serialize + socket round trip + deserialize)."""
    from repro.index import ShardPlacement
    from repro.serve import (FrontendConfig, RpcFrontend, ShardWorker,
                             WorkerPool, WorkerServer)

    placement = ShardPlacement.for_store(
        store, nodes, replication=min(2, len(nodes)))
    held = placement.replica_assignment()
    straggle = straggle or {}
    servers = {n: WorkerServer(ShardWorker(n, store, held[n]),
                               straggle_s=straggle.get(n, 0.0)).start()
               for n in nodes if held[n]}
    pool = WorkerPool({n: s.address for n, s in servers.items()})
    pool.wait_connected()
    fe = RpcFrontend(pool, placement,
                     FrontendConfig(max_wait_s=0.0, **cfg))
    return fe, servers


def run_rpc(n_docs: int = 256, n_queries: int = 48) -> dict:
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        return _run_rpc(td, n_docs, n_queries)


def _run_rpc(tmp_root, n_docs: int, n_queries: int) -> dict:
    """In-process vs RPC dispatch overhead, then the hedged-cancel win:
    a straggling worker is injected at the WorkerServer (wall-clock
    sleeps, cancellable between shard tiles) and the hedge-on pass must
    pull p99 back to roughly hedge_after + base while the loser's
    ``cancelled_tiles`` counter moves — the 'observably cancelled'
    datum, measured end to end over real sockets."""
    from repro.index import ShardPlacement

    c, store = _build_store(n_docs, tmp_root)
    queries, _ = make_workload(c, n_queries, seed=79)
    nodes = ["w0", "w1", "w2"]
    out = {}

    # -- dispatch overhead: in-process scatter vs real RPC fan-out ----------
    fe = make_multihost_frontend(store, hosts=len(nodes), replication=2,
                                 max_batch=32, max_wait_s=0.0,
                                 hedge_after_s=1e9)
    _warm(fe, lambda: run_closed(fe, queries, 0.8, 32))
    t0 = time.perf_counter()
    run_closed(fe, queries, 0.8, 32)
    wall = time.perf_counter() - t0
    snap = fe.metrics.snapshot()
    inproc_us = wall / snap.served * 1e6
    emit("serving/rpc/inproc", inproc_us,
         f"qps={snap.served / wall:.0f};p50_ms={snap.p50_ms:.2f};"
         f"p99_ms={snap.p99_ms:.2f}")
    out["inproc_us"] = inproc_us

    fe, servers = _rpc_fleet(store, nodes, hedge_after_s=1e9)
    try:
        _warm(fe, lambda: run_closed(fe, queries, 0.8, 32))
        t0 = time.perf_counter()
        run_closed(fe, queries, 0.8, 32)
        wall = time.perf_counter() - t0
        snap = fe.metrics.snapshot()
        rpc_us = wall / snap.served * 1e6
        emit("serving/rpc/remote", rpc_us,
             f"qps={snap.served / wall:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};rpcs={snap.rpcs_sent};"
             f"channels_up={snap.channels_up}")
        out["rpc_us"] = rpc_us
        emit("serving/rpc/dispatch_overhead", rpc_us - inproc_us,
             f"ratio={rpc_us / max(inproc_us, 1e-9):.2f}x")
        out["overhead_ratio"] = rpc_us / max(inproc_us, 1e-9)
    finally:
        fe.close()
        for s in servers.values():
            s.close()

    # -- hedged-cancel win: wall-clock straggler, loser told on the wire ----
    placement = ShardPlacement.for_store(store, nodes, replication=2)
    straggler = placement.owner(0)        # a node that owns a primary
    hq = queries[:min(16, len(queries))]
    for label, hedge_after in (("hedge_off", 1e9), ("hedge_on", 0.01)):
        fe, servers = _rpc_fleet(store, nodes,
                                 straggle={straggler: 0.08},
                                 hedge_after_s=hedge_after)
        try:
            run_closed(fe, hq, 0.8, 8)    # warm (kernels + channels)
            fe.pop_responses()
            fe.reset_metrics()
            run_closed(fe, hq, 0.8, 8)
            snap = fe.metrics.snapshot()
            ex = fe.executor
            ctiles = fe.pool.channel(straggler).stats()["cancelled_tiles"]
            emit(f"serving/rpc/{label}/p99", snap.p99_ms * 1e3,
                 f"p50_ms={snap.p50_ms:.2f};p99_ms={snap.p99_ms:.2f};"
                 f"hedges_fired={ex.hedges_fired};"
                 f"hedges_won={ex.hedges_won};"
                 f"hedges_cancelled={ex.hedges_cancelled};"
                 f"cancelled_tiles={ctiles}")
            out[label] = {"p50_ms": snap.p50_ms, "p99_ms": snap.p99_ms,
                          "hedges_fired": ex.hedges_fired,
                          "hedges_cancelled": ex.hedges_cancelled,
                          "cancelled_tiles": ctiles}
        finally:
            fe.close()
            for s in servers.values():
                s.close()
    p99_off = out["hedge_off"]["p99_ms"]
    p99_on = out["hedge_on"]["p99_ms"]
    if p99_on > 0:
        emit("serving/rpc/hedge_p99_improvement", p99_off / p99_on,
             f"off={p99_off:.2f}ms;on={p99_on:.2f}ms;"
             f"cancelled_tiles={out['hedge_on']['cancelled_tiles']}")
        out["hedge_p99_improvement"] = p99_off / p99_on
    return out


# --------------------------------------------------------------------------
# Network serving: real sockets, concurrent clients
# --------------------------------------------------------------------------

def _drive_clients(address, per_client_queries, *, window: int = 16,
                   threshold: float = 0.8, topk_every: int = 0,
                   deadline_s=None, arrival_gaps=None):
    """N concurrent NetClient sessions, one thread each, pipelining their
    query stream through the socket in ``window``-sized flights (or
    following ``arrival_gaps`` seconds between submissions — open loop).
    Returns per-status counts summed over clients; every submitted query
    is awaited, so a hang would fail loudly rather than undercount."""
    from collections import Counter

    from repro.serve import NetClient

    counts: Counter = Counter()
    errors: list = []
    lock = threading.Lock()

    def one_client(ci: int, queries) -> None:
        try:
            local: Counter = Counter()
            with NetClient(*address, timeout_s=120.0) as c:
                gaps = (arrival_gaps[ci] if arrival_gaps is not None
                        else None)
                pending = []
                for qi, q in enumerate(queries):
                    k = 3 if topk_every and qi % topk_every == 0 else None
                    pending.append(c.submit(
                        q, threshold=None if k else threshold, top_k=k,
                        deadline_s=deadline_s))
                    if gaps is not None:
                        time.sleep(gaps[qi])
                    elif len(pending) >= window:
                        for f in pending:
                            local[f.result(120.0).status.value] += 1
                        pending = []
                for f in pending:
                    local[f.result(120.0).status.value] += 1
            with lock:
                counts.update(local)
        except Exception as e:
            with lock:
                errors.append((ci, e))

    threads = [threading.Thread(target=one_client, args=(i, qs))
               for i, qs in enumerate(per_client_queries)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        # loud, not a quietly-short count: a wedged or kicked session is
        # exactly the regression this bench exists to catch
        raise RuntimeError(f"client failures: {errors}")
    return counts


def run_net(n_docs: int = 256, n_queries: int = 96, clients: int = 4
            ) -> dict:
    """End-to-end socket serving: closed-loop capacity, queue-cap
    overload accounting, and open-loop latency, all through real TCP
    round trips."""
    from repro.serve import (NetServer, QueryServer, ServerConfig,
                             ServingLoop, Status)

    c, _, compact = built_indexes(n_docs)
    queries, _ = make_workload(c, n_queries, seed=77)
    split = [queries[i::clients] for i in range(clients)]
    out = {}

    # -- closed loop: concurrent pipelined clients --------------------------
    server = QueryServer(compact, ServerConfig(
        max_batch=32, max_wait_s=0.002, result_cache=0, row_cache=0))
    net = NetServer(ServingLoop(server)).start()
    try:
        _drive_clients(net.address, split)        # jit warmup
        server.reset_metrics(clear_caches=True)
        t0 = time.perf_counter()
        counts = _drive_clients(net.address, split, topk_every=7)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        qps = snap.served / wall
        emit(f"serving/net/closed/clients{clients}",
             wall / max(1, snap.served) * 1e6,
             f"qps={qps:.0f};p50_ms={snap.p50_ms:.2f};"
             f"p99_ms={snap.p99_ms:.2f};coalesce={snap.coalesce_rate:.2f};"
             f"max_depth={snap.max_queue_depth};"
             f"conns={snap.total_connections}")
        out["closed_qps"] = qps
        out["coalesce_rate"] = snap.coalesce_rate
        out["closed_counts"] = dict(counts)
        base_qps = qps
    finally:
        net.close()

    # -- overload: queue cap must refuse, never lose ------------------------
    cap = 32
    server = QueryServer(compact, ServerConfig(
        max_batch=8, max_wait_s=0.05, max_queued=cap,
        result_cache=0, row_cache=0))
    net = NetServer(ServingLoop(server)).start()
    try:
        burst = [queries[i % len(queries)] for i in range(6 * cap)]
        bsplit = [burst[i::clients] for i in range(clients)]
        counts = _drive_clients(net.address, bsplit, window=6 * cap)
        total = sum(counts.values())
        lost = len(burst) - total
        emit("serving/net/overload", 0.0,
             f"sent={len(burst)};answered={total};lost={lost};"
             f"ok={counts.get(Status.OK.value, 0)};"
             f"rejected={counts.get(Status.REJECTED.value, 0)}")
        out["overload_lost"] = lost
        out["overload_rejected"] = counts.get(Status.REJECTED.value, 0)
    finally:
        net.close()

    # -- open loop: Poisson arrivals across the fleet -----------------------
    server = QueryServer(compact, ServerConfig(
        max_batch=32, max_wait_s=0.002, result_cache=0, row_cache=0))
    net = NetServer(ServingLoop(server)).start()
    try:
        _drive_clients(net.address, split)        # jit warmup
        server.reset_metrics(clear_caches=True)
        offered = max(20.0, base_qps * 0.5)
        rng = np.random.default_rng(1)
        gaps = [rng.exponential(clients / offered, size=len(s))
                for s in split]
        t0 = time.perf_counter()
        _drive_clients(net.address, split, arrival_gaps=gaps)
        wall = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        emit("serving/net/open/load50", wall / max(1, snap.served) * 1e6,
             f"offered_qps={offered:.0f};"
             f"achieved_qps={snap.served / wall:.0f};"
             f"p50_ms={snap.p50_ms:.2f};p99_ms={snap.p99_ms:.2f};"
             f"coalesce={snap.coalesce_rate:.2f}")
        out["open_qps"] = snap.served / wall
    finally:
        net.close()
    return out


def run_net_connect(address, n_queries: int = 96, clients: int = 4) -> dict:
    """Client-only load against an EXTERNAL server (e.g. `python -m
    repro.launch.serve --listen PORT`): random DNA compiled with the
    HELLO-announced index params, pipelined from N sessions. Only
    client-side numbers are reported — the server's metrics live in its
    own process."""
    import time as _time

    from repro.serve import NetClient, Status

    with NetClient(*address) as probe:
        kmer = probe.params.kmer
    rng = np.random.default_rng(3)
    queries = [rng.integers(0, 4, size=int(n), dtype=np.uint8)
               for n in rng.integers(kmer + 25, 320, size=n_queries)]
    split = [queries[i::clients] for i in range(clients)]
    t0 = _time.perf_counter()
    counts = _drive_clients(address, split)
    wall = _time.perf_counter() - t0
    total = sum(counts.values())
    emit(f"serving/net/connect/clients{clients}",
         wall / max(1, total) * 1e6,
         f"qps={total / wall:.0f};answered={total};"
         f"ok={counts.get(Status.OK.value, 0)};"
         f"rejected={counts.get(Status.REJECTED.value, 0)}")
    return {"qps": total / wall, "counts": dict(counts)}


def main() -> None:
    """CLI for CI artifacts: run the multi-host scale-out + hedging bench
    (default) or the socket serving bench (--listen) and dump the emitted
    rows as a BENCH json."""
    import argparse
    import json
    from pathlib import Path

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=3,
                    help="scale-out sweep upper bound (1..N fake hosts)")
    ap.add_argument("--n-docs", type=int, default=128)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--listen", action="store_true",
                    help="run the network serving bench (in-process "
                         "NetServer on an ephemeral port, concurrent "
                         "NetClient load) instead of the multi-host one")
    ap.add_argument("--rpc", action="store_true",
                    help="run the RPC data-plane bench: in-process vs "
                         "RPC per-shard dispatch overhead, plus the "
                         "hedged-cancel win under a wall-clock straggler")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="with --listen: drive the load against an "
                         "EXTERNAL server (repro.launch.serve --listen) "
                         "instead of an in-process one")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client sessions in --listen mode")
    ap.add_argument("--json", default=None,
                    help="write emitted rows as a json artifact here")
    args = ap.parse_args()
    if args.connect and not args.listen:
        ap.error("--connect requires --listen (it selects the socket "
                 "bench and points it at an external server)")
    if args.rpc and args.listen:
        ap.error("--rpc and --listen are separate benches; pick one")

    print("name,us_per_call,derived")
    if args.rpc:
        bench, extra = "rpc_serving", {}
        run_rpc(args.n_docs, args.queries)
    elif args.listen:
        bench, extra = "net_serving", {"clients": args.clients}
        if args.connect:
            host, port = args.connect.rsplit(":", 1)
            run_net_connect((host, int(port)), args.queries,
                            clients=args.clients)
        else:
            run_net(args.n_docs, args.queries, clients=args.clients)
    else:
        bench, extra = "serving_multihost", {"hosts": args.hosts}
        run_multihost(args.n_docs, args.queries, max_hosts=args.hosts)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        rows = [{"name": n, "us_per_call": v, "derived": d}
                for n, v, d in common.ROWS]
        out.write_text(json.dumps({"bench": bench, **extra,
                                   "rows": rows}, indent=2))
        print(f"# wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
