"""Observability overhead smoke: tracing must be (near) free.

Drives the SAME pipelined query stream through two in-process socket
servers — one with tracing + kernel profiling on (the default), one with
both off and a non-tracing client — and compares end-to-end wall clock.
Passes are interleaved (on, off, on, off, ...) and the medians compared,
so drift in machine load hits both sides equally. The bench FAILS (exit
code 1) if the traced path is more than ``--max-overhead-pct`` slower.

Along the way it asserts the STATS frame actually parses in both
formats — the JSON snapshot and the Prometheus text exposition — since
CI is the only place a format skew between `render_prometheus` and
`parse_prometheus` would otherwise hide.

    PYTHONPATH=src python -m benchmarks.obs_overhead \\
        --json results/BENCH_obs_overhead.json
"""
from __future__ import annotations

import time

import numpy as np

from repro.launch.serve import make_workload
from repro.serve import (NetClient, NetServer, QueryServer, ServerConfig,
                         ServingLoop, Status)

from .common import built_indexes, emit


def _drive(address, queries, *, trace: bool, window: int = 16) -> None:
    """One pipelined closed-loop pass over ``queries``; every response
    must be OK (a reject would make the comparison meaningless)."""
    with NetClient(*address, timeout_s=120.0, trace=trace) as cl:
        pending = []
        for q in queries:
            pending.append(cl.submit(q, threshold=0.8))
            if len(pending) >= window:
                for f in pending:
                    assert f.result(120.0).status == Status.OK
                pending = []
        for f in pending:
            assert f.result(120.0).status == Status.OK


def run(n_docs: int = 128, n_queries: int = 64, repeats: int = 5) -> dict:
    c, _, compact = built_indexes(n_docs)
    queries, _ = make_workload(c, n_queries, seed=79)

    def server(traced: bool):
        cfg = ServerConfig(max_batch=32, max_wait_s=0.002,
                           result_cache=0, row_cache=0,
                           tracing=traced, profile_kernels=traced)
        return QueryServer(compact, cfg)

    servers = {True: server(True), False: server(False)}
    nets = {k: NetServer(ServingLoop(s)).start()
            for k, s in servers.items()}
    try:
        # jit warmup on both (the compile cache is process-global, but the
        # warm pass also populates row plans / sockets / thread pools)
        for traced, net in nets.items():
            _drive(net.address, queries, trace=traced)
            servers[traced].reset_metrics(clear_caches=True)

        walls: dict[bool, list[float]] = {True: [], False: []}
        for _ in range(repeats):
            for traced in (True, False):      # interleaved: drift-neutral
                t0 = time.perf_counter()
                _drive(nets[traced].address, queries, trace=traced)
                walls[traced].append(time.perf_counter() - t0)
        on = float(np.median(walls[True]))
        off = float(np.median(walls[False]))
        overhead_pct = (on - off) / off * 100.0

        # the traced server really traced (and the untraced one didn't)
        assert servers[True].tracer.finished_count >= n_queries
        assert servers[False].tracer.finished_count == 0

        # STATS parses in both formats over the traced session
        from repro.obs.export import parse_prometheus
        with NetClient(*nets[True].address, timeout_s=60.0) as cl:
            snap = cl.stats()
            assert isinstance(snap, dict) and snap["served"] >= n_queries
            parsed = parse_prometheus(cl.stats(prometheus=True))
            assert parsed.get('serve_requests_total{status="ok"}', 0) >= \
                n_queries
        emit("obs/stats_frame", 0.0, "json=ok;prometheus=ok")
    finally:
        for net in nets.values():
            net.close()

    per_q = 1e6 / n_queries
    emit("obs/traced_on", on * per_q, f"wall_s={on:.4f}")
    emit("obs/traced_off", off * per_q, f"wall_s={off:.4f}")
    emit("obs/overhead_pct", overhead_pct,
         f"on_s={on:.4f};off_s={off:.4f};repeats={repeats}")
    return {"on_s": on, "off_s": off, "overhead_pct": overhead_pct}


def main() -> None:
    import argparse
    import json
    from pathlib import Path

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=128)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--max-overhead-pct", type=float, default=5.0,
                    help="fail if tracing costs more than this (<=0 "
                         "disables the gate)")
    ap.add_argument("--json", default=None,
                    help="write emitted rows as a json artifact here")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    out = run(args.n_docs, args.queries, repeats=args.repeats)
    if args.json:
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        rows = [{"name": n, "us_per_call": v, "derived": d}
                for n, v, d in common.ROWS]
        path.write_text(json.dumps({"bench": "obs_overhead", **out,
                                    "rows": rows}, indent=2))
        print(f"# wrote {path} ({len(rows)} rows)")
    if args.max_overhead_pct > 0 and out["overhead_pct"] > \
            args.max_overhead_pct:
        raise SystemExit(
            f"tracing overhead {out['overhead_pct']:.2f}% exceeds "
            f"{args.max_overhead_pct:.1f}% budget")
    print(f"# tracing overhead {out['overhead_pct']:+.2f}% "
          f"(budget {args.max_overhead_pct:.1f}%)")


if __name__ == "__main__":
    main()
