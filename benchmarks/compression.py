"""Compressed arena: ratio x decode throughput x end-to-end latency.

The tentpole claim of the compressed arena is that fused-decode scoring
multiplies EFFECTIVE memory bandwidth: a dict-coded shard moves
raw_bytes/ratio across HBM per dispatch and decodes inside the kernel
loop, so the win is real only when the decode cost stays below the
bandwidth saved. This sweep measures all three terms per corpus
redundancy level:

  ratio   — on-disk + HBM compression ratio the rowdict codec achieves;
  decode  — host decode throughput (codec layer, tile -> raw MB/s) and
            fused kernel call time vs the raw kernel on identical shapes;
  e2e     — QueryServer latency over the same query stream, raw store vs
            compressed store with the planner's cost model active.

``--json`` writes results/BENCH_compression.json for CI trend tracking.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import IndexParams, QueryEngine
from repro.core import codec as codec_mod
from repro.data import make_corpus
from repro.index import build_compact_streaming

from .common import emit, timeit


def _redundant_terms(n_base: int, reps: int, seed: int = 3):
    c = make_corpus(n_base, k=15, mean_length=160, min_length=120,
                    seed=seed)
    return c, [c.doc_terms[i % n_base] for i in range(n_base * reps)]


def _decode_throughput(storage) -> tuple[float, float]:
    """(host decode MB/s over all dict shards, decoded MB)."""
    total_b = 0
    t0 = time.perf_counter()
    for s in range(storage.n_shards):
        if storage.shard_codec(s) in codec_mod.DICT_CODECS:
            tile = np.asarray(storage.shard_host(s))
            total_b += tile.nbytes
    dt = time.perf_counter() - t0
    return (total_b / 2 ** 20 / max(dt, 1e-9), total_b / 2 ** 20)


def _serve_latency(index, pats, *, compressed: bool) -> float:
    from repro.serve.server import QueryServer, ServerConfig
    srv = QueryServer(index, ServerConfig(result_cache=0, row_cache=0,
                                          compressed=compressed))
    rid = srv.submit(pats[0], threshold=0.4)   # warm the jit path
    srv.drain()
    srv.pop_responses()

    def one_round():
        for p in pats:
            srv.submit(p, threshold=0.4)
        srv.drain()
        srv.pop_responses()

    return timeit(one_round, repeats=3, warmup=1)


def run(n_base: int = 24, n_queries: int = 24, *,
        reps_levels: tuple[int, ...] = (1, 4, 8)) -> dict:
    params = IndexParams(n_hashes=1, fpr=0.03, kmer=15)
    report: dict = {"params": {"n_base": n_base, "n_queries": n_queries},
                    "levels": []}
    rng = np.random.default_rng(0)
    for reps in reps_levels:
        c, terms = _redundant_terms(n_base, reps)
        pats = ["".join(rng.choice(list("ACGT"), size=60))
                for _ in range(n_queries // 2)]
        pats += [c.documents[i % n_base][10:90]
                 for i in range(n_queries - len(pats))]
        tmp = Path(tempfile.mkdtemp(prefix="cobs-compress-"))
        try:
            idx_c, _ = build_compact_streaming(
                terms, tmp / "comp", params, block_docs=128,
                blocks_per_shard=1, codec="rowdict")
            idx_r, _ = build_compact_streaming(
                terms, tmp / "raw", params, block_docs=128,
                blocks_per_shard=1, codec="raw")
            ratio = idx_c.storage.dict_ratio() or 1.0
            mbps, mb = _decode_throughput(idx_c.storage)

            # fused-decode kernel vs raw kernel, identical shapes, warm
            eng_r = QueryEngine(idx_r, method="lookup")
            eng_c = QueryEngine(idx_c, method="lookup", compressed=True)
            t_raw_k = timeit(lambda: [eng_r.search(p, threshold=0.4)
                                      for p in pats], repeats=3)
            t_comp_k = timeit(lambda: [eng_c.search(p, threshold=0.4)
                                       for p in pats], repeats=3)

            t_raw_e2e = _serve_latency(idx_r, pats, compressed=False)
            t_comp_e2e = _serve_latency(idx_c, pats, compressed=True)

            per_q = 1e6 / len(pats)
            tag = f"reps={reps}"
            emit(f"compression/ratio_{reps}x", ratio * 1000,
                 f"{tag};ratio={ratio:.2f};unit=milli")
            emit(f"compression/decode_host_{reps}x",
                 1e6 * mb / max(mbps, 1e-9) / max(mb, 1e-9),
                 f"{tag};MBps={mbps:.0f}")
            emit(f"compression/query_raw_{reps}x", t_raw_k * per_q, tag)
            emit(f"compression/query_fused_{reps}x", t_comp_k * per_q,
                 f"{tag};vs_raw={t_comp_k / max(t_raw_k, 1e-12):.2f}")
            emit(f"compression/serve_raw_{reps}x", t_raw_e2e * per_q, tag)
            emit(f"compression/serve_comp_{reps}x", t_comp_e2e * per_q,
                 f"{tag};vs_raw={t_comp_e2e / max(t_raw_e2e, 1e-12):.2f}")
            report["levels"].append({
                "reps": reps,
                "ratio": round(ratio, 4),
                "decode_host_MBps": round(mbps, 1),
                "decoded_MB": round(mb, 3),
                "query_raw_us": round(t_raw_k * per_q, 1),
                "query_fused_us": round(t_comp_k * per_q, 1),
                "serve_raw_us": round(t_raw_e2e * per_q, 1),
                "serve_comp_us": round(t_comp_e2e * per_q, 1),
            })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return report


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the sweep report to this path")
    args = ap.parse_args()
    report = run(n_base=16 if args.quick else 24,
                 n_queries=12 if args.quick else 24,
                 reps_levels=(1, 4) if args.quick else (1, 4, 8))
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
