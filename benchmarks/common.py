"""Shared benchmark utilities: timing, corpus cache, CSV emission."""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@lru_cache(maxsize=8)
def corpus(n_docs: int, seed: int = 0, mean_length: int = 2000):
    from repro.data import make_corpus
    return make_corpus(n_docs, k=15, mean_length=mean_length, sigma=1.0,
                       seed=seed)


@lru_cache(maxsize=4)
def built_indexes(n_docs: int):
    from repro.core import IndexParams, build_classic, build_compact
    c = corpus(n_docs)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    classic = build_classic(c.doc_terms, params)
    compact = build_compact(c.doc_terms, params, block_docs=64, row_align=64)
    return c, classic, compact
