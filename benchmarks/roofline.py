"""Roofline report (deliverable g): reads results/dryrun.jsonl and renders
the per-(arch x shape x mesh) three-term table + bottleneck + MODEL_FLOPS
ratio as markdown (for EXPERIMENTS.md §Roofline) and CSV.

    PYTHONPATH=src python -m benchmarks.roofline [--jsonl results/dryrun.jsonl]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(path: str):
    recs = [json.loads(l) for l in open(path)]
    # keep the newest record per cell
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x:.1e}"
    return f"{x:.4f}"


def render(recs, mesh_filter: str | None = "single-pod-16x16") -> str:
    lines = []
    lines.append("| arch | shape | mesh | t_compute (s) | t_memory (s) | "
                 "t_collective (s) | bound | useful/computed | "
                 "roofline frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3, "query_b64": 4}
    recs = sorted(recs, key=lambda r: (r["mesh"], r["arch"],
                                       order.get(r["shape"], 9)))
    for r in recs:
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped: {r['reason']} | — | — |")
            continue
        if r["status"] != "ok" or "roofline" not in r:
            continue
        f = r["roofline"]
        tc, tm, tl = f["t_compute_s"], f["t_memory_s"], f["t_collective_s"]
        bound = max(tc, tm, tl)
        # roofline fraction: useful-compute time / achievable step time
        useful_t = (f["model_flops"] / f["chips"]) / 197e12
        frac = useful_t / bound if bound else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(tc)} | "
            f"{fmt_s(tm)} | {fmt_s(tl)} | {f['bottleneck']} | "
            f"{f['useful_flops_ratio']:.2f} | {frac:.2f} |")
    return "\n".join(lines)


def advice(r) -> str:
    """One sentence per cell: what would move the dominant term down."""
    f = r["roofline"]
    b = f["bottleneck"]
    mode = ("decode" if "decode" in r["shape"] or "long" in r["shape"]
            else ("prefill" if "prefill" in r["shape"] else "train"))
    coll = f.get("coll_breakdown", {})
    big = max(coll, key=coll.get) if coll else ""
    if b == "collective":
        if mode == "decode":
            return ("latency-regime: batch more requests per chip or "
                    "co-locate decode replicas per pod to amortize the "
                    f"per-token {big} of the FSDP/TP weights.")
        if "moe" in r["arch"]:
            return ("switch MoE dispatch to shard_map local capacity and "
                    "re-factor the mesh toward more data/less model "
                    "parallelism (§Perf cell A: 97.4→3.5 s).")
        return ("lower the TP degree (mesh data x model refactor) and/or "
                "overlap the Megatron all-reduce with the next layer's "
                f"matmuls; dominant op: {big} (§Perf cell B pattern).")
    if b == "memory":
        if r["arch"] == "cobs-index":
            return ("bandwidth floor of the signature scan — next step is "
                    "row compression (paper's future work) or larger "
                    "query batches to amortize row reads.")
        return ("increase arithmetic intensity: larger per-chip batch, "
                "bf16 optimizer state, or fuse the attention cache "
                "update with the projection.")
    return ("compute-bound at the stated batch: raise MFU via remat-policy "
            "tuning (drop the +1 forward) and causal-block skipping in the "
            "blockwise attention.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single-pod-16x16",
                    help="mesh filter or 'all'")
    args = ap.parse_args()
    recs = load(args.jsonl)
    mf = None if args.mesh == "all" else args.mesh
    print(render(recs, mf))
    Path("results").mkdir(exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(render(recs, None))
        f.write("\n\n## Per-cell notes (dominant-term reduction)\n\n")
        for r in sorted(recs, key=lambda x: (x["mesh"], x["arch"])):
            if r.get("status") != "ok":
                continue
            if "roofline" not in r and "bytes_per_chip" in r:
                # COBS index cell: terms recorded flat
                t = {"compute": r["flops_per_chip"] / 197e12,
                     "memory": r["bytes_per_chip"] / 819e9,
                     "collective": r["coll_bytes_per_chip"] / 50e9}
                b = max(t, key=t.get)
                r = {**r, "roofline": {"bottleneck": b,
                                       "coll_breakdown": r.get(
                                           "coll_breakdown", {})}}
            if "roofline" in r:
                f.write(f"* **{r['arch']} × {r['shape']} ({r['mesh']})** — "
                        f"{r['roofline']['bottleneck']}-bound: {advice(r)}\n")
    with open("results/roofline.csv", "w") as f:
        f.write("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
                "bottleneck,useful_ratio\n")
        for r in recs:
            if r["status"] != "ok" or "roofline" not in r:
                continue
            rf = r["roofline"]
            f.write(f"{r['arch']},{r['shape']},{r['mesh']},"
                    f"{rf['t_compute_s']},{rf['t_memory_s']},"
                    f"{rf['t_collective_s']},{rf['bottleneck']},"
                    f"{rf['useful_flops_ratio']}\n")


if __name__ == "__main__":
    main()
