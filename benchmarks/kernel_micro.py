"""Kernel microbenchmarks: scoring methods across (terms x doc-words)
tiles, the fused lookup paths, and the batched row-dedup pair under a
row-overlap sweep.

On CPU the Pallas kernels execute in interpret mode (correctness path);
the jnp oracle ('ref') is the XLA-compiled CPU path, so it is the
meaningful CPU wall-clock datum, while the interpret numbers track kernel-
body overhead. On TPU the same harness times compiled Mosaic kernels.

The overlap sweep is the PR-4 acceptance datum: batches whose queries
share rows (overlapping k-mers) re-stream the same arena rows under the
fused multi-query kernel, while the dedup pair streams each unique row
once. ``arena_row_dmas`` counts the arena row-tile transfers each path
issues per word-tile column — exact from the kernel grids, not sampled:
fused = Q*nb*L cells, dedup = the padded unique-row count. At 90% batch
overlap the ratio is >= 2x (typically ~8x at these shapes).

    PYTHONPATH=src python -m benchmarks.kernel_micro [--quick] \\
        [--json results/BENCH_kernels.json]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# the REAL padding rule: the benchmark's DMA accounting must stay
# bit-consistent with what plan_dedup_batch pads for the serving path
from repro.core.query import _pad_unique
from repro.kernels import ops

from .common import emit, timeit


def overlap_batch(rng: np.random.Generator, Q: int, L: int, n_rows: int,
                  overlap: float) -> np.ndarray:
    """Row indices [Q, 1, L] whose gathers share ~``overlap`` of their
    rows: 0.0 draws every cell a distinct row (fully disjoint batch),
    otherwise cells draw from a pool sized (1-overlap) * Q * L."""
    n = Q * L
    if overlap <= 0.0:
        idx = rng.choice(n_rows, size=min(n, n_rows), replace=False)
        if idx.size < n:                      # tiny arena: wrap around
            idx = np.resize(idx, n)
        return idx.reshape(Q, 1, L).astype(np.int32)
    pool = max(1, int(round(n * (1.0 - overlap))))
    pool_rows = rng.choice(n_rows, size=min(pool, n_rows), replace=False)
    return rng.choice(pool_rows, size=(Q, 1, L)).astype(np.int32)


def dedup_traffic(idx: np.ndarray) -> tuple[int, int, np.ndarray, np.ndarray]:
    """(fused arena-row DMAs, dedup arena-row DMAs, uniq_pad, indir) for a
    row-index batch — the exact per-word-tile transfer counts of the two
    kernel paths (dedup counts the PADDED unique buffer it really
    streams)."""
    uniq, inv = np.unique(idx, return_inverse=True)
    indir = inv.reshape(idx.shape).astype(np.int32)
    uniq_pad = np.zeros(_pad_unique(uniq.size), dtype=np.int32)
    uniq_pad[: uniq.size] = uniq
    return int(idx.size), int(uniq_pad.size), uniq_pad, indir


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    report: dict = {"bench": "kernel_micro", "add_step": [], "lookup": [],
                    "batch_overlap": []}

    # -- ADD-step methods over materialized gathers -------------------------
    shapes = ((64, 128), (256, 512)) if quick else \
        ((64, 128), (256, 512), (1024, 1024))
    for L, W in shapes:
        rows = jnp.asarray(rng.integers(0, 2 ** 32, size=(L, W),
                                        dtype=np.uint32))
        for method in ("ref", "unpack", "vertical"):
            fn = jax.jit(lambda r, m=method: ops.bitslice_score(r, method=m))
            fn(rows).block_until_ready()
            t = timeit(lambda: fn(rows).block_until_ready(), repeats=3)
            docs_per_s = (W * 32 * L) / t
            emit(f"kernel/{method}/L{L}xW{W}", t * 1e6,
                 f"term_doc_pairs_per_s={docs_per_s:.2e}")
            report["add_step"].append(
                {"method": method, "L": L, "W": W, "us": t * 1e6})

    # -- fused single-query lookup (gather inside the kernel) ---------------
    R = 2048 if quick else 8192
    for L, W in ((64, 128),) if quick else ((64, 128), (256, 256)):
        arena = jnp.asarray(rng.integers(0, 2 ** 32, size=(R, W),
                                         dtype=np.uint32))
        idx = jnp.asarray(rng.integers(0, R, size=L).astype(np.int32))
        msk = jnp.ones(L, dtype=jnp.int32)
        t = timeit(lambda: ops.bitslice_lookup_score(
            arena, idx, msk).block_until_ready(), repeats=3)
        emit(f"kernel/lookup/L{L}xW{W}", t * 1e6, f"arena_row_dmas={L}")
        report["lookup"].append({"L": L, "W": W, "us": t * 1e6,
                                 "arena_row_dmas": L})

    # -- batched fused multi vs row-dedup under an overlap sweep ------------
    Q, L, W = (4, 32, 64) if quick else (8, 64, 128)
    arena = jnp.asarray(rng.integers(0, 2 ** 32, size=(R, W),
                                     dtype=np.uint32))
    mask = jnp.ones((Q, 1, L), dtype=jnp.int32)
    for overlap in (0.0, 0.5, 0.9):
        idx = overlap_batch(rng, Q, L, R, overlap)
        fused_dmas, dedup_dmas, uniq_pad, indir = dedup_traffic(idx)
        idx_d = jnp.asarray(idx)
        t_multi = timeit(lambda: ops.bitslice_lookup_score_multi(
            arena, idx_d, mask).block_until_ready(), repeats=3)
        u_d, i_d = jnp.asarray(uniq_pad), jnp.asarray(indir)
        t_dedup = timeit(lambda: ops.bitslice_lookup_score_dedup(
            arena, u_d, i_d, mask).block_until_ready(), repeats=3)
        ratio = fused_dmas / dedup_dmas
        pct = int(overlap * 100)
        emit(f"kernel/lookup_multi/Q{Q}xL{L}/ov{pct}", t_multi * 1e6,
             f"arena_row_dmas={fused_dmas}")
        emit(f"kernel/dedup/Q{Q}xL{L}/ov{pct}", t_dedup * 1e6,
             f"arena_row_dmas={dedup_dmas} traffic_ratio={ratio:.1f}x")
        report["batch_overlap"].append({
            "overlap": overlap, "Q": Q, "L": L, "W": W,
            "fused_us": t_multi * 1e6, "dedup_us": t_dedup * 1e6,
            "fused_arena_row_dmas": fused_dmas,
            "dedup_arena_row_dmas": dedup_dmas,
            "traffic_ratio": ratio})
    return report


def main() -> None:
    """CLI for CI artifacts: run the sweep, dump a BENCH json."""
    import argparse
    import json
    from pathlib import Path

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized shapes")
    ap.add_argument("--json", default=None,
                    help="write the report as a json artifact here")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = run(quick=args.quick)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
