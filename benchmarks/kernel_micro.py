"""Kernel microbenchmarks: scoring methods across (terms x doc-words)
tiles. On CPU the Pallas kernels execute in interpret mode (correctness
path); the jnp oracle ('ref') is the XLA-compiled CPU path, so it is the
meaningful CPU wall-clock datum, while the interpret numbers track kernel-
body overhead. On TPU the same harness times compiled Mosaic kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit, timeit


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for L, W in ((64, 128), (256, 512), (1024, 1024)):
        rows = jnp.asarray(rng.integers(0, 2 ** 32, size=(L, W),
                                        dtype=np.uint32))
        for method in ("ref", "unpack", "vertical"):
            fn = jax.jit(lambda r, m=method: ops.bitslice_score(r, method=m))
            fn(rows).block_until_ready()
            t = timeit(lambda: fn(rows).block_until_ready(), repeats=3)
            docs_per_s = (W * 32 * L) / t
            emit(f"kernel/{method}/L{L}xW{W}", t * 1e6,
                 f"term_doc_pairs_per_s={docs_per_s:.2e}")
            out[(method, L, W)] = t
    return out
