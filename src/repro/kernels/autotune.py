"""Kernel autotuner: measured tile/grid configs per (arena, batch) shape,
with a persisted on-disk cache.

The scoring kernels expose three knobs whose best setting depends on the
hardware and the shapes in flight — ``word_block`` (lane tile width),
``term_block`` (sublane tile height of the materialized-gather kernels)
and ``grid_order`` (outer grid permutation of the fused multi-query
kernel). The ROADMAP's open item asked for exactly this: tune
``lookup_score_multi``'s grid order / word_block and measure arena-tile
reuse across queries. Instead of baking in per-backend constants, the
tuner:

1. benchmarks each candidate config against a synthetic arena of the
   index's dtype/width (row count capped — gather cost is row-count
   independent once past cache sizes, and keys still carry the REAL
   shape);
2. for the fused ``lookup`` method additionally measures the row-dedup
   path at two unique-row fractions and derives the **dedup-rate
   break-even threshold** — the planner compares each live batch's
   measured dedup rate against it to decide fused-multi vs dedup;
3. persists every tuned entry to a JSON ``TuningCache`` (stored beside a
   v2 store's manifest by convention, see ``repro.core.store.
   tuning_path``), so a reopened index serves with measured choices and
   never re-tunes.

Layering: this module sits with the kernels (imports ``ops`` only); the
serving planner (``repro.serve.planner``) consults it, and
``repro.core.query``'s score-fn factories accept its choices as plain
keyword arguments.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from . import bitslice_score as _k
from . import ops

CACHE_VERSION = 1
DEFAULT_WORD_BLOCKS = (64, 128, 256)
DEFAULT_TERM_BLOCKS = (8, 16)

# Methods the tuner knows how to measure for a batch dispatch.
# "lookup_c" is the fused DECODE-in-the-loop variant over a
# rowdict-compressed arena (kernels.bitslice_score.
# lookup_score_multi_compressed): measurable only when the tuner knows
# the index's dict compression ratio (``comp_ratio``), and picked by the
# planner only when its measured cost — decode indirection included —
# beats the raw fused kernel, i.e. when the bandwidth saved on dict rows
# outweighs the extra scalar gather.
#
# "lookup_p" (the pruned chunked executor) is tunable via ``entry`` but
# deliberately NOT listed here: it is chosen by prune-rate break-even
# against the argmin of these methods, never by cost argmin itself, and
# live profiler observations of it would poison the cost table.
TUNABLE_METHODS = ("lookup", "lookup_c", "vertical", "unpack")

# Key prefix for live observed-cost entries (see TunedEntry.observed).
# tuning_key() output always starts with "r<rows>", so no collision.
LIVE_PREFIX = "live."

# Chunk size used when measuring the pruned (chunked) path's break-even.
# The planner may serve a different chunk size; the break-even is a rate
# comparison and only weakly chunk-size dependent, so one fixture size
# keeps the tuning cost bounded.
PRUNE_TUNE_CHUNK = 32


@dataclasses.dataclass(frozen=True)
class TunedEntry:
    """The measured best config for one (method, shape) key.

    ``cost_us`` is the measured per-dispatch cost at the chosen config.
    ``dedup_threshold`` (lookup only) is the minimum batch dedup rate at
    which the row-dedup path beats the fused multi-query kernel: None =
    never measured (heuristics apply), 0.0 = dedup wins even for fully
    disjoint batches, 2.0 = MEASURED and dedup never won (no real batch
    reaches rate 2, so the planner keeps the fused kernel).
    """
    method: str
    word_block: int
    term_block: int
    grid_order: str
    cost_us: float
    dedup_threshold: float | None = None
    # True for entries derived from LIVE serving measurements (the
    # KernelProfiler feeding back through ``KernelTuner.observe``) as
    # opposed to offline synthetic tuning. Live entries are stored under
    # a "live."-prefixed key so both kinds coexist; ``entry``/``costs``
    # prefer the live one when present.
    observed: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "TunedEntry":
        return TunedEntry(
            method=str(d["method"]), word_block=int(d["word_block"]),
            term_block=int(d["term_block"]),
            grid_order=str(d["grid_order"]), cost_us=float(d["cost_us"]),
            dedup_threshold=(None if d.get("dedup_threshold") is None
                             else float(d["dedup_threshold"])),
            observed=bool(d.get("observed", False)))


def tuning_key(n_rows: int, doc_words: int, n_hashes: int, n_blocks: int,
               method: str, bucket: int, batch: int) -> str:
    """Cache key: arena shape x index addressing x batch shape x method.
    Everything that changes the dispatched kernel's shape is in the key;
    nothing else is (so a rebuilt index of the same geometry hits)."""
    return (f"r{n_rows}.w{doc_words}.k{n_hashes}.b{n_blocks}"
            f".{method}.L{bucket}.Q{batch}")


class TuningCache:
    """JSON-backed map of tuning key -> TunedEntry.

    ``path=None`` keeps the cache in memory only. ``save`` writes
    atomically (tmp + rename, like the store manifest); ``hits`` /
    ``misses`` counters let callers (and tests) observe that a reopened
    cache serves without re-tuning.

    An unreadable cache file — truncated/corrupt JSON, a version from a
    different build, malformed entries — must never take serving down:
    tuned configs are an optimization, not state. Such a file is treated
    as empty (``invalid`` is set so callers/tests can observe it), the
    planner falls back to heuristics, and the next ``save`` rewrites the
    file in the current format. Stale-GEOMETRY entries need no special
    casing: the tuning key carries the full arena shape, so an entry
    measured for a different arena can never be served — it just misses.
    """

    def __init__(self, path: str | Path | None = None):
        self.path = None if path is None else Path(path)
        self.entries: dict[str, TunedEntry] = {}
        self.hits = 0
        self.misses = 0
        self.invalid = False      # file existed but could not be used
        if self.path is not None and self.path.exists():
            try:
                data = json.loads(self.path.read_text())
                if data.get("version") != CACHE_VERSION:
                    raise ValueError(
                        f"version {data.get('version')!r} != "
                        f"{CACHE_VERSION}")
                self.entries = {k: TunedEntry.from_json(v)
                                for k, v in data["entries"].items()}
            except (OSError, ValueError, KeyError, TypeError,
                    AttributeError):
                # json.JSONDecodeError is a ValueError; missing/mistyped
                # fields raise KeyError/TypeError/ValueError from
                # from_json; a non-dict payload raises AttributeError
                self.entries = {}
                self.invalid = True

    def __len__(self) -> int:
        return len(self.entries)

    def get(self, key: str) -> TunedEntry | None:
        e = self.entries.get(key)
        if e is None:
            self.misses += 1
        else:
            self.hits += 1
        return e

    def put(self, key: str, entry: TunedEntry) -> None:
        self.entries[key] = entry

    def save(self) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": CACHE_VERSION,
                   "entries": {k: e.to_json()
                               for k, e in sorted(self.entries.items())}}
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload, indent=2))
        tmp.rename(self.path)


def _timeit(fn, repeats: int) -> float:
    """Median wall-clock seconds per call (1 warmup = the compile)."""
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _pad_unique(n: int) -> int:
    """Mirror of repro.core.query._pad_unique (kernels must not import
    core): unique count -> power-of-two buffer length, floor 8."""
    return max(8, 1 << max(0, int(n) - 1).bit_length())


class KernelTuner:
    """On-demand per-shape tuning bound to one index geometry.

    ``entry(method, bucket, batch)`` returns the cached TunedEntry, or —
    when ``enabled`` and the key is absent — measures the candidate
    configs, persists the winner, and returns it. With ``enabled=False``
    the tuner is read-only: cache hits inform the planner, misses return
    None (heuristics apply), nothing is ever measured in the serving
    path.

    Measurement runs against a SYNTHETIC arena of the index's word width
    with rows capped at ``max_tune_rows`` (row count only changes gather
    address ranges, not per-row cost), and block count capped at
    ``max_tune_blocks`` (costs scale ~linearly in nb; method comparisons
    are unaffected). Keys always carry the real geometry.
    """

    def __init__(self, n_rows: int, doc_words: int, n_hashes: int,
                 n_blocks: int, cache: TuningCache | None = None, *,
                 enabled: bool = True,
                 word_blocks: tuple[int, ...] = DEFAULT_WORD_BLOCKS,
                 term_blocks: tuple[int, ...] = DEFAULT_TERM_BLOCKS,
                 grid_orders: tuple[str, ...] = _k.GRID_ORDERS,
                 repeats: int = 2, max_tune_rows: int = 2048,
                 max_tune_blocks: int = 4, seed: int = 0,
                 comp_ratio: float | None = None):
        self.n_rows = int(n_rows)
        self.doc_words = int(doc_words)
        self.n_hashes = int(n_hashes)
        self.n_blocks = int(n_blocks)
        self.cache = cache if cache is not None else TuningCache()
        self.enabled = enabled
        self.word_blocks = tuple(word_blocks)
        self.term_blocks = tuple(term_blocks)
        self.grid_orders = tuple(grid_orders)
        self.repeats = int(repeats)
        self.max_tune_rows = int(max_tune_rows)
        self.max_tune_blocks = int(max_tune_blocks)
        self.seed = int(seed)
        # The index's HBM dict compression ratio (ArenaStorage.dict_ratio):
        # None = no dict-coded shards, the compressed method "lookup_c" is
        # untunable and never returned. The ratio shapes the synthetic
        # dict fixture so the measured decode cost reflects the real
        # dict-row working set the fused-decode kernel would stream.
        self.comp_ratio = None if comp_ratio is None else float(comp_ratio)
        self.tunes = 0              # measurement runs (tests assert 0 on reopen)
        self._arena = None
        self._dict = None           # (dict_rows_dev, refs_dev) fixture
        # -- live observed-cost feedback (KernelProfiler -> observe) --
        # Rolling per-key sample windows; every ``live_min_samples`` new
        # observations the median is (re-)promoted to a cache entry
        # under LIVE_PREFIX so choose_method sees serving-measured costs.
        self.prefer_observed = True
        self.live_min_samples = 8
        self.observations = 0
        self._live_lock = threading.Lock()
        self._live_samples: dict[str, "deque[float]"] = {}
        self._live_cfg: dict[str, tuple[int, int, str]] = {}
        self._live_new: dict[str, int] = {}

    @classmethod
    def for_index(cls, index, cache: TuningCache | None = None, **kw
                  ) -> "KernelTuner":
        # dict_ratio is None for all-raw stores, which disables the
        # compressed method cleanly; pass comp_ratio explicitly to override
        if "comp_ratio" not in kw:
            ratio_fn = getattr(index.storage, "dict_ratio", None)
            kw["comp_ratio"] = ratio_fn() if callable(ratio_fn) else None
        return cls(index.storage.shape[0], index.storage.shape[1],
                   index.params.n_hashes, index.layout.n_blocks,
                   cache, **kw)

    # -- synthetic measurement fixture --------------------------------------
    def _tune_arena(self) -> jnp.ndarray:
        if self._arena is None:
            rng = np.random.default_rng(self.seed)
            rows = max(8, min(self.n_rows, self.max_tune_rows))
            self._arena = jnp.asarray(rng.integers(
                0, 2 ** 32, size=(rows, self.doc_words), dtype=np.uint32))
        return self._arena

    def _tune_dict(self) -> tuple:
        """Synthetic (dict_rows, refs) at the index's measured ratio: the
        tuning arena's first ~R/ratio rows as the dictionary, refs drawn
        uniformly — the fused-decode kernels then stream a dict working
        set of the size the real compressed shards would."""
        if self._dict is None:
            arena = self._tune_arena()
            R = int(arena.shape[0])
            ratio = max(1.0, self.comp_ratio or 1.0)
            D = _pad_unique(max(8, int(round(R / ratio))))
            rng = np.random.default_rng(self.seed + 3)
            self._dict = (arena[: min(D, R)],
                          jnp.asarray(rng.integers(
                              0, min(D, R), size=R).astype(np.int32)))
        return self._dict

    def _batch_fixture(self, bucket: int, batch: int, n_unique: int | None
                       ) -> tuple:
        """(idx [Q, nb, L], mask) drawing rows from ``n_unique`` distinct
        values (None = unconstrained, the fused kernel's fixture)."""
        rng = np.random.default_rng(self.seed + bucket * 31 + batch)
        nb = max(1, min(self.n_blocks, self.max_tune_blocks))
        R = int(self._tune_arena().shape[0])
        n = batch * nb * bucket
        if n_unique is None:
            idx = rng.integers(0, R, size=(batch, nb, bucket))
        elif n_unique >= min(n, R):
            # as-disjoint-as-the-arena-allows: every cell a distinct row
            # (wrapping only when the batch outsizes the tuning arena)
            idx = np.resize(rng.permutation(R), n).reshape(
                batch, nb, bucket)
        else:
            pool = rng.choice(R, size=n_unique, replace=False)
            idx = rng.choice(pool, size=(batch, nb, bucket))
        mask = np.ones((batch, nb, bucket), dtype=np.int32)
        return idx.astype(np.int32), mask

    # -- measurement --------------------------------------------------------
    def _measure_fused(self, bucket: int, batch: int, word_block: int,
                       grid_order: str) -> float:
        arena = self._tune_arena()
        idx, mask = self._batch_fixture(bucket, batch, None)
        idx_d, mask_d = jnp.asarray(idx), jnp.asarray(mask)
        return _timeit(
            lambda: ops.bitslice_lookup_score_multi(
                arena, idx_d, mask_d, word_block=word_block,
                grid_order=grid_order).block_until_ready(),
            self.repeats)

    def _measure_fused_c(self, bucket: int, batch: int, word_block: int,
                         grid_order: str) -> float:
        dict_rows, refs = self._tune_dict()
        idx, mask = self._batch_fixture(bucket, batch, None)
        idx_d, mask_d = jnp.asarray(idx), jnp.asarray(mask)
        return _timeit(
            lambda: ops.bitslice_lookup_score_multi_comp(
                dict_rows, refs, idx_d, mask_d, word_block=word_block,
                grid_order=grid_order).block_until_ready(),
            self.repeats)

    def _measure_dedup(self, bucket: int, batch: int, word_block: int,
                       n_unique: int, compressed: bool = False
                       ) -> tuple[float, int]:
        """(seconds, ACTUAL padded unique-row count). The fixture's real
        unique count is capped by the tuning arena height and reduced by
        with-replacement draws, so the break-even fit must use the U the
        kernel really gathered, not the requested target. ``compressed``
        measures the fused-decode dedup pair against the dict fixture."""
        arena = self._tune_arena()
        idx, mask = self._batch_fixture(bucket, batch, n_unique)
        uniq, inv = np.unique(idx, return_inverse=True)
        indir = inv.reshape(idx.shape).astype(np.int32)
        uniq_pad = np.zeros(_pad_unique(uniq.size), dtype=np.int32)
        uniq_pad[: uniq.size] = uniq
        u_d, i_d, m_d = (jnp.asarray(uniq_pad), jnp.asarray(indir),
                         jnp.asarray(mask))
        if compressed:
            dict_rows, refs = self._tune_dict()
            t = _timeit(
                lambda: ops.bitslice_lookup_score_dedup_comp(
                    dict_rows, refs, u_d, i_d, m_d,
                    word_block=word_block).block_until_ready(),
                self.repeats)
        else:
            t = _timeit(
                lambda: ops.bitslice_lookup_score_dedup(
                    arena, u_d, i_d, m_d,
                    word_block=word_block).block_until_ready(),
                self.repeats)
        return t, int(uniq_pad.size)

    def _measure_plan_host(self, bucket: int, batch: int) -> float:
        """Host-side dedup PLANNING cost for this batch shape: the
        np.unique over all live (block, row) cells plus the indirection
        scatter — the work repro.core.query.plan_dedup_batch does per
        batch per shard before the dedup kernels can run. The break-even
        fit must charge this against the dedup path: a dedup dispatch
        that beats the fused kernel on device but loses the difference
        to host planning is a net regression."""
        idx, mask = self._batch_fixture(bucket, batch, None)
        live_mask = mask.astype(bool)

        def plan() -> None:
            live = idx[live_mask]
            uniq, inv = np.unique(live, return_inverse=True)
            indir = np.zeros(idx.shape, dtype=np.int32)
            indir[live_mask] = np.asarray(inv).reshape(-1).astype(np.int32)

        return _timeit(plan, self.repeats)

    def _measure_add(self, method: str, bucket: int, batch: int,
                     word_block: int, term_block: int) -> float:
        """unpack/vertical dispatch cost INCLUDING the arena gather the
        serving path performs before the ADD step (make_score_fn
        materializes arena[rows] then scores) — the fused lookup's cost
        has its gather in-kernel, so comparing add-only numbers against
        it would systematically favor the materialized path. k>1's AND
        is omitted (one extra vector op per word; negligible next to the
        gather + expansion)."""
        import jax
        arena = self._tune_arena()
        R = int(arena.shape[0])
        nb = max(1, min(self.n_blocks, self.max_tune_blocks))
        rng = np.random.default_rng(self.seed + 1)
        idx = jnp.asarray(rng.integers(
            0, R, size=(batch, bucket, nb)).astype(np.int32))

        def one(idx_q):
            flat = arena[idx_q].reshape(bucket, nb * self.doc_words)
            return ops.bitslice_score(flat, method=method,
                                      word_block=word_block,
                                      term_block=term_block)

        fn = jax.jit(jax.vmap(one))
        return _timeit(lambda: fn(idx).block_until_ready(), self.repeats)

    def _measure_chunk(self, bucket: int, batch: int, word_block: int,
                       chunk: int) -> float:
        """One pruned-executor chunk dispatch at the WORST case: no block
        pruned yet, every (query, block, term) cell touching a distinct
        row — the per-chunk cost ``run_paged_pruned`` pays before any
        bound fires. The timed body includes the host row gather the
        executor performs per chunk (rows stream out of the mmap, not a
        staged tile) plus the accumulate kernel."""
        arena = self._tune_arena()
        host = np.asarray(arena)
        R = int(host.shape[0])
        nb = max(1, min(self.n_blocks, self.max_tune_blocks))
        chunk = max(1, min(chunk, bucket))
        rng = np.random.default_rng(self.seed + 7)
        idx = rng.integers(0, R, size=(batch, nb, chunk))
        uniq, inv = np.unique(idx, return_inverse=True)
        indir = jnp.asarray(np.asarray(inv).reshape(idx.shape)
                            .astype(np.int32))
        mask = jnp.asarray(np.ones(idx.shape, dtype=np.int32))
        u_pad = _pad_unique(uniq.size)
        acc = ops.chunk_acc_init(batch, nb, self.doc_words, word_block)

        def one() -> None:
            rows = np.zeros((u_pad, self.doc_words), dtype=np.uint32)
            rows[: uniq.size] = host[uniq]
            out, bmax = ops.bitslice_chunk_score_dedup(
                jnp.asarray(rows), indir, mask, acc,
                word_block=word_block)
            bmax.block_until_ready()

        return _timeit(one, self.repeats)

    def _dedup_threshold(self, bucket: int, batch: int, word_block: int,
                         fused_s: float, compressed: bool = False
                         ) -> float | None:
        """Break-even dedup rate from two measured unique fractions.

        The dedup cost is ~linear in the unique-row count U (the gather
        streams U rows; the indirected score is U-independent): measure a
        near-disjoint fixture and a ~90%-shared one, fit cost(U) = a + b*U
        through the ACTUAL padded unique counts each fixture produced
        (targets are capped by the tuning arena height and shrunk by
        with-replacement draws — fitting at the requested targets would
        flatten the slope and poison the cached threshold), add the
        measured HOST planning cost (hash/unique/indirection, which only
        the dedup path pays), and solve cost(U*) + host == fused.
        threshold = 1 - U*/N. Returns 2.0 (unreachable rate = measured,
        never wins) when even the heavily-shared measurement plus its
        planning loses to the fused kernel."""
        n = batch * max(1, min(self.n_blocks, self.max_tune_blocks)) * bucket
        d_hi, u_hi = self._measure_dedup(bucket, batch, word_block, n,
                                         compressed)
        d_lo, u_lo = self._measure_dedup(bucket, batch, word_block,
                                         max(8, n // 10), compressed)
        host = self._measure_plan_host(bucket, batch)
        if u_lo >= u_hi:
            return None                       # fixtures indistinguishable
        if d_lo + host >= fused_s:
            return 2.0                        # measured: dedup never wins
        if d_hi + host <= fused_s:
            return 0.0                        # dedup wins even disjoint
        b = (d_hi - d_lo) / (u_hi - u_lo)
        if b <= 0:
            return 0.0
        a = d_hi - b * u_hi
        u_star = (fused_s - host - a) / b
        return float(min(1.0, max(0.0, 1.0 - u_star / n)))

    def _tune(self, method: str, bucket: int, batch: int) -> TunedEntry:
        self.tunes += 1
        best = None
        if method == "lookup_p":
            # Pruned (chunked) executor break-even. Field reuse on the
            # returned entry: ``term_block`` carries the tuned CHUNK SIZE
            # and ``dedup_threshold`` the minimum predicted PRUNE RATE at
            # which chunked execution beats the best unpruned dispatch
            # (0.0 = pruned wins even with nothing pruned, 2.0 = measured
            # and pruned never wins). cost_us is the worst-case (nothing
            # pruned) full-query chunked cost.
            chunk = max(1, min(PRUNE_TUNE_CHUNK, bucket))
            n_chunks = -(-bucket // chunk)
            for wb in self.word_blocks:
                t = self._measure_chunk(bucket, batch, wb, chunk)
                if best is None or t < best[0]:
                    best = (t, wb)
            c0, wb = best
            full = c0 * n_chunks
            if self.n_hashes == 1:
                fused = min(self._measure_fused(bucket, batch, wb, go)
                            for go in self.grid_orders)
            else:
                fused = self._measure_add("vertical", bucket, batch, wb,
                                          _k.DEFAULT_TERM_BLOCK)
            # Expected pruned cost at prune rate p is ~ full - p*(full -
            # c0): the first chunk always runs in full, later chunks skip
            # pruned blocks. Solve full - p*(full - c0) <= fused.
            if full <= fused:
                thr = 0.0
            elif fused <= c0 or full <= c0:
                thr = 2.0
            else:
                thr = float(min(1.0, max(
                    0.0, (full - fused) / (full - c0))))
            return TunedEntry("lookup_p", wb, chunk, "wq", full * 1e6,
                              dedup_threshold=thr)
        if method in ("lookup", "lookup_c"):
            compressed = method == "lookup_c"
            measure = (self._measure_fused_c if compressed
                       else self._measure_fused)
            for wb in self.word_blocks:
                for go in self.grid_orders:
                    t = measure(bucket, batch, wb, go)
                    if best is None or t < best[0]:
                        best = (t, wb, _k.DEFAULT_TERM_BLOCK, go)
            t, wb, tb, go = best
            thr = self._dedup_threshold(bucket, batch, wb, t, compressed)
            return TunedEntry(method, wb, tb, go, t * 1e6,
                              dedup_threshold=thr)
        for wb in self.word_blocks:
            for tb in self.term_blocks:
                t = self._measure_add(method, bucket, batch, wb, tb)
                if best is None or t < best[0]:
                    best = (t, wb, tb, "wq")
        t, wb, tb, go = best
        return TunedEntry(method, wb, tb, go, t * 1e6)

    # -- public surface ------------------------------------------------------
    def key(self, method: str, bucket: int, batch: int) -> str:
        k = tuning_key(self.n_rows, self.doc_words, self.n_hashes,
                       self.n_blocks, method, bucket, batch)
        if method == "lookup_c" and self.comp_ratio is not None:
            # decode cost depends on the dict working-set size: a store
            # rebuilt at a different ratio must re-measure, not hit
            k += f".cr{self.comp_ratio:.2f}"
        return k

    def entry(self, method: str, bucket: int, batch: int
              ) -> TunedEntry | None:
        """Cached entry for (method, bucket, batch); tunes + persists on a
        miss when enabled, else returns None (caller falls back to
        heuristics).

        A live observed-cost entry (serving-measured, LIVE_PREFIX key)
        is preferred over the synthetic-tuned one when present — it
        reflects the REAL arena, cache residency and batch mix rather
        than the tuning fixture — and also suppresses a synthetic tune
        on a cold cache (a measurement already exists). The synthetic
        entry's dedup_threshold is grafted on because live entries never
        carry one (the profiler sees only dispatched configurations)."""
        if method in ("lookup", "lookup_c") and self.n_hashes != 1:
            return None
        if method == "lookup_c" and self.comp_ratio is None:
            return None               # no dict-coded shards to decode from
        key = self.key(method, bucket, batch)
        live = (self.cache.entries.get(LIVE_PREFIX + key)
                if self.prefer_observed else None)
        e = self.cache.get(key)
        if e is None and self.enabled and live is None:
            e = self._tune(method, bucket, batch)
            self.cache.put(key, e)
            self.cache.save()
        if live is not None:
            if (e is not None and live.dedup_threshold is None
                    and e.dedup_threshold is not None):
                live = dataclasses.replace(
                    live, dedup_threshold=e.dedup_threshold)
            return live
        return e

    def observe(self, method: str, bucket: int, batch: int,
                seconds: float, *, word_block: int,
                term_block: int = 0, grid_order: str = "wq") -> None:
        """Feed one LIVE kernel measurement (from the KernelProfiler)
        into the cost cache. Samples accumulate per tuning key; every
        ``live_min_samples`` new ones the rolling median is promoted to
        an ``observed=True`` entry under LIVE_PREFIX and persisted.
        Non-tunable methods (e.g. the dedup pair, chosen by threshold
        rather than cost argmin) are ignored."""
        if method not in TUNABLE_METHODS:
            return
        key = self.key(method, bucket, batch)
        with self._live_lock:
            q = self._live_samples.get(key)
            if q is None:
                q = self._live_samples[key] = deque(maxlen=64)
            q.append(float(seconds))
            self._live_cfg[key] = (int(word_block),
                                   int(term_block) or _k.DEFAULT_TERM_BLOCK,
                                   str(grid_order))
            self.observations += 1
            self._live_new[key] = self._live_new.get(key, 0) + 1
            if (len(q) < self.live_min_samples
                    or self._live_new[key] < self.live_min_samples):
                return
            self._live_new[key] = 0
            cost_us = float(np.median(np.fromiter(q, float))) * 1e6
            wb, tb, go = self._live_cfg[key]
            entry = TunedEntry(method, wb, tb, go, cost_us, observed=True)
        self.cache.put(LIVE_PREFIX + key, entry)
        self.cache.save()

    def costs(self, bucket: int, batch: int,
              methods: tuple[str, ...] = TUNABLE_METHODS
              ) -> dict[str, TunedEntry]:
        """Entries for every applicable method of a batch shape (the
        planner's cost table)."""
        out = {}
        for m in methods:
            e = self.entry(m, bucket, batch)
            if e is not None:
                out[m] = e
        return out
