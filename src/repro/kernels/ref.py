"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its semantics defined HERE; tests sweep
shapes/dtypes and assert the kernels match these references exactly
(integer outputs -> exact equality, not just allclose).
"""
from __future__ import annotations

import jax.numpy as jnp


def bitslice_score_ref(rows: jnp.ndarray) -> jnp.ndarray:
    """Score ADD step of the query (paper Fig. 3, right).

    rows: uint32 [L, W] — one packed, already-ANDed/masked row per query term
          (bit d%32 of word d//32 == term present in document d).
    returns: int32 [W * 32] — per-document score = number of terms whose row
          has the document's bit set. Document order is word-major, LSB-first.
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((rows[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    return bits.sum(axis=0).reshape(-1)


def bitslice_lookup_score_ref(
    arena: jnp.ndarray, rows_idx: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Fused GATHER + ADD: score directly from the arena.

    arena:    uint32 [R, W] bit-sliced matrix
    rows_idx: int32  [L]    row of each term (invalid terms may point anywhere)
    mask:     int32  [L]    1 = count this term, 0 = ignore
    returns:  int32  [W * 32]
    """
    gathered = arena[rows_idx]                      # [L, W]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((gathered[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    return (bits * mask[:, None, None]).sum(axis=0).reshape(-1)


def bitslice_lookup_score_blocks_ref(
    arena: jnp.ndarray, rows_idx: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Multi-block fused GATHER + ADD oracle.

    arena uint32 [R, W]; rows_idx int32 [nb, L]; mask int32 [nb, L]
    -> int32 [nb * W * 32] in (block, word, bit) order.
    """
    gathered = arena[rows_idx]                        # [nb, L, W]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, None, :]
    bits = ((gathered[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    bits = bits * mask[:, :, None, None]
    return bits.sum(axis=1).reshape(-1)               # sum over L


def bitslice_lookup_score_multi_ref(
    arena: jnp.ndarray, rows_idx: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Multi-query multi-block fused GATHER + ADD oracle.

    arena uint32 [R, W]; rows_idx int32 [Q, nb, L]; mask int32 [Q, nb, L]
    -> int32 [Q, nb * W * 32], each query in (block, word, bit) slot order.
    """
    Q = rows_idx.shape[0]
    gathered = arena[rows_idx]                        # [Q, nb, L, W]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, None, None, :]
    bits = ((gathered[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    bits = bits * mask[:, :, :, None, None]
    return bits.sum(axis=2).reshape(Q, -1)            # sum over L


def bitslice_lookup_score_dedup_ref(
    arena: jnp.ndarray, uniq_rows: jnp.ndarray, indir: jnp.ndarray,
    mask: jnp.ndarray
) -> jnp.ndarray:
    """Row-dedup GATHER + indirected ADD oracle.

    arena uint32 [R, W]; uniq_rows int32 [U] (each arena row listed once);
    indir int32 [Q, nb, L] (index into uniq_rows per term); mask int32
    [Q, nb, L] -> int32 [Q, nb * W * 32]. Identical to
    ``bitslice_lookup_score_multi_ref(arena, uniq_rows[indir], mask)`` —
    the dedup path is pure re-addressing, never a semantic change.
    """
    return bitslice_lookup_score_multi_ref(arena, uniq_rows[indir], mask)


def and_rows_ref(rows: jnp.ndarray) -> jnp.ndarray:
    """AND step over the k hash functions: uint32 [L, k, W] -> [L, W]."""
    out = rows[:, 0]
    for i in range(1, rows.shape[1]):
        out = out & rows[:, i]
    return out
