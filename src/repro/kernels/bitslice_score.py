"""Pallas TPU kernels for the COBS query hot loop.

The query's per-term work is: fetch the term's bit-sliced row (W uint32
words = 32W documents), and accumulate each document's bit into its int32
score. On the paper's CPU this is the SSE LUT expansion; on TPU we target
the VPU with three designs:

1. ``unpack`` — paper-faithful analogue: every row word is expanded to 32
   int32 lanes via shift-and-mask and summed. O(32) vector ops per word.
   BlockSpec tiles (term_block x word_block) keep the working set in VMEM.

2. ``vertical`` — beyond-paper: Harley–Seal style bit-sliced counters.
   Per word column we keep ceil(log2(L+1)) uint32 counter *planes*; adding a
   row is a ripple-carry (AND/XOR chain) across planes — O(2 log2 L) vector
   ops per word instead of O(32); the expensive 32-way expansion happens
   once at the end instead of once per term. For ell >= ~100 terms this cuts
   VPU work by 3-6x and is the preferred production path.

3. ``lookup`` (fused) — gathers rows straight from the arena in HBM using
   scalar-prefetched row indices, so the [L, W] gathered matrix never
   materializes in HBM. This is the TPU analogue of the paper's streaming
   row scan from NVMe: row -> VMEM tile -> accumulate.

All kernels share the oracle semantics of ref.bitslice_score_ref. Tile sizes
default to (8 terms x 128 words) = (sublane x lane) alignment; uint32 words
* 128 lanes = 4096 documents per tile column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TERM_BLOCK = 8     # sublane-aligned
DEFAULT_WORD_BLOCK = 128   # lane-aligned


def _num_planes(n_terms: int) -> int:
    return max(1, (int(n_terms)).bit_length())


# --------------------------------------------------------------------------
# 1. unpack kernel (paper-faithful ADD step)
# --------------------------------------------------------------------------

def _unpack_kernel(rows_ref, out_ref):
    i_l = pl.program_id(1)
    block = rows_ref[...]                                   # uint32 [bl, bw]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((block[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    partial = bits.sum(axis=0)                              # int32 [bw, 32]

    @pl.when(i_l == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i_l > 0)
    def _acc():
        out_ref[...] += partial


def unpack_score(
    rows: jnp.ndarray,
    *,
    term_block: int = DEFAULT_TERM_BLOCK,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """uint32 [L, W] -> int32 [W, 32]; L % term_block == W % word_block == 0."""
    L, W = rows.shape
    grid = (W // word_block, L // term_block)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((term_block, word_block), lambda iw, il: (il, iw))],
        out_specs=pl.BlockSpec((word_block, 32), lambda iw, il: (iw, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 32), jnp.int32),
        interpret=interpret,
    )(rows)


# --------------------------------------------------------------------------
# 2. vertical (Harley–Seal bit-sliced counter) kernel
# --------------------------------------------------------------------------

def _vertical_kernel(rows_ref, out_ref, planes_ref, *, n_planes: int,
                     term_block: int):
    i_l = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(i_l == 0)
    def _init():
        planes_ref[...] = jnp.zeros_like(planes_ref)

    block = rows_ref[...]                                   # uint32 [bl, bw]

    # Ripple-carry each of the bl rows into the counter planes. The loop over
    # rows is unrolled (bl is small/static); each row costs 2*n_planes vector
    # bit-ops on [bw] lanes — this is the entire per-term inner loop.
    planes = [planes_ref[j, :] for j in range(n_planes)]
    for r in range(term_block):
        carry = block[r, :]
        for j in range(n_planes):
            new_carry = planes[j] & carry
            planes[j] = planes[j] ^ carry
            carry = new_carry
        # counts < 2^n_planes by construction; carry out of the top plane
        # cannot happen (n_planes = ceil(log2(L+1))).
    for j in range(n_planes):
        planes_ref[j, :] = planes[j]

    @pl.when(i_l == n_l - 1)
    def _expand():
        # one-time expansion: count[d] = sum_j bit_j(plane_j) << j
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
        acc = jnp.zeros(out_ref.shape, jnp.int32)
        for j in range(n_planes):
            bits = ((planes_ref[j, :][:, None] >> shifts) & jnp.uint32(1))
            acc += bits.astype(jnp.int32) << j
        out_ref[...] = acc


def vertical_score(
    rows: jnp.ndarray,
    *,
    term_block: int = DEFAULT_TERM_BLOCK,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """uint32 [L, W] -> int32 [W, 32] via bit-sliced vertical counters."""
    L, W = rows.shape
    n_planes = _num_planes(L)
    grid = (W // word_block, L // term_block)
    kernel = functools.partial(
        _vertical_kernel, n_planes=n_planes, term_block=term_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((term_block, word_block), lambda iw, il: (il, iw))],
        out_specs=pl.BlockSpec((word_block, 32), lambda iw, il: (iw, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 32), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
        interpret=interpret,
    )(rows)


# --------------------------------------------------------------------------
# 3. fused lookup+score kernel (scalar-prefetched row gather from the arena)
# --------------------------------------------------------------------------

def _lookup_kernel(idx_ref, mask_ref, arena_ref, out_ref, planes_ref, *,
                   n_planes: int):
    i_l = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(i_l == 0)
    def _init():
        planes_ref[...] = jnp.zeros_like(planes_ref)

    row = arena_ref[0, :]                                   # uint32 [bw]
    row = row * mask_ref[i_l].astype(jnp.uint32)            # mask invalid term
    carry = row
    for j in range(n_planes):
        new_carry = planes_ref[j, :] & carry
        planes_ref[j, :] = planes_ref[j, :] ^ carry
        carry = new_carry

    @pl.when(i_l == n_l - 1)
    def _expand():
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
        acc = jnp.zeros(out_ref.shape, jnp.int32)
        for j in range(n_planes):
            bits = ((planes_ref[j, :][:, None] >> shifts) & jnp.uint32(1))
            acc += bits.astype(jnp.int32) << j
        out_ref[...] = acc


def _lookup_blocks_kernel(idx_ref, mask_ref, arena_ref, out_ref, planes_ref,
                          *, n_planes: int):
    il = pl.program_id(2)
    n_l = pl.num_programs(2)

    @pl.when(il == 0)
    def _init():
        planes_ref[...] = jnp.zeros_like(planes_ref)

    ib = pl.program_id(1)
    row = arena_ref[0, :] * mask_ref[ib, il].astype(jnp.uint32)
    carry = row
    for j in range(n_planes):
        new_carry = planes_ref[j, :] & carry
        planes_ref[j, :] = planes_ref[j, :] ^ carry
        carry = new_carry

    @pl.when(il == n_l - 1)
    def _expand():
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
        acc = jnp.zeros(out_ref.shape[1:], jnp.int32)
        for j in range(n_planes):
            bits = ((planes_ref[j, :][:, None] >> shifts) & jnp.uint32(1))
            acc += bits.astype(jnp.int32) << j
        out_ref[0] = acc


def lookup_score_blocks(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-block fused gather+score (the compact-index hot loop).

    arena uint32 [R, W]; rows_idx int32 [nb, L] (term row per sub-index
    block); mask int32 [nb, L] -> int32 [nb, W, 32]. Each (word-tile, block)
    cell streams its L rows HBM->VMEM via scalar-prefetched indices and
    accumulates vertical (Harley-Seal) counters — the [L, nb, W] gathered
    intermediate of the unfused path never exists.
    """
    R, W = arena.shape
    nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, nb, L),
        in_specs=[
            pl.BlockSpec((1, word_block),
                         lambda iw, ib, il, idx, msk: (idx[ib, il], iw)),
        ],
        out_specs=pl.BlockSpec((1, word_block, 32),
                               lambda iw, ib, il, idx, msk: (ib, iw, 0)),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_lookup_blocks_kernel, n_planes=n_planes)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, arena)


def _lookup_multi_kernel(idx_ref, mask_ref, arena_ref, out_ref, planes_ref,
                         *, n_planes: int, q_axis: int = 1, b_axis: int = 2):
    il = pl.program_id(3)
    n_l = pl.num_programs(3)

    @pl.when(il == 0)
    def _init():
        planes_ref[...] = jnp.zeros_like(planes_ref)

    iq = pl.program_id(q_axis)
    ib = pl.program_id(b_axis)
    row = arena_ref[0, :] * mask_ref[iq, ib, il].astype(jnp.uint32)
    carry = row
    for j in range(n_planes):
        new_carry = planes_ref[j, :] & carry
        planes_ref[j, :] = planes_ref[j, :] ^ carry
        carry = new_carry

    @pl.when(il == n_l - 1)
    def _expand():
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
        acc = jnp.zeros(out_ref.shape[2:], jnp.int32)
        for j in range(n_planes):
            bits = ((planes_ref[j, :][:, None] >> shifts) & jnp.uint32(1))
            acc += bits.astype(jnp.int32) << j
        out_ref[0, 0] = acc


GRID_ORDERS = ("wq", "qw")


def lookup_score_multi(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    grid_order: str = "wq",
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused MULTI-QUERY gather+score (the batched-serving hot loop).

    arena uint32 [R, W]; rows_idx int32 [Q, nb, L] (term row per query per
    sub-index block); mask int32 [Q, nb, L] -> int32 [Q, nb, W, 32].

    The batched generalization of lookup_score_blocks: the grid grows a
    query dimension, every (word-tile, query, block) cell streams its L
    rows HBM->VMEM via scalar-prefetched indices and keeps Harley-Seal
    counter planes in a single shared VMEM scratch. Queries share arena
    tiles through the same BlockSpec pipeline, so a batch never
    materializes the [Q, L, W] gather that forces the unfused path to the
    pure-jnp ref scorer under vmap.

    ``grid_order`` permutes the outer grid axes (autotuner knob):
    'wq' = (word, query, block, term) — word tiles outermost, so one
    query's whole accumulation streams before the next word tile; 'qw' =
    (query, block, word, term) — queries outermost, so a query's term rows
    stream word-tile by word-tile. Term stays innermost either way (the
    counter-plane scratch accumulates over it); both orders are
    bit-identical and differ only in DMA locality.
    """
    R, W = arena.shape
    Q, nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    if grid_order == "wq":
        grid = (W // word_block, Q, nb, L)
        arena_map = lambda iw, iq, ib, il, idx, msk: (idx[iq, ib, il], iw)
        out_map = lambda iw, iq, ib, il, idx, msk: (iq, ib, iw, 0)
        q_axis, b_axis = 1, 2
    elif grid_order == "qw":
        grid = (Q, nb, W // word_block, L)
        arena_map = lambda iq, ib, iw, il, idx, msk: (idx[iq, ib, il], iw)
        out_map = lambda iq, ib, iw, il, idx, msk: (iq, ib, iw, 0)
        q_axis, b_axis = 0, 1
    else:
        raise ValueError(f"unknown grid_order {grid_order!r}; "
                         f"one of {GRID_ORDERS}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec((1, word_block), arena_map)],
        out_specs=pl.BlockSpec((1, 1, word_block, 32), out_map),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_lookup_multi_kernel, n_planes=n_planes,
                               q_axis=q_axis, b_axis=b_axis)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, arena)


# --------------------------------------------------------------------------
# 4. batched row-dedup pair: unique-row gather + indirected score
# --------------------------------------------------------------------------
#
# Real serving batches share rows heavily (overlapping k-mers between
# queries), but the fused multi-query kernel re-DMAs an arena tile from HBM
# for every (query, block, term) grid cell. The dedup pair makes arena
# traffic scale with UNIQUE rows instead:
#
#   gather_rows   — streams each unique arena row HBM->VMEM exactly ONCE
#                   and writes the compact [U, W] unique-row matrix.
#   dedup_score   — scores every (query, block) cell against that compact
#                   matrix: the [U_pad, word_block] tile is one pipeline
#                   block whose index map depends only on the word axis, so
#                   it stays resident in VMEM across all (query, block)
#                   steps of a word tile; per term the kernel reads the
#                   indirection index from scalar memory and ripple-carries
#                   the VMEM row into Harley-Seal counter planes.
#
# Host-side planning (repro.core.query.plan_dedup_batch) builds the unique
# row list and the [Q, nb, L] indirection.


def _gather_kernel(idx_ref, arena_ref, out_ref):
    out_ref[...] = arena_ref[...]


def gather_rows(
    arena: jnp.ndarray,
    uniq_idx: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Unique-row gather: (arena uint32 [R, W], uniq_idx int32 [U]) ->
    uint32 [U, W]. Each arena row tile is DMA'd HBM->VMEM exactly once —
    U * (W / word_block) row-tile transfers total, however many query
    cells reference the row downstream."""
    R, W = arena.shape
    U = uniq_idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(W // word_block, U),
        in_specs=[
            pl.BlockSpec((1, word_block), lambda iw, iu, idx: (idx[iu], iw)),
        ],
        out_specs=pl.BlockSpec((1, word_block), lambda iw, iu, idx: (iu, iw)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((U, W), jnp.uint32),
        interpret=interpret,
    )(uniq_idx, arena)


def _dedup_score_kernel(indir_ref, mask_ref, uniq_ref, out_ref, *,
                        n_planes: int, n_terms: int):
    iq = pl.program_id(1)
    ib = pl.program_id(2)
    wb = uniq_ref.shape[1]

    def add_term(il, planes):
        u = indir_ref[iq, ib, il]
        row = (uniq_ref[pl.ds(u, 1), :][0]
               * mask_ref[iq, ib, il].astype(jnp.uint32))
        carry = row
        nxt = []
        for j in range(n_planes):
            new_carry = planes[j] & carry
            nxt.append(planes[j] ^ carry)
            carry = new_carry
        return tuple(nxt)

    planes = tuple(jnp.zeros((wb,), jnp.uint32) for _ in range(n_planes))
    planes = jax.lax.fori_loop(0, n_terms, add_term, planes)

    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    acc = jnp.zeros((wb, 32), jnp.int32)
    for j in range(n_planes):
        bits = ((planes[j][:, None] >> shifts) & jnp.uint32(1))
        acc += bits.astype(jnp.int32) << j
    out_ref[0, 0] = acc


def dedup_score(
    uniq: jnp.ndarray,
    indir: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Indirected multi-query score over a gathered unique-row matrix.

    uniq uint32 [U, W] (from ``gather_rows``); indir int32 [Q, nb, L]
    (index into uniq per term); mask int32 [Q, nb, L] -> int32
    [Q, nb, W, 32].

    The [U, word_block] block's index map depends only on the word axis,
    so the Pallas pipeline re-DMAs it ONLY when the word tile changes —
    every (query, block) cell of a word tile scores against the same
    resident VMEM copy, which is where the cross-query arena-tile reuse
    the fused kernel lacks comes from.
    """
    U, W = uniq.shape
    Q, nb, L = indir.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, Q, nb),
        in_specs=[
            pl.BlockSpec((U, word_block),
                         lambda iw, iq, ib, ind, msk: (0, iw)),
        ],
        out_specs=pl.BlockSpec((1, 1, word_block, 32),
                               lambda iw, iq, ib, ind, msk: (iq, ib, iw, 0)),
    )
    kernel = functools.partial(_dedup_score_kernel, n_planes=n_planes,
                               n_terms=L)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(indir, mask, uniq)


# --------------------------------------------------------------------------
# 5. fused-decode kernels: score straight off the rowdict-compressed arena
# --------------------------------------------------------------------------
#
# A rowdict-coded shard lives in HBM as (dict_rows uint32 [D, W], refs int32
# [R]) with D << R — the DeviceTileCache stages that pair instead of the
# expanded tile, shrinking the HBM working set by the shard's ratio. The
# kernels below decode by ONE extra scalar indirection in the BlockSpec
# index map: where the raw kernels DMA ``arena[row]``, these DMA
# ``dict[refs[row]]``. refs ride the scalar-prefetch channel (SMEM), so
# rows decompress on the way HBM->VMEM — no expanded tile ever exists in
# HBM, and effective gather bandwidth multiplies by R/D when queries hit
# duplicate rows. Bit-identical to the raw kernels by construction
# (dict[refs[row]] == arena[row]); property-tested in
# tests/test_compression.py.


def gather_rows_compressed(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    uniq_idx: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused decode+gather: (dict uint32 [D, W], refs int32 [R], uniq_idx
    int32 [U]) -> uint32 [U, W] with out[u] = dict[refs[uniq_idx[u]]].

    The compressed twin of ``gather_rows``: feeds ``dedup_score``
    unchanged. The double indirection collapses at grid-index time —
    both lookups are scalar reads, the DMA itself moves one dict row
    tile, so duplicate rows ACROSS the unique set still cost one dict
    slot each in cache-resident HBM."""
    D, W = dict_rows.shape
    U = uniq_idx.shape[0]

    def kernel(idx_ref, refs_ref, dict_ref, out_ref):
        del idx_ref, refs_ref            # consumed by the index map
        out_ref[...] = dict_ref[...]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, U),
        in_specs=[
            pl.BlockSpec((1, word_block),
                         lambda iw, iu, idx, refs: (refs[idx[iu]], iw)),
        ],
        out_specs=pl.BlockSpec((1, word_block),
                               lambda iw, iu, idx, refs: (iu, iw)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((U, W), jnp.uint32),
        interpret=interpret,
    )(uniq_idx, refs, dict_rows)


def _lookup_multi_comp_kernel(idx_ref, mask_ref, refs_ref, arena_ref,
                              out_ref, planes_ref, *, n_planes: int,
                              q_axis: int = 1, b_axis: int = 2):
    # Same body as _lookup_multi_kernel; refs_ref is consumed by the
    # BlockSpec index map (the decode), not by the compute.
    del refs_ref
    _lookup_multi_kernel(idx_ref, mask_ref, arena_ref, out_ref, planes_ref,
                         n_planes=n_planes, q_axis=q_axis, b_axis=b_axis)


def lookup_score_multi_compressed(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    grid_order: str = "wq",
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode-in-the-loop variant of ``lookup_score_multi``: (dict uint32
    [D, W], refs int32 [R], rows_idx int32 [Q, nb, L], mask int32
    [Q, nb, L]) -> int32 [Q, nb, W, 32], scoring ``dict[refs[row]]``
    where the raw kernel scores ``arena[row]``. Duplicate rows within AND
    across queries resolve to the same dict slot, so repeated terms hit
    tiles the pipeline already has in flight instead of new HBM traffic."""
    D, W = dict_rows.shape
    Q, nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    if grid_order == "wq":
        grid = (W // word_block, Q, nb, L)
        arena_map = (lambda iw, iq, ib, il, idx, msk, refs:
                     (refs[idx[iq, ib, il]], iw))
        out_map = lambda iw, iq, ib, il, idx, msk, refs: (iq, ib, iw, 0)
        q_axis, b_axis = 1, 2
    elif grid_order == "qw":
        grid = (Q, nb, W // word_block, L)
        arena_map = (lambda iq, ib, iw, il, idx, msk, refs:
                     (refs[idx[iq, ib, il]], iw))
        out_map = lambda iq, ib, iw, il, idx, msk, refs: (iq, ib, iw, 0)
        q_axis, b_axis = 0, 1
    else:
        raise ValueError(f"unknown grid_order {grid_order!r}; "
                         f"one of {GRID_ORDERS}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[pl.BlockSpec((1, word_block), arena_map)],
        out_specs=pl.BlockSpec((1, 1, word_block, 32), out_map),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_lookup_multi_comp_kernel, n_planes=n_planes,
                               q_axis=q_axis, b_axis=b_axis)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, refs, dict_rows)


def _lookup_blocks_comp_kernel(idx_ref, mask_ref, refs_ref, arena_ref,
                               out_ref, planes_ref, *, n_planes: int):
    del refs_ref
    _lookup_blocks_kernel(idx_ref, mask_ref, arena_ref, out_ref, planes_ref,
                          n_planes=n_planes)


def lookup_score_blocks_compressed(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode-in-the-loop variant of ``lookup_score_blocks`` (single-query
    compact hot loop): int32 [nb, W, 32] over ``dict[refs[row]]``."""
    D, W = dict_rows.shape
    nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(W // word_block, nb, L),
        in_specs=[
            pl.BlockSpec((1, word_block),
                         lambda iw, ib, il, idx, msk, refs:
                         (refs[idx[ib, il]], iw)),
        ],
        out_specs=pl.BlockSpec((1, word_block, 32),
                               lambda iw, ib, il, idx, msk, refs:
                               (ib, iw, 0)),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_lookup_blocks_comp_kernel, n_planes=n_planes)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, refs, dict_rows)


# --------------------------------------------------------------------------
# 6. chunked accumulator kernels: branch-and-bound pruned scoring
# --------------------------------------------------------------------------
#
# Threshold/top-k queries do not need every term scored before blocks can
# be discarded: after a PREFIX of the terms, any block whose best-possible
# final score (running count + terms remaining) cannot reach the required
# cutoff is dead. The chunked variants below score one term CHUNK and fold
# the partial counts into a persistent per-(query, block) running-count
# buffer ``acc`` — the executor (repro.core.query.run_paged_pruned) calls
# them once per surviving (chunk, shard) visit and derives the per-block
# survivor mask host-side from the block max of the returned buffer. All
# three fused forms exist: raw (tile-resident), dedup (host-gathered
# unique chunk rows — the out-of-core I/O saver), and _comp (fused decode
# off the rowdict pair). Bit-identity with the unchunked kernels is by
# construction: the sum over chunks telescopes into the full-term sum.


def _chunk_dedup_kernel(indir_ref, mask_ref, uniq_ref, acc_ref, out_ref, *,
                        n_planes: int, n_terms: int):
    iq = pl.program_id(1)
    ib = pl.program_id(2)
    wb = uniq_ref.shape[1]

    def add_term(il, planes):
        u = indir_ref[iq, ib, il]
        row = (uniq_ref[pl.ds(u, 1), :][0]
               * mask_ref[iq, ib, il].astype(jnp.uint32))
        carry = row
        nxt = []
        for j in range(n_planes):
            new_carry = planes[j] & carry
            nxt.append(planes[j] ^ carry)
            carry = new_carry
        return tuple(nxt)

    planes = tuple(jnp.zeros((wb,), jnp.uint32) for _ in range(n_planes))
    planes = jax.lax.fori_loop(0, n_terms, add_term, planes)

    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    acc = acc_ref[0, 0]
    for j in range(n_planes):
        bits = ((planes[j][:, None] >> shifts) & jnp.uint32(1))
        acc += bits.astype(jnp.int32) << j
    out_ref[0, 0] = acc


def chunk_dedup_score(
    uniq: jnp.ndarray,
    indir: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """``dedup_score`` over one term chunk, accumulated into ``acc``.

    uniq uint32 [U, W] (the chunk's unique rows, host-gathered so only
    the touched rows were ever read); indir/mask int32 [Q, nb, Lc];
    acc int32 [Q, nb, W, 32] (running counts) -> int32 [Q, nb, W, 32]
    with out = acc + chunk partial counts."""
    U, W = uniq.shape
    Q, nb, L = indir.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, Q, nb),
        in_specs=[
            pl.BlockSpec((U, word_block),
                         lambda iw, iq, ib, ind, msk: (0, iw)),
            pl.BlockSpec((1, 1, word_block, 32),
                         lambda iw, iq, ib, ind, msk: (iq, ib, iw, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, word_block, 32),
                               lambda iw, iq, ib, ind, msk: (iq, ib, iw, 0)),
    )
    kernel = functools.partial(_chunk_dedup_kernel, n_planes=n_planes,
                               n_terms=L)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(indir, mask, uniq, acc)


def _chunk_multi_kernel(idx_ref, mask_ref, arena_ref, acc_ref, out_ref,
                        planes_ref, *, n_planes: int):
    il = pl.program_id(3)
    n_l = pl.num_programs(3)

    @pl.when(il == 0)
    def _init():
        planes_ref[...] = jnp.zeros_like(planes_ref)

    iq = pl.program_id(1)
    ib = pl.program_id(2)
    row = arena_ref[0, :] * mask_ref[iq, ib, il].astype(jnp.uint32)
    carry = row
    for j in range(n_planes):
        new_carry = planes_ref[j, :] & carry
        planes_ref[j, :] = planes_ref[j, :] ^ carry
        carry = new_carry

    @pl.when(il == n_l - 1)
    def _expand():
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
        acc = acc_ref[0, 0]
        for j in range(n_planes):
            bits = ((planes_ref[j, :][:, None] >> shifts) & jnp.uint32(1))
            acc += bits.astype(jnp.int32) << j
        out_ref[0, 0] = acc


def chunk_lookup_score_multi(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """``lookup_score_multi`` over one term chunk, accumulated into ``acc``.

    Used by the pruned executor when the shard's full tile is already
    resident (promoted / cached) — the chunk's rows stream straight out of
    the staged tile, no host gather. rows_idx/mask int32 [Q, nb, Lc];
    acc int32 [Q, nb, W, 32] -> acc + chunk counts."""
    R, W = arena.shape
    Q, nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, Q, nb, L),
        in_specs=[
            pl.BlockSpec((1, word_block),
                         lambda iw, iq, ib, il, idx, msk:
                         (idx[iq, ib, il], iw)),
            pl.BlockSpec((1, 1, word_block, 32),
                         lambda iw, iq, ib, il, idx, msk: (iq, ib, iw, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, word_block, 32),
                               lambda iw, iq, ib, il, idx, msk:
                               (iq, ib, iw, 0)),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_chunk_multi_kernel, n_planes=n_planes)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, arena, acc)


def _chunk_multi_comp_kernel(idx_ref, mask_ref, refs_ref, arena_ref, acc_ref,
                             out_ref, planes_ref, *, n_planes: int):
    del refs_ref                 # consumed by the BlockSpec index map
    _chunk_multi_kernel(idx_ref, mask_ref, arena_ref, acc_ref, out_ref,
                        planes_ref, n_planes=n_planes)


def chunk_lookup_score_multi_compressed(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused-decode twin of ``chunk_lookup_score_multi``: the chunk's rows
    resolve ``dict[refs[row]]`` inside the gather, so a dict-coded shard
    scores chunks straight off its compressed (dict, refs) HBM pair."""
    D, W = dict_rows.shape
    Q, nb, L = rows_idx.shape
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(W // word_block, Q, nb, L),
        in_specs=[
            pl.BlockSpec((1, word_block),
                         lambda iw, iq, ib, il, idx, msk, refs:
                         (refs[idx[iq, ib, il]], iw)),
            pl.BlockSpec((1, 1, word_block, 32),
                         lambda iw, iq, ib, il, idx, msk, refs:
                         (iq, ib, iw, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, word_block, 32),
                               lambda iw, iq, ib, il, idx, msk, refs:
                               (iq, ib, iw, 0)),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_chunk_multi_comp_kernel, n_planes=n_planes)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, nb, W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, refs, dict_rows, acc)


def lookup_score(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    word_block: int = DEFAULT_WORD_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused gather+score: (arena uint32 [R, W], rows_idx int32 [L],
    mask int32 [L]) -> int32 [W, 32]. W % word_block == 0.

    The row index per grid step comes from scalar prefetch, so each [1, bw]
    arena tile is DMA'd HBM->VMEM exactly when needed and the gathered [L, W]
    intermediate never exists.
    """
    R, W = arena.shape
    L = rows_idx.shape[0]
    n_planes = _num_planes(L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W // word_block, L),
        in_specs=[
            pl.BlockSpec((1, word_block), lambda iw, il, idx, msk: (idx[il], iw)),
        ],
        out_specs=pl.BlockSpec((word_block, 32), lambda iw, il, idx, msk: (iw, 0)),
        scratch_shapes=[pltpu.VMEM((n_planes, word_block), jnp.uint32)],
    )
    kernel = functools.partial(_lookup_kernel, n_planes=n_planes)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((W, 32), jnp.int32),
        interpret=interpret,
    )(rows_idx, mask, arena)
