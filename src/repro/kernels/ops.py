"""jit'd public wrappers around the Pallas kernels.

Handles padding to tile boundaries, method selection, and CPU fallback:
on a CPU backend the kernels run under ``interpret=True`` (bit-exact
execution of the kernel body); on TPU they compile to Mosaic. ``method=
'ref'`` bypasses Pallas entirely (pure jnp oracle) — useful under vmap-heavy
query batching and as the ground truth in tests.

Tile-shape knobs (``word_block``, ``term_block``, ``grid_order``) default
to ``None`` = the kernel defaults; the serving planner threads measured
choices from ``repro.kernels.autotune`` through these parameters, so a
tuned configuration reaches every call site without baked-in constants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitslice_score as _k
from . import ref as _ref

METHODS = ("ref", "unpack", "vertical", "lookup")


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _word_block(W: int, word_block: int | None) -> int:
    wb = _k.DEFAULT_WORD_BLOCK if word_block is None else int(word_block)
    return min(wb, max(8, W))  # small-index friendliness


@functools.partial(jax.jit, static_argnames=("method", "interpret",
                                             "word_block", "term_block"))
def bitslice_score(
    rows: jnp.ndarray, method: str = "vertical", interpret: bool | None = None,
    word_block: int | None = None, term_block: int | None = None,
) -> jnp.ndarray:
    """Score ADD step: uint32 [L, W] (masked rows) -> int32 [W * 32].

    Invalid/padded terms must already be zeroed; zero rows contribute zero.
    """
    if interpret is None:
        interpret = _use_interpret()
    L, W = rows.shape
    if method == "ref":
        return _ref.bitslice_score_ref(rows)
    tb = _k.DEFAULT_TERM_BLOCK if term_block is None else int(term_block)
    wb = _word_block(W, word_block)
    padded = _pad_axis(_pad_axis(rows, 0, tb), 1, wb)
    if method == "unpack":
        out = _k.unpack_score(padded, term_block=tb, word_block=wb,
                              interpret=interpret)
    elif method == "vertical":
        out = _k.vertical_score(padded, term_block=tb, word_block=wb,
                                interpret=interpret)
    else:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    return out[:W].reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_lookup_score(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> jnp.ndarray:
    """Fused gather+score from the arena: -> int32 [W * 32]."""
    if interpret is None:
        interpret = _use_interpret()
    R, W = arena.shape
    wb = _word_block(W, word_block)
    arena_p = _pad_axis(arena, 1, wb)
    out = _k.lookup_score(
        arena_p, rows_idx.astype(jnp.int32), mask.astype(jnp.int32),
        word_block=wb, interpret=interpret)
    return out[:W].reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_lookup_score_blocks(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> jnp.ndarray:
    """Multi-block fused gather+score: (arena [R, W], rows_idx [nb, L],
    mask [nb, L]) -> int32 [nb * W * 32] in (block, word, bit) slot order."""
    if interpret is None:
        interpret = _use_interpret()
    R, W = arena.shape
    wb = _word_block(W, word_block)
    arena_p = _pad_axis(arena, 1, wb)
    out = _k.lookup_score_blocks(
        arena_p, rows_idx.astype(jnp.int32), mask.astype(jnp.int32),
        word_block=wb, interpret=interpret)
    return out[:, :W].reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block",
                                             "grid_order"))
def bitslice_lookup_score_multi(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
    grid_order: str = "wq",
) -> jnp.ndarray:
    """Multi-query multi-block fused gather+score: (arena [R, W], rows_idx
    [Q, nb, L], mask [Q, nb, L]) -> int32 [Q, nb * W * 32], each query in
    (block, word, bit) slot order — the serving batch hot path."""
    if interpret is None:
        interpret = _use_interpret()
    R, W = arena.shape
    Q = rows_idx.shape[0]
    wb = _word_block(W, word_block)
    arena_p = _pad_axis(arena, 1, wb)
    out = _k.lookup_score_multi(
        arena_p, rows_idx.astype(jnp.int32), mask.astype(jnp.int32),
        word_block=wb, grid_order=grid_order, interpret=interpret)
    return out[:, :, :W].reshape(Q, -1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_lookup_score_dedup(
    arena: jnp.ndarray,
    uniq_rows: jnp.ndarray,
    indir: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> jnp.ndarray:
    """Row-dedup batched gather+score: (arena [R, W], uniq_rows [U] or
    [U, k], indir [Q, nb, L], mask [Q, nb, L]) -> int32 [Q, nb * W * 32].

    Two kernels: ``gather_rows`` streams each unique arena row from HBM
    exactly once into a compact [U, W] matrix; ``dedup_score`` accumulates
    every query through the indirection against that matrix (resident in
    VMEM per word tile). Arena DMA traffic is U row tiles instead of the
    fused path's Q*nb*L — the win scales with batch row overlap. Semantics
    == ``bitslice_lookup_score_multi(arena, uniq_rows[indir], mask)``,
    property-tested bit-identical.

    For k>1 indexes ``uniq_rows`` is [U, k]: each unique entry is a
    (row-set) tuple whose k gathered rows are AND-reduced on device before
    scoring — dedup over AND'd tuples, so shared row-SETS between queries
    (not just shared single rows) collapse to one gather + one AND each.
    """
    if interpret is None:
        interpret = _use_interpret()
    R, W = arena.shape
    Q = indir.shape[0]
    wb = _word_block(W, word_block)
    arena_p = _pad_axis(arena, 1, wb)
    uniq_rows = uniq_rows.astype(jnp.int32)
    if uniq_rows.ndim == 1:
        uniq = _k.gather_rows(arena_p, uniq_rows, word_block=wb,
                              interpret=interpret)
    else:
        uniq = _k.gather_rows(arena_p, uniq_rows[:, 0], word_block=wb,
                              interpret=interpret)
        for j in range(1, uniq_rows.shape[1]):
            uniq = uniq & _k.gather_rows(arena_p, uniq_rows[:, j],
                                         word_block=wb, interpret=interpret)
    out = _k.dedup_score(uniq, indir.astype(jnp.int32),
                         mask.astype(jnp.int32), word_block=wb,
                         interpret=interpret)
    return out[:, :, :W].reshape(Q, -1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block",
                                             "grid_order"))
def bitslice_lookup_score_multi_comp(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
    grid_order: str = "wq",
) -> jnp.ndarray:
    """``bitslice_lookup_score_multi`` over a rowdict-compressed arena:
    (dict [D, W], refs [R], rows_idx [Q, nb, L], mask [Q, nb, L]) ->
    int32 [Q, nb * W * 32]. Rows decode HBM->VMEM inside the kernel via
    ``dict[refs[row]]`` — bit-identical to the raw path on the expanded
    tile, moving D-dict-row working sets instead of R."""
    if interpret is None:
        interpret = _use_interpret()
    D, W = dict_rows.shape
    Q = rows_idx.shape[0]
    wb = _word_block(W, word_block)
    dict_p = _pad_axis(dict_rows, 1, wb)
    out = _k.lookup_score_multi_compressed(
        dict_p, refs.astype(jnp.int32), rows_idx.astype(jnp.int32),
        mask.astype(jnp.int32), word_block=wb, grid_order=grid_order,
        interpret=interpret)
    return out[:, :, :W].reshape(Q, -1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_lookup_score_blocks_comp(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> jnp.ndarray:
    """``bitslice_lookup_score_blocks`` over a rowdict-compressed arena:
    single-query decode-in-the-loop scoring, int32 [nb * W * 32]."""
    if interpret is None:
        interpret = _use_interpret()
    D, W = dict_rows.shape
    wb = _word_block(W, word_block)
    dict_p = _pad_axis(dict_rows, 1, wb)
    out = _k.lookup_score_blocks_compressed(
        dict_p, refs.astype(jnp.int32), rows_idx.astype(jnp.int32),
        mask.astype(jnp.int32), word_block=wb, interpret=interpret)
    return out[:, :W].reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_lookup_score_dedup_comp(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    uniq_rows: jnp.ndarray,
    indir: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> jnp.ndarray:
    """``bitslice_lookup_score_dedup`` over a rowdict-compressed arena:
    ``gather_rows_compressed`` decodes each unique row (or k>1 row-set,
    AND-reduced) out of the dict on the way HBM->VMEM, then the identical
    ``dedup_score`` indirection scores it. int32 [Q, nb * W * 32]."""
    if interpret is None:
        interpret = _use_interpret()
    D, W = dict_rows.shape
    Q = indir.shape[0]
    wb = _word_block(W, word_block)
    dict_p = _pad_axis(dict_rows, 1, wb)
    refs = refs.astype(jnp.int32)
    uniq_rows = uniq_rows.astype(jnp.int32)
    if uniq_rows.ndim == 1:
        uniq = _k.gather_rows_compressed(dict_p, refs, uniq_rows,
                                         word_block=wb, interpret=interpret)
    else:
        uniq = _k.gather_rows_compressed(dict_p, refs, uniq_rows[:, 0],
                                         word_block=wb, interpret=interpret)
        for j in range(1, uniq_rows.shape[1]):
            uniq = uniq & _k.gather_rows_compressed(
                dict_p, refs, uniq_rows[:, j], word_block=wb,
                interpret=interpret)
    out = _k.dedup_score(uniq, indir.astype(jnp.int32),
                         mask.astype(jnp.int32), word_block=wb,
                         interpret=interpret)
    return out[:, :, :W].reshape(Q, -1)


# --------------------------------------------------------------------------
# chunked pruned-scoring wrappers (branch-and-bound executor support)
# --------------------------------------------------------------------------
#
# The pruned executor (repro.core.query.run_paged_pruned) scores terms in
# chunks and keeps a persistent per-(query, block) running-count buffer per
# shard. Each wrapper returns (new_acc, block_max) where block_max int32
# [Q, nb] is the per-block maximum running count — the executor's survivor
# bound ``block_max + terms_remaining < required`` consumes only that tiny
# array host-side, the acc itself stays on device between chunks.


def chunk_acc_init(q: int, nb: int, w: int,
                   word_block: int | None = None) -> jnp.ndarray:
    """Fresh running-count buffer int32 [Q, nb, Wp, 32] with the word axis
    pre-padded to the kernel tile multiple (stable across chunk calls)."""
    wb = _word_block(w, word_block)
    wp = w + ((-w) % wb)
    return jnp.zeros((q, nb, wp, 32), jnp.int32)


def chunk_acc_scores(acc: jnp.ndarray, w: int) -> jnp.ndarray:
    """Finished running counts -> int32 [Q, nb * W * 32] in the engine's
    (block, word, bit) slot order."""
    q = acc.shape[0]
    return acc[:, :, :w].reshape(q, -1)


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_chunk_score_dedup(
    uniq: jnp.ndarray,
    indir: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One term chunk against a host-gathered unique-row matrix.

    uniq uint32 [U, W] (only the chunk's touched rows were read from the
    store — for k>1 the host pre-ANDs the row sets); indir/mask int32
    [Q, nb, Lc]; acc int32 [Q, nb, Wp, 32]. Returns (acc + chunk counts,
    per-block max int32 [Q, nb])."""
    if interpret is None:
        interpret = _use_interpret()
    U, W = uniq.shape
    wb = _word_block(W, word_block)
    uniq_p = _pad_axis(uniq, 1, wb)
    out = _k.chunk_dedup_score(uniq_p, indir.astype(jnp.int32),
                               mask.astype(jnp.int32), acc,
                               word_block=wb, interpret=interpret)
    return out, jnp.max(out, axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_chunk_score_multi(
    arena: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One term chunk fused-gathered from a resident shard tile (the
    promoted path: tile already staged, chunk rows stream out of HBM).
    Returns (acc + chunk counts, per-block max int32 [Q, nb])."""
    if interpret is None:
        interpret = _use_interpret()
    R, W = arena.shape
    wb = _word_block(W, word_block)
    arena_p = _pad_axis(arena, 1, wb)
    out = _k.chunk_lookup_score_multi(
        arena_p, rows_idx.astype(jnp.int32), mask.astype(jnp.int32), acc,
        word_block=wb, interpret=interpret)
    return out, jnp.max(out, axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("interpret", "word_block"))
def bitslice_chunk_score_multi_comp(
    dict_rows: jnp.ndarray,
    refs: jnp.ndarray,
    rows_idx: jnp.ndarray,
    mask: jnp.ndarray,
    acc: jnp.ndarray,
    interpret: bool | None = None,
    word_block: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One term chunk fused-DECODED from a resident (dict, refs) pair —
    the compressed promoted path. Returns (acc', per-block max)."""
    if interpret is None:
        interpret = _use_interpret()
    D, W = dict_rows.shape
    wb = _word_block(W, word_block)
    dict_p = _pad_axis(dict_rows, 1, wb)
    out = _k.chunk_lookup_score_multi_compressed(
        dict_p, refs.astype(jnp.int32), rows_idx.astype(jnp.int32),
        mask.astype(jnp.int32), acc, word_block=wb, interpret=interpret)
    return out, jnp.max(out, axis=(2, 3))


@functools.partial(jax.jit, static_argnames=("k",))
def chunk_topk_lower(acc: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-query k-th-largest running counts of one shard's buffer: int32
    [Q, k] (descending). Running counts are LOWER bounds on final scores,
    so merging these across shards gives a sound, ever-tightening top-k
    pruning cutoff."""
    q = acc.shape[0]
    flat = acc.reshape(q, -1)
    kk = min(int(k), flat.shape[1])
    vals, _ = jax.lax.top_k(flat, kk)
    return vals


def and_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """AND over the k hash rows: uint32 [L, k, W] -> [L, W] (jnp; XLA fuses
    this into the surrounding gather — measured no win from a kernel)."""
    return _ref.and_rows_ref(rows)


@jax.jit
def gather_and_rows(arena: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Device-side row-set materialization for the promoted k>1 pruned
    path: uint32 tile [R, W] resident in HBM, rows int32 [U, k] ->
    uint32 [U, W] with the k hash rows of each set ANDed in place.

    This replaces the unpromoted path's host mmap reads once a tile is
    staged — the gather streams out of HBM and XLA fuses the AND into it,
    so post-promotion chunks never touch the host arena again."""
    g = arena[rows]                               # [U, k, W]
    out = g[:, 0]
    for i in range(1, g.shape[1]):
        out = out & g[:, i]
    return out


@jax.jit
def gather_and_rows_comp(dict_rows: jnp.ndarray, refs: jnp.ndarray,
                         rows: jnp.ndarray) -> jnp.ndarray:
    """``gather_and_rows`` against a resident (dict, refs) pair: the
    double gather decodes rowdict-coded rows on the fly, HBM traffic
    proportional to the dictionary instead of the expanded tile."""
    g = dict_rows[refs[rows]]                     # [U, k, W]
    out = g[:, 0]
    for i in range(1, g.shape[1]):
        out = out & g[:, i]
    return out


def bulk_query_chunk(nb: int, w: int, *, word_block: int | None = None,
                     budget_bytes: int = 32 * 2**20, floor: int = 8,
                     cap: int = 512) -> int:
    """Query-chunk size for the shard-major bulk executor.

    The bulk lane scores the whole query set against one resident tile in
    slabs of Qc queries; the dominant live buffer is the running-count
    accumulator int32 [Qc, nb, Wp, 32], so Qc is chosen to keep that under
    ``budget_bytes`` (a conservative stand-in for the VMEM/HBM slice the
    chunk kernels can hold). Rounded down to a power of two so every slab
    of a sweep shares one compiled kernel shape (the last slab is padded
    up, never down)."""
    wb = _word_block(w, word_block)
    wp = w + ((-w) % wb)
    per_q = max(1, nb * wp * 32 * 4)
    q = max(int(floor), int(budget_bytes) // per_q)
    q = 1 << (q.bit_length() - 1)                 # pow2 floor
    return int(min(int(cap), max(int(floor), q)))
