"""Pallas TPU kernels for the query hot loop (ops.py = jit wrappers,
ref.py = pure-jnp oracles, bitslice_score.py = the kernels, autotune.py =
measured tile/grid configs + the persisted tuning cache)."""
from . import autotune, ops, ref

__all__ = ["autotune", "ops", "ref"]
