"""Pallas TPU kernels for the query hot loop (ops.py = jit wrappers,
ref.py = pure-jnp oracles, bitslice_score.py = the kernels)."""
from . import ops, ref

__all__ = ["ops", "ref"]
