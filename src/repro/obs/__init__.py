"""Observability for the serving stack: tracing, metrics, profiling.

Three cooperating pieces, each usable alone:

* ``registry`` — a general counter / gauge / histogram registry with
  per-metric locks and labeled families. ``repro.serve.metrics`` is a
  facade over one of these; ``repro.obs.export`` renders it in the
  Prometheus text exposition format.
* ``trace`` — request tracing: a ``Trace`` is minted per admitted
  query, ``Span``s are appended by every serving layer it crosses
  (queue wait, flush, plan, tile fetch, kernel, hedged shard dispatch,
  gather, delivery), and the finished trace lands in a ring buffer —
  plus the slow-query JSONL log when it blows a latency budget.
* ``profile`` — ``KernelProfiler`` wraps the score-kernel dispatch,
  recording per-(method, bucket, word_block) wall time and bytes-moved
  estimates, and optionally feeds the measurements back into the
  autotuner's cost cache as live "observed" entries.
"""
from .events import EventLog
from .profile import KernelProfiler
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, Trace, Tracer
from .export import render_prometheus

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Trace", "Tracer",
    "EventLog", "KernelProfiler", "render_prometheus",
]
