"""Structured JSONL event log (slow-query log and friends).

One JSON object per line: ``{"ts": <unix seconds>, "kind": ...,
**payload}``. Writes are serialized by a lock and flushed per event —
a slow-query log that loses its tail on crash is useless, and the
emit rate is bounded by the slow threshold, not the query rate.

A bounded in-memory ring mirrors the last events so tests and the
STATS surface can read them without re-parsing the file; ``path=None``
keeps the log memory-only.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Optional, Union


class EventLog:
    def __init__(self, path: Optional[Union[str, Path]] = None, *,
                 ring: int = 256):
        self.path = Path(path) if path is not None else None
        self._lock = threading.Lock()
        self._ring: "deque[dict]" = deque(maxlen=ring)
        self._emitted = 0
        self._fh = None
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")

    def emit(self, kind: str, payload: dict) -> dict:
        event = {"ts": time.time(), "kind": kind}
        event.update(payload)
        line = json.dumps(event, separators=(",", ":"),
                          default=str)
        with self._lock:
            self._emitted += 1
            self._ring.append(event)
            if self._fh is not None:
                self._fh.write(line + "\n")
                self._fh.flush()
        return event

    @property
    def emitted(self) -> int:
        with self._lock:
            return self._emitted

    def tail(self, n: int = 0, *, kind: Optional[str] = None) -> list[dict]:
        with self._lock:
            events = list(self._ring)
        if kind is not None:
            events = [e for e in events if e.get("kind") == kind]
        return events[-n:] if n else events

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: Union[str, Path]) -> list[dict]:
    """Parse a JSONL event file (skipping torn/blank lines)."""
    out = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out
