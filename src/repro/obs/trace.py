"""Request tracing: spans per serving stage, ring-buffered traces.

A ``Trace`` is minted when a query is admitted (QueryServer/Frontend
``submit``) and travels with the request through every layer; each
layer appends flat ``Span``s — (name, start, end, tags) on the shared
monotonic clock — rather than maintaining an open-span stack, because
a request's stages run on different threads (submitter, dispatcher,
scoring worker, scatter pool) and the batch-level stages (flush, plan,
kernel) are legitimately shared by every request in the micro-batch.
The tree structure a UI would want is recoverable from the intervals;
``benchmarks/trace_report.py`` renders exactly that.

``Tracer`` owns trace lifecycle: minting ids, the bounded ring of
finished traces (for the STATS surface / tests), and the slow-query
sink — a finished trace whose end-to-end latency exceeds ``slow_ms``
is emitted to the JSONL ``EventLog`` with its full span tree.

Everything is cheap when disabled: ``tracer.begin`` returns None and
every call site guards with ``if trace is not None`` (span recording
itself is two clock reads and an append under a small lock).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Callable, Optional


class Span:
    """One timed stage. ``tags`` is small str->str/num metadata
    (method, shard, replica role, hit/fault...)."""

    __slots__ = ("name", "start_s", "end_s", "tags")

    def __init__(self, name: str, start_s: float, end_s: float,
                 tags: Optional[dict] = None):
        self.name = name
        self.start_s = start_s
        self.end_s = end_s
        self.tags = tags or {}

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    def to_json(self) -> dict:
        d = {"name": self.name, "start_s": self.start_s,
             "end_s": self.end_s}
        if self.tags:
            d["tags"] = self.tags
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
                f"{self.tags})")


class Trace:
    """Spans for one request. Thread-safe appends; ``finish`` is
    idempotent (the first caller wins) so the deliver path and the
    sync-driver path cannot double-emit."""

    def __init__(self, trace_id: int, request_id: int = 0, *,
                 started_s: float = 0.0):
        self.trace_id = trace_id
        self.request_id = request_id
        self.started_s = started_s
        self.ended_s: Optional[float] = None
        self._lock = threading.Lock()
        self._spans: list[Span] = []

    def add(self, name: str, start_s: float, end_s: float,
            tags: Optional[dict] = None) -> Span:
        s = Span(name, start_s, end_s, tags)
        with self._lock:
            self._spans.append(s)
        return s

    @property
    def done(self) -> bool:
        return self.ended_s is not None

    @property
    def duration_s(self) -> float:
        end = self.ended_s
        if end is None:
            with self._lock:
                end = max((s.end_s for s in self._spans),
                          default=self.started_s)
        return end - self.started_s

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def stage_totals(self) -> dict[str, float]:
        """Per-stage wall time, summed over same-named spans — the
        compact breakdown the RESULT frame carries back to the client.
        Stages keep first-seen (i.e. roughly causal) order."""
        out: dict[str, float] = {}
        for s in self.spans():
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    def to_json(self) -> dict:
        return {
            "trace_id": self.trace_id,
            "request_id": self.request_id,
            "started_s": self.started_s,
            "ended_s": self.ended_s,
            "duration_ms": self.duration_s * 1e3,
            "spans": [s.to_json() for s in self.spans()],
        }


class Tracer:
    """Trace factory + finished-trace ring + slow-query sink.

    ``clock`` must be the same callable the serving clock uses
    (monotonic by default; the sim-clock in tests) so span timestamps
    and request deadlines share an epoch. ``sink`` is an EventLog-like
    object with ``emit(kind, payload)``; only traces slower than
    ``slow_ms`` reach it.
    """

    def __init__(self, *, enabled: bool = True, ring: int = 256,
                 slow_ms: float = 0.0, sink=None,
                 clock: Optional[Callable[[], float]] = None):
        self.enabled = enabled
        self.slow_ms = slow_ms
        self.sink = sink
        self.clock = clock or time.monotonic
        # When a ServingLoop fronts the backend, the loop finishes the
        # trace after callback delivery (so "deliver" is a span); sync
        # drivers finish in pop_responses. The loop flips this flag.
        self.defer_finish = False
        self._lock = threading.Lock()
        self._ring: "deque[Trace]" = deque(maxlen=ring)
        self._ids = itertools.count(1)
        self._finished = 0
        self._slow = 0

    def mint_id(self) -> int:
        return next(self._ids)

    def begin(self, request_id: int = 0, *,
              trace_id: Optional[int] = None,
              started_s: Optional[float] = None) -> Optional[Trace]:
        """New trace, or None when tracing is off. A nonzero wire
        trace id (client-minted) is honored verbatim."""
        if not self.enabled:
            return None
        tid = trace_id if trace_id else self.mint_id()
        t0 = self.clock() if started_s is None else started_s
        return Trace(tid, request_id, started_s=t0)

    def finish(self, trace: Optional[Trace]) -> None:
        """Seal the trace, ring-buffer it, and emit to the slow-query
        sink if over budget. Idempotent; None is a no-op."""
        if trace is None:
            return
        with trace._lock:           # claim: first finisher wins
            if trace.ended_s is not None:
                return
            trace.ended_s = self.clock()
        with self._lock:
            self._ring.append(trace)
            self._finished += 1
            slow = trace.duration_s * 1e3 >= self.slow_ms > 0
            if slow:
                self._slow += 1
        if slow and self.sink is not None:
            self.sink.emit("slow_query", trace.to_json())

    # -- reading -----------------------------------------------------------
    @property
    def finished_count(self) -> int:
        with self._lock:
            return self._finished

    @property
    def slow_count(self) -> int:
        with self._lock:
            return self._slow

    def recent(self, n: int = 0) -> list[Trace]:
        """Most recent finished traces (all buffered when n=0)."""
        with self._lock:
            traces = list(self._ring)
        return traces[-n:] if n else traces

    def find(self, trace_id: int) -> Optional[Trace]:
        with self._lock:
            for t in reversed(self._ring):
                if t.trace_id == trace_id:
                    return t
        return None
