"""Counter / gauge / histogram registry with per-metric locking.

The serving stack mostly runs single-threaded behind the ServingLoop's
backend lock, but three producers live outside it: per-connection
socket threads (connection gauge), the scatter thread pool (per-worker
latencies), and any monitoring thread calling ``snapshot`` or the
Prometheus renderer. Every metric therefore owns a lock and every
read/write takes it — uncontended acquisition is ~100ns, invisible
next to a kernel dispatch, and it turns "iterating a deque while a
worker appends" from a RuntimeError into a consistent copy.

Labeled metrics follow the Prometheus family model: ``registry.counter
("x_total", labels=("method",))`` returns a family; ``family.labels
("fused")`` returns (creating on first use) the child counter for that
label value. Unlabeled metrics are their own child with no labels.
"""
from __future__ import annotations

import threading
from typing import Iterable, Optional, Sequence

import numpy as np


class Counter:
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """A value that goes up and down (plus a high-water mark)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v
            if v > self._max:
                self._max = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n
            if self._value > self._max:
                self._max = self._value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    @property
    def max(self) -> float:
        with self._lock:
            return self._max


class Histogram:
    """Sliding-window sample store with exact lifetime count/sum.

    Percentiles are computed over the last ``window`` observations
    (matching the old ServingMetrics deques); ``recent`` keeps a short
    secondary window for hot-path consumers (adaptive hedging derives a
    p95 per batch over 128 samples, not 65k).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *, window: int = 65536,
                 recent: int = 128):
        from collections import deque
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._samples: "deque[float]" = deque(maxlen=window)
        self._recent: "deque[float]" = deque(maxlen=recent)
        self._count = 0
        self._sum = 0.0

    def observe(self, v: float) -> None:
        with self._lock:
            self._samples.append(v)
            self._recent.append(v)
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def values(self) -> np.ndarray:
        """Consistent copy of the sample window."""
        with self._lock:
            return np.fromiter(self._samples, float, len(self._samples))

    def recent_values(self) -> np.ndarray:
        with self._lock:
            return np.fromiter(self._recent, float, len(self._recent))

    def percentile(self, p: float) -> float:
        v = self.values()
        return float(np.percentile(v, p)) if v.size else 0.0

    def percentiles(self, ps: Sequence[float]) -> list[float]:
        v = self.values()
        if not v.size:
            return [0.0] * len(ps)
        return [float(x) for x in np.percentile(v, list(ps))]

    def mean(self) -> float:
        v = self.values()
        return float(v.mean()) if v.size else 0.0


class Family:
    """A labeled metric family: one child per label-value tuple."""

    def __init__(self, cls, name: str, help: str, label_names: tuple,
                 **kwargs):
        self.cls = cls
        self.name = name
        self.help = help
        self.label_names = label_names
        self.kind = cls.kind
        self._kwargs = kwargs
        self._lock = threading.Lock()
        self._children: dict[tuple, object] = {}

    def labels(self, *values):
        values = tuple(str(v) for v in values)
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {values}")
        with self._lock:
            child = self._children.get(values)
            if child is None:
                child = self.cls(self.name, self.help, **self._kwargs)
                self._children[values] = child
            return child

    def children(self) -> list[tuple[tuple, object]]:
        with self._lock:
            return sorted(self._children.items())


class MetricsRegistry:
    """Name -> metric (or labeled family). Constructors are idempotent:
    asking for an existing name returns the existing object (and raises
    if the kind or labels disagree — two subsystems silently sharing a
    name with different meanings is a bug worth failing loudly on)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get_or_make(self, cls, name: str, help: str,
                     labels: Iterable[str], **kwargs):
        label_names = tuple(labels)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                existing = m.label_names if isinstance(m, Family) else ()
                if existing != label_names:
                    raise ValueError(
                        f"metric {name!r} labels {existing} != "
                        f"{label_names}")
                return m
            if label_names:
                m = Family(cls, name, help, label_names, **kwargs)
            else:
                m = cls(name, help, **kwargs)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (), *, window: int = 65536,
                  recent: int = 128) -> Histogram:
        return self._get_or_make(Histogram, name, help, labels,
                                 window=window, recent=recent)

    def get(self, name: str) -> Optional[object]:
        with self._lock:
            return self._metrics.get(name)

    def collect(self) -> list[tuple[str, object]]:
        """(name, metric-or-family) pairs, sorted by name."""
        with self._lock:
            return sorted(self._metrics.items())
