"""Prometheus text exposition rendering for a MetricsRegistry.

Counters and gauges render as-is; histograms render as summaries
(quantiles over the sliding sample window plus exact lifetime
``_count`` / ``_sum``) because the serving stack wants precise p50/p99
over recent traffic, not fixed buckets chosen ahead of time. The
output parses under the Prometheus text format v0.0.4, which is what
``launch/serve.py --stats-interval`` dumps and the STATS frame ships.
"""
from __future__ import annotations

from .registry import Family, Histogram, MetricsRegistry

QUANTILES = (0.5, 0.9, 0.99)


def _escape(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace("\"", "\\\"")
            .replace("\n", "\\n"))


def _labels(names: tuple, values: tuple, extra: str = "") -> str:
    parts = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _render_one(lines: list, name: str, metric, label_names: tuple,
                label_values: tuple) -> None:
    if isinstance(metric, Histogram):
        for q in QUANTILES:
            qlab = 'quantile="%s"' % q
            lines.append(
                f"{name}{_labels(label_names, label_values, qlab)}"
                f" {_fmt(metric.percentile(q * 100))}")
        lines.append(f"{name}_count{_labels(label_names, label_values)}"
                     f" {metric.count}")
        lines.append(f"{name}_sum{_labels(label_names, label_values)}"
                     f" {_fmt(metric.sum)}")
    else:
        lines.append(f"{name}{_labels(label_names, label_values)}"
                     f" {_fmt(metric.value)}")


def render_prometheus(registry: MetricsRegistry) -> str:
    lines: list[str] = []
    for name, metric in registry.collect():
        kind = "summary" if metric.kind == "histogram" else metric.kind
        if metric.help:
            lines.append(f"# HELP {name} {metric.help}")
        lines.append(f"# TYPE {name} {kind}")
        if isinstance(metric, Family):
            for values, child in metric.children():
                _render_one(lines, name, child, metric.label_names, values)
        else:
            _render_one(lines, name, metric, (), ())
    return "\n".join(lines) + "\n" if lines else ""


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal parser for round-trip tests and the STATS smoke: maps
    ``name{labels}`` sample lines back to float values."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, _, value = line.rpartition(" ")
        try:
            out[key] = float(value)
        except ValueError:
            continue
    return out
