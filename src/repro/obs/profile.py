"""Kernel profiling: per-(method, bucket, word_block) wall time and
bytes-moved accounting for every score dispatch.

The serving layers already know everything worth recording at the
moment a kernel returns — the method the planner chose, the bucket and
batch geometry, the word_block actually dispatched, and (for the
dedup path) how many arena rows the gather streamed. ``KernelProfiler.
record`` is the single funnel: it feeds a labeled histogram + counter
in the metrics registry (Prometheus-visible), keeps a bounded ring of
raw records for tests/reports, and forwards each measurement to
``KernelTuner.observe`` so the autotuner's cost model learns from live
traffic instead of only offline synthetic fixtures.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Optional


def gather_bytes(n_rows: int, doc_words: int, itemsize: int = 4) -> int:
    """Bytes-moved estimate for an arena gather: rows streamed from the
    bit-sliced arena times the row stride. The dedup plan's
    ``n_unique`` (padded) rows for the dedup path, Q*nb*L for the fused
    kernel — per-slice addressing reads whole rows either way."""
    return int(n_rows) * int(doc_words) * int(itemsize)


class KernelProfiler:
    """Sink for score-kernel timings. All methods are thread-safe and
    cheap when ``enabled`` is False (one branch)."""

    def __init__(self, registry=None, tuner=None, *, enabled: bool = True,
                 ring: int = 512):
        self.enabled = enabled
        self.tuner = tuner
        self._lock = threading.Lock()
        self._ring: "deque[dict]" = deque(maxlen=ring)
        self._count = 0
        self._hist = None
        self._bytes = None
        if registry is not None:
            self.bind_registry(registry)

    def bind_registry(self, registry) -> None:
        self._hist = registry.histogram(
            "kernel_score_seconds",
            "score-kernel wall time per dispatch",
            labels=("method", "bucket", "word_block"))
        self._bytes = registry.counter(
            "kernel_bytes_moved_total",
            "estimated arena bytes gathered by score dispatches",
            labels=("method", "bucket"))

    def record(self, *, method: str, bucket: int, batch: int,
               seconds: float, word_block: int = 0,
               term_block: int = 0, grid_order: str = "wq",
               bytes_moved: int = 0, shard: Optional[int] = None) -> None:
        """One finished kernel dispatch."""
        if not self.enabled:
            return
        if self._hist is not None:
            self._hist.labels(method, bucket, word_block).observe(seconds)
        if self._bytes is not None and bytes_moved:
            self._bytes.labels(method, bucket).inc(bytes_moved)
        rec = {"method": method, "bucket": int(bucket),
               "batch": int(batch), "word_block": int(word_block),
               "seconds": float(seconds), "bytes_moved": int(bytes_moved)}
        if shard is not None:
            rec["shard"] = int(shard)
        with self._lock:
            self._ring.append(rec)
            self._count += 1
        if self.tuner is not None and word_block:
            try:
                self.tuner.observe(method, bucket, batch, seconds,
                                   word_block=word_block,
                                   term_block=term_block,
                                   grid_order=grid_order)
            except Exception:
                # cost feedback is advisory; a cache-save hiccup (full
                # disk, read-only mount) must not fail the scoring path
                pass

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def records(self, n: int = 0) -> list[dict]:
        with self._lock:
            recs = list(self._ring)
        return recs[-n:] if n else recs
