from .synthetic import (SyntheticCorpus, make_corpus, make_queries,
                        random_genome, mutate)
from .fasta import read_fasta, write_fasta

__all__ = ["SyntheticCorpus", "make_corpus", "make_queries", "random_genome",
           "mutate", "read_fasta", "write_fasta"]
