"""Synthetic genomic corpora with the paper's key statistical property.

The 100k-microbial dataset that motivates COBS has *heavily skewed* document
sizes (min 0 k-mers, mean 3.4M, max 138M — a ~40x mean-to-max ratio). The
compact layout's entire advantage (Fig. 4) comes from that skew, so the
generator draws document lengths from a log-normal clipped to a [min, max]
range, giving the same staircase-vs-rectangle geometry at laptop scale.

Query sets mirror section 3 'Queries': true positives are substrings sampled
from indexed documents; true negatives are random strings verified to share
no k-mer with any document.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import dna


def random_genome(rng: np.random.Generator, length: int) -> np.ndarray:
    """Uniform random 2-bit code string (uint8 [length])."""
    return rng.integers(0, 4, size=length, dtype=np.uint8)


def mutate(rng: np.random.Generator, codes: np.ndarray, rate: float) -> np.ndarray:
    """Point-mutate a fraction ``rate`` of bases (never to the same base)."""
    out = codes.copy()
    n_mut = int(len(codes) * rate)
    if n_mut == 0:
        return out
    pos = rng.choice(len(codes), size=n_mut, replace=False)
    out[pos] = (out[pos] + rng.integers(1, 4, size=n_mut, dtype=np.uint8)) % 4
    return out


@dataclass
class SyntheticCorpus:
    documents: list[np.ndarray]          # 2-bit code arrays
    doc_terms: list[np.ndarray]          # distinct packed k-mers per doc
    k: int
    canonical: bool = False
    names: list[str] = field(default_factory=list)

    @property
    def n_docs(self) -> int:
        return len(self.documents)

    def term_counts(self) -> np.ndarray:
        return np.array([t.shape[0] for t in self.doc_terms], dtype=np.int64)


def make_corpus(
    n_docs: int,
    *,
    k: int = 31,
    mean_length: int = 2000,
    sigma: float = 1.0,
    min_length: int = 64,
    max_length: int | None = None,
    canonical: bool = False,
    seed: int = 0,
) -> SyntheticCorpus:
    """Log-normal document-size corpus (the paper's size-skew regime).

    sigma=1.0 gives roughly the 1-to-40 mean/max spread of the microbial set
    at a few thousand documents.
    """
    rng = np.random.default_rng(seed)
    mu = np.log(mean_length) - sigma ** 2 / 2
    lengths = np.exp(rng.normal(mu, sigma, size=n_docs)).astype(np.int64)
    lengths = np.clip(lengths, min_length, max_length or 50 * mean_length)
    docs, terms = [], []
    for i in range(n_docs):
        g = random_genome(rng, int(lengths[i]))
        docs.append(g)
        terms.append(dna.document_terms([g], k, canonical))
    return SyntheticCorpus(docs, terms, k, canonical,
                           [f"doc{i:06d}" for i in range(n_docs)])


def make_queries(
    corpus: SyntheticCorpus,
    *,
    n_pos: int,
    n_neg: int,
    length: int,
    seed: int = 1,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Query batch in random order with ground-truth labels.

    Returns (queries, origin) where origin[i] is the source document id for
    true positives and -1 for verified true negatives (section 3, Queries).
    """
    rng = np.random.default_rng(seed)
    k = corpus.k

    # k-mer membership structure over the whole corpus for negative checking
    all_terms = (np.concatenate(corpus.doc_terms, axis=0)
                 if corpus.doc_terms else np.zeros((0, 2), np.uint32))
    universe = set()
    if all_terms.shape[0]:
        u64 = (all_terms[:, 0].astype(np.uint64)
               | (all_terms[:, 1].astype(np.uint64) << np.uint64(32)))
        universe = set(u64.tolist())

    queries: list[np.ndarray] = []
    origin: list[int] = []

    long_enough = [i for i, d in enumerate(corpus.documents)
                   if len(d) >= max(length, k)]
    if n_pos and not long_enough:
        raise ValueError("no document long enough for positive queries")
    for _ in range(n_pos):
        d = int(rng.choice(long_enough))
        doc = corpus.documents[d]
        start = int(rng.integers(0, len(doc) - length + 1))
        queries.append(doc[start:start + length].copy())
        origin.append(d)

    def is_negative(codes: np.ndarray) -> bool:
        t = dna.pack_kmers(codes, k, corpus.canonical)
        u64 = (t[:, 0].astype(np.uint64)
               | (t[:, 1].astype(np.uint64) << np.uint64(32)))
        return not any(int(v) in universe for v in u64)

    made = 0
    while made < n_neg:
        cand = random_genome(rng, length)
        if is_negative(cand):
            queries.append(cand)
            origin.append(-1)
            made += 1

    perm = rng.permutation(len(queries))
    return [queries[i] for i in perm], np.array(origin, dtype=np.int64)[perm]
