"""Minimal FASTA reader/writer (the paper's document input format).

Each FASTA record becomes one read; a multi-record file is one document
whose reads are k-merized independently, matching COBS' DNA input mode.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from ..core import dna


def read_fasta(path: str | Path) -> list[np.ndarray]:
    """Returns the reads of one FASTA document as 2-bit code arrays."""
    reads: list[np.ndarray] = []
    cur: list[str] = []
    for line in Path(path).read_text().splitlines():
        if line.startswith(">"):
            if cur:
                reads.append(dna.encode_dna("".join(cur)))
                cur = []
        else:
            cur.append(line.strip())
    if cur:
        reads.append(dna.encode_dna("".join(cur)))
    return reads


def write_fasta(path: str | Path, reads: list[np.ndarray],
                name_prefix: str = "read") -> None:
    with open(path, "w") as f:
        for i, r in enumerate(reads):
            f.write(f">{name_prefix}{i}\n{dna.decode_dna(r)}\n")
