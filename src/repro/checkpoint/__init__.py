from .store import (CheckpointManager, save_pytree, load_pytree,
                    latest_step, AsyncCheckpointer)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree", "latest_step",
           "AsyncCheckpointer"]
