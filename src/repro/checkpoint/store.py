"""Fault-tolerant checkpointing.

Design (tensorstore-free, dependency-light, same layout principles as
production JAX checkpointers):

  * a checkpoint is a directory  step_<N>/  holding one .npz per pytree
    leaf-group plus a JSON manifest with the treedef, shapes, dtypes and a
    content hash per array — restore verifies integrity before use;
  * writes are ATOMIC: everything lands in step_<N>.tmp/ and is renamed
    only after fsync — a crash mid-write can never corrupt the latest
    checkpoint (restore simply picks the newest complete step);
  * AsyncCheckpointer moves the host-side serialization off the training
    thread (device->host copy happens synchronously, the file write
    asynchronously), bounded to one in-flight save;
  * retention: keep_last N steps are retained, older ones garbage-collected
    AFTER a successful new save (never delete before commit).

On a multi-host deployment each host writes its own address-space shards
(jax.Array addressable_shards) under shard_<rank>/; this CPU build exercises
the rank-0 path and the manifest/commit machinery, which is where the
fault-tolerance logic lives.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        names.append((name or "root", leaf))
    return names, treedef


def _hash(a: np.ndarray) -> str:
    return hashlib.blake2b(a.tobytes(), digest_size=16).hexdigest()


def save_pytree(tree, path: str | Path) -> None:
    """Atomic single-host save of an arbitrary pytree of arrays."""
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _leaf_paths(tree)
    manifest = {"leaves": [], "format": "repro-ckpt-v1"}
    arrays = {}
    for i, (name, leaf) in enumerate(leaves):
        a = np.asarray(leaf)
        key = f"a{i}"
        arrays[key] = a
        manifest["leaves"].append({
            "name": name, "key": key, "shape": list(a.shape),
            "dtype": str(a.dtype), "hash": _hash(a)})
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    fd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    if path.exists():
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(template, path: str | Path):
    """Restore into the structure of ``template`` (shape/dtype checked,
    hashes verified)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    if manifest.get("format") != "repro-ckpt-v1":
        raise ValueError(f"unknown checkpoint format at {path}")
    leaves, treedef = _leaf_paths(template)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    out = []
    with np.load(path / "arrays.npz") as z:
        for name, leaf in leaves:
            m = by_name.get(name)
            if m is None:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            a = z[m["key"]]
            if _hash(a) != m["hash"]:
                raise IOError(f"checkpoint corruption in leaf {name!r}")
            want_shape = tuple(getattr(leaf, "shape", a.shape))
            if tuple(a.shape) != want_shape:
                raise ValueError(
                    f"leaf {name!r}: checkpoint shape {a.shape} != "
                    f"expected {want_shape}")
            out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


_STEP_RE = re.compile(r"^step_(\d+)$")


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = [int(m.group(1)) for p in root.iterdir()
             if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()]
    return max(steps) if steps else None


class CheckpointManager:
    """Step-indexed checkpoints with retention + resume."""

    def __init__(self, root: str | Path, keep_last: int = 3):
        self.root = Path(root)
        self.keep_last = keep_last
        self.root.mkdir(parents=True, exist_ok=True)

    def path(self, step: int) -> Path:
        return self.root / f"step_{step}"

    def save(self, step: int, tree) -> Path:
        p = self.path(step)
        save_pytree(tree, p)
        self._gc()
        return p

    def restore(self, template, step: int | None = None):
        step = step if step is not None else latest_step(self.root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return load_pytree(template, self.path(step)), step

    def _gc(self) -> None:
        steps = sorted(int(_STEP_RE.match(p.name).group(1))
                       for p in self.root.iterdir() if _STEP_RE.match(p.name))
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.path(s), ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for p in self.root.glob("*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

    def all_steps(self) -> list[int]:
        return sorted(int(_STEP_RE.match(p.name).group(1))
                      for p in self.root.iterdir() if _STEP_RE.match(p.name))


class AsyncCheckpointer:
    """One-in-flight background writer: ``save`` returns as soon as the
    host copy is snapshot; the file write happens on a worker thread.
    ``wait()`` joins the in-flight save (call before exit / next save)."""

    def __init__(self, manager: CheckpointManager):
        self.manager = manager
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree) -> None:
        self.wait()
        # snapshot with an explicit copy: np.asarray on a host numpy leaf
        # aliases the caller's buffer (donated-buffer mutation hazard)
        host_tree = jax.tree.map(lambda a: np.array(a, copy=True), tree)

        def work():
            try:
                self.manager.save(step, host_tree)
            except BaseException as e:               # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
