"""Model configuration covering every assigned architecture family.

One dataclass, many knobs — each src/repro/configs/<arch>.py instantiates it
with the published numbers. ``block_pattern`` describes the layer stack as
(kind, count) segments; each segment is lax.scan'd over stacked params so
the lowered HLO stays compact at 26–48 layers.

Block kinds:
  "attn"    global causal attention (+MLP)
  "local"   sliding-window causal attention (+MLP)
  "rglru"   RG-LRU recurrent block, Griffin-style (+MLP)
  "moe"     attention + mixture-of-experts MLP
  "mlstm"   xLSTM matrix-memory block
  "slstm"   xLSTM scalar-memory block
  "enc"     bidirectional encoder attention (+MLP)      [whisper encoder]
  "xdec"    causal self-attn + cross-attn (+MLP)        [whisper decoder]
  "griffin" composite unit (rglru, rglru, local)        [recurrentgemma 2:1]
  "xunit"   composite unit (mlstm, slstm)               [xlstm alternating]

Composite kinds exist so hybrid stacks keep their exact interleaving while
still lowering to ONE scanned block instance per segment.
"""
from __future__ import annotations

import dataclasses
import math


# composite kinds expand to this many underlying layers
LAYERS_PER_KIND = {"griffin": 3, "xunit": 2}
CONV_W_APPROX = 4  # rg-lru temporal conv width (param_count estimate)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2
    # "einsum": GSPMD one-hot/scatter dispatch with GLOBAL capacity (simple,
    #   but the global cumsum over the sharded token dim costs collective-
    #   permute chains — the dry-run measured ~80 GB/layer of collectives).
    # "local": shard_map dispatch with PER-DATA-SHARD capacity — local
    #   cumsum, local scatter, one psum([T_local, D]) per layer (§Perf).
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None          # default d_model // n_heads
    block_pattern: tuple[tuple[str, int], ...] = ()  # default: all "attn"
    family: str = "dense"                # dense|hybrid|moe|ssm|audio|vlm

    # attention
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    window: int = 4096                   # for "local" blocks
    logits_softcap: float | None = None

    # moe
    moe: MoEConfig | None = None

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500                  # frames from the (stubbed) frontend
    learned_pos: bool = False            # learned positions instead of RoPE

    # frontend stub: "text" | "audio" | "vision"
    frontend: str = "text"

    gated_mlp: bool = True               # SwiGLU vs plain GELU (whisper)

    # training
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                  # "none" | "full"

    # capability flags used by the launcher / dry-run
    sub_quadratic: bool = False          # can run long_500k
    has_decoder: bool = True             # encoder-only archs skip decode

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern",
                               (("attn", self.n_layers),))
        n = sum(c * LAYERS_PER_KIND.get(k, 1) for k, c in self.block_pattern)
        if n != self.n_layers:
            raise ValueError(
                f"{self.name}: block_pattern covers {n} layers, "
                f"config says {self.n_layers}")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")

    # ---------------- derived sizes ----------------
    @property
    def q_group(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS and memory
        budgeting; exact count comes from the built pytree)."""
        d, v = self.d_model, self.vocab
        total = v * d                               # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * self.d_ff if self.d_ff else 0
        rglru_p = 3 * d * d + 2 * d * d + 4 * d * CONV_W_APPROX + 2 * d + mlp
        for kind, count in self.block_pattern:
            if kind == "griffin":
                total += count * (2 * rglru_p + attn + mlp)
            elif kind == "xunit":
                total += count * (12 * d * d + 10 * d * d)
            elif kind in ("attn", "local", "enc"):
                total += count * (attn + mlp)
            elif kind == "xdec":
                total += count * (2 * attn + mlp)
            elif kind == "moe":
                e = self.moe
                expert = 3 * d * e.d_ff_expert * e.n_experts
                shared = 3 * d * self.d_ff if e.shared_expert else 0
                total += count * (attn + expert + shared + d * e.n_experts)
            elif kind == "rglru":
                total += count * rglru_p
            elif kind == "mlstm":
                # up 2x2d, qkv+gates in 2d inner, down 2d->d (approximate)
                total += count * (12 * d * d)
            elif kind == "slstm":
                # 4 gates x (input + recurrent) + head mix (approximate)
                total += count * (10 * d * d)
        # encoder stack (whisper)
        total += self.n_enc_layers * (attn + mlp)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        dense_like = self.param_count()
        all_experts = 0
        active = 0
        for kind, count in self.block_pattern:
            if kind == "moe":
                all_experts += count * 3 * d * e.d_ff_expert * e.n_experts
                active += count * 3 * d * e.d_ff_expert * e.top_k
        return dense_like - all_experts + active
