"""Mixture-of-Experts MLP with capacity-based top-k routing and
expert-parallel-friendly layout.

Dispatch is sort-free one-hot/capacity based (the MaxText/GSPMD idiom): a
dispatch tensor [tokens, experts, capacity] routes token activations into an
[experts, capacity, d_model] buffer whose expert axis shards over "model"
(EP). Tokens beyond an expert's capacity are dropped (their combine weight
is zero) — standard capacity-factor semantics; aux load-balancing and
router-z losses are returned for the training loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mlp_apply, mlp_init
from .partition import ParamMeta, hint


def moe_init(rng, cfg: ModelConfig):
    e = cfg.moe
    ks = jax.random.split(rng, 5)
    d, f = cfg.d_model, e.d_ff_expert
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "router": ParamMeta(
            jax.random.normal(ks[0], (d, e.n_experts), dt) * d ** -0.5,
            ("embed", "experts")),
        "wi": ParamMeta(jax.random.normal(ks[1], (e.n_experts, d, f), dt)
                        * d ** -0.5, ("experts", "embed", "ff")),
        "wg": ParamMeta(jax.random.normal(ks[2], (e.n_experts, d, f), dt)
                        * d ** -0.5, ("experts", "embed", "ff")),
        "wo": ParamMeta(jax.random.normal(ks[3], (e.n_experts, f, d), dt)
                        * f ** -0.5, ("experts", "ff", "embed")),
    }
    if e.shared_expert:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.d_ff, gated=True)
    return p


def _capacity(n_tokens: int, e) -> int:
    c = int(n_tokens * e.top_k * e.capacity_factor / e.n_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(p, cfg: ModelConfig, x):
    """x [B, S, D] -> (out [B, S, D], aux-losses dict). Dispatch routing per
    cfg.moe.dispatch ('einsum' global-capacity baseline vs 'local'
    shard_map expert parallelism)."""
    if cfg.moe.dispatch == "local":
        from .partition import current
        ctx = current()
        if ctx is not None and _local_dispatch_applicable(cfg, ctx[0]):
            return moe_apply_local(p, cfg, x, ctx[0])
    return moe_apply_einsum(p, cfg, x)


def moe_apply_einsum(p, cfg: ModelConfig, x):
    """Baseline: GSPMD one-hot/scatter dispatch, GLOBAL capacity."""
    e = cfg.moe
    B, S, D = x.shape
    n_tok = B * S
    cap = _capacity(n_tok, e)
    cd = jnp.dtype(cfg.compute_dtype)

    xt = x.reshape(n_tok, D)
    logits = (xt.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))          # [T, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)   # [T, k]
    if e.top_k > 1:
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e.n_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(n_tok * e.top_k, e.n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)      # [T*k, E]
    pos = (pos_in_expert * flat).sum(-1).reshape(n_tok, e.top_k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch [T, k] -> [E, cap, D] via scatter
    tok_idx = jnp.broadcast_to(jnp.arange(n_tok)[:, None],
                               (n_tok, e.top_k))
    eid = gate_idx.reshape(-1)
    cpos = jnp.where(keep, pos, cap).reshape(-1)           # dropped -> slot cap
    buf = jnp.zeros((e.n_experts, cap + 1, D), cd)
    buf = buf.at[eid, cpos].add(xt.astype(cd)[tok_idx.reshape(-1)])
    buf = hint(buf[:, :cap], "experts", None, "embed")     # [E, cap, D]

    # expert computation (E sharded over "model")
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cd))
    h = jax.nn.silu(g) * h
    h = hint(h, "experts", None, "ff")
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))  # [E, cap, D]

    # combine: gather each kept (token, k) result and weight by its gate
    y_tok = y[eid, jnp.clip(cpos, 0, cap - 1)]             # [T*k, D]
    y_tok = y_tok * (gate_vals.reshape(-1, 1).astype(cd))
    out = jnp.zeros((n_tok, D), cd).at[tok_idx.reshape(-1)].add(y_tok)

    if e.shared_expert:
        shared = mlp_apply(p["shared"], cfg, x)        # [B, S, D] (3-D hints)
        out = out + shared.reshape(n_tok, D).astype(cd)

    # aux losses (Switch-style load balance + router z)
    me = probs.mean(0)                                     # [E]
    ce = jnp.zeros((e.n_experts,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0) / (n_tok * e.top_k)
    aux = {
        "moe_aux": e.aux_coef * e.n_experts * jnp.sum(me * ce),
        "moe_z": e.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map local-capacity dispatch (§Perf hillclimb: qwen3-moe x train_4k)
# ---------------------------------------------------------------------------

def _local_dispatch_applicable(cfg: ModelConfig, mesh) -> bool:
    names = mesh.axis_names
    if "model" not in names:
        return False
    if cfg.moe.n_experts % mesh.shape["model"] != 0:
        return False
    return True


def moe_apply_local(p, cfg: ModelConfig, x, mesh):
    """Expert-parallel MoE with PER-DATA-SHARD capacity via shard_map.

    Why (hypothesis confirmed in EXPERIMENTS.md §Perf): the einsum/scatter
    baseline computes each token's position within its expert's capacity as
    a cumsum over the GLOBAL flattened token dim. That dim is sharded over
    ("pod","data"), so XLA lowers the prefix sum into collective-permute
    chains and replicates the dispatch buffers (~80 GB/layer collectives,
    99 GiB temp). Computing capacity per data shard makes routing entirely
    local; the only cross-chip traffic left is
      * the FSDP all-gather of the layer's expert weights over "data", and
      * ONE psum of the combined output [T_local, D] over "model",
    i.e. exactly a tensor-parallel MLP's collective footprint.

    Every (data s, model m) chip: routes its replicated copy of shard s's
    tokens, builds dispatch buffers ONLY for its local experts, runs them,
    scatters results back to token rows, and psums over "model".
    """
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P

    e = cfg.moe
    B, S, D = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_mp = mesh.shape["model"]
    e_loc = e.n_experts // n_mp
    has_data = "data" in mesh.axis_names

    # specs must match the rule-engine placement of the expert weights:
    # wi/wg [experts->model, embed->data, ff]; wo [experts->model, ff,
    # embed->data] (ff lost "model" to the expert dim — no axis reuse).
    d_ax = "data" if has_data else None
    wi_spec = P("model", d_ax, None)
    wo_spec = P("model", None, d_ax)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def body(xb, router, wi, wg, wo):
        # xb [B_loc, S, D] (replicated over model); w* are local shards
        T = xb.shape[0] * S
        xt = xb.reshape(T, D)
        cap = max(4, int(T * e.top_k * e.capacity_factor / e.n_experts)
                  // 4 * 4)
        m = jax.lax.axis_index("model")
        e0 = m * e_loc

        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)     # [T, k]
        if e.top_k > 1:
            gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        # keep only MY experts; position via LOCAL cumsum per expert
        local_e = gate_idx - e0                                  # [T, k]
        mine = (local_e >= 0) & (local_e < e_loc)
        le = jnp.where(mine, local_e, e_loc)                     # dump row
        onehot = jax.nn.one_hot(le.reshape(-1), e_loc + 1,
                                dtype=jnp.int32)                 # [T*k, E1]
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        cpos = (pos * onehot).sum(-1)                            # [T*k]
        keep = mine.reshape(-1) & (cpos < cap)
        cpos = jnp.where(keep, cpos, cap)
        le_flat = jnp.where(keep, le.reshape(-1), e_loc)

        # FSDP: un-shard my experts' weights over "data"
        if has_data:
            wi_f = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg_f = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        else:
            wi_f, wg_f, wo_f = wi, wg, wo

        tok = jnp.broadcast_to(jnp.arange(T)[:, None],
                               (T, e.top_k)).reshape(-1)
        buf = jnp.zeros((e_loc + 1, cap + 1, D), cd)
        buf = buf.at[le_flat, cpos].add(xt.astype(cd)[tok])
        buf = buf[:e_loc, :cap]

        h = jnp.einsum("ecd,edf->ecf", buf, wi_f.astype(cd))
        g = jnp.einsum("ecd,edf->ecf", buf, wg_f.astype(cd))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                       wo_f.astype(cd))                          # [E1,cap,D]

        y_tok = y[jnp.clip(le_flat, 0, e_loc - 1),
                  jnp.clip(cpos, 0, cap - 1)]                    # [T*k, D]
        w = jnp.where(keep, gate_vals.reshape(-1), 0.0).astype(cd)
        partial = jnp.zeros((T, D), cd).at[tok].add(y_tok * w[:, None])
        out = jax.lax.psum(partial, "model")

        # aux losses: identical on every model chip; average over data
        me_ = probs.mean(0)
        ce_ = jnp.zeros((e.n_experts,), jnp.float32).at[
            gate_idx.reshape(-1)].add(1.0) / (T * e.top_k)
        aux_lb = e.aux_coef * e.n_experts * jnp.sum(me_ * ce_)
        aux_z = e.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2)
        if dp:
            aux_lb = jax.lax.pmean(aux_lb, dp)
            aux_z = jax.lax.pmean(aux_z, dp)
        return out.reshape(xb.shape), aux_lb, aux_z

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(), wi_spec, wi_spec, wo_spec),
        out_specs=(P(dp_spec, None, None), P(), P()),
        check_vma=False)
    out, aux_lb, aux_z = fn(x, p["router"], p["wi"], p["wg"], p["wo"])
    aux = {"moe_aux": aux_lb, "moe_z": aux_z}
    if e.shared_expert:
        out = out + mlp_apply(p["shared"], cfg, x).astype(out.dtype)
    return out, aux
