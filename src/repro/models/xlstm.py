"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent gate connections).

mLSTM trains in its parallel form (stabilized exponential-gate attention
analogue) and decodes with the O(1) recurrent matrix-memory update
C_t = f C_{t-1} + i v k^T — the sub-quadratic property that lets the
xlstm-125m config lower the 500k-token decode shape.

sLSTM has true recurrent connections (h_{t-1} enters the gates), so its
training path is a lax.scan over time; heads use block-diagonal recurrent
matrices as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, dense_init
from .partition import ParamMeta, hint

_EPS = 1e-6


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d                       # inner width (paper's proj factor 2)
    ks = jax.random.split(rng, 8)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "up": dense_init(ks[0], d, 2 * di, ("embed", "ff"), dtype=dt),
        "wq": dense_init(ks[1], di, di, ("ff", "ff"), dtype=dt),
        "wk": dense_init(ks[2], di, di, ("ff", "ff"), dtype=dt),
        "wv": dense_init(ks[3], di, di, ("ff", "ff"), dtype=dt),
        "wi": dense_init(ks[4], di, cfg.n_heads, ("ff", "heads"), bias=True,
                         dtype=dt),
        "wf": dense_init(ks[5], di, cfg.n_heads, ("ff", "heads"), bias=True,
                         dtype=dt),
        "norm": ParamMeta(jnp.ones((di,), dt), ("ff",)),
        "down": dense_init(ks[6], di, d, ("ff", "embed"), dtype=dt),
    }


def _heads(x, h):
    B, S, D = x.shape
    return x.reshape(B, S, h, D // h).transpose(0, 2, 1, 3)  # [B,H,S,dh]


def mlstm_apply(p, cfg: ModelConfig, x, *, state=None):
    """x [B, S, D]. state (decode): {"C": [B,H,dh,dh], "n": [B,H,dh],
    "m": [B,H]}. Returns (out, new_state or None)."""
    B, S, D = x.shape
    H = cfg.n_heads
    u, g = jnp.split(dense(p["up"], x, jnp.float32), 2, axis=-1)  # [B,S,di]
    di = u.shape[-1]
    dh = di // H
    q = _heads(dense(p["wq"], u, jnp.float32), H)
    k = _heads(dense(p["wk"], u, jnp.float32), H) * dh ** -0.5
    v = _heads(dense(p["wv"], u, jnp.float32), H)
    logi = dense(p["wi"], u, jnp.float32).transpose(0, 2, 1)      # [B,H,S]
    logf = jax.nn.log_sigmoid(
        dense(p["wf"], u, jnp.float32)).transpose(0, 2, 1)

    if state is None:
        # parallel stabilized form
        F = jnp.cumsum(logf, axis=-1)                              # [B,H,S]
        Dm = F[:, :, :, None] - F[:, :, None, :] + logi[:, :, None, :]
        causal = jnp.tril(jnp.ones((S, S), bool))
        Dm = jnp.where(causal[None, None], Dm, -jnp.inf)
        m = jnp.max(Dm, axis=-1, keepdims=True)                    # [B,H,S,1]
        m = jnp.maximum(m, -30.0)
        W = jnp.exp(Dm - m) * jnp.einsum("bhsd,bhtd->bhst", q, k)
        n = jnp.maximum(jnp.abs(W.sum(-1, keepdims=True)),
                        jnp.exp(-m)) + _EPS
        h = jnp.einsum("bhst,bhtd->bhsd", W / n, v)                # [B,H,S,dh]
        # exact final recurrent state (for parallel prefill -> O(1) decode):
        # logw_s = F_S - F_s + logi_s, stabilized against m0 = -30
        m0 = jnp.full(logf.shape[:2], -30.0)                       # [B,H]
        logw = F[:, :, -1:] - F + logi                             # [B,H,S]
        mS = jnp.maximum(jnp.max(logw, axis=-1), F[:, :, -1] + m0)
        wS = jnp.exp(logw - mS[..., None])                         # [B,H,S]
        C1 = jnp.einsum("bhs,bhsd,bhse->bhde", wS, k, v)
        n1 = jnp.einsum("bhs,bhsd->bhd", wS, k)
        new_state = {"C": C1, "n": n1, "m": mS}
    else:
        # recurrent decode (S == 1)
        C, n0, m0 = state["C"], state["n"], state["m"]
        li, lf = logi[:, :, 0], logf[:, :, 0]                      # [B,H]
        m1 = jnp.maximum(lf + m0, li)
        fs = jnp.exp(lf + m0 - m1)[..., None]
        is_ = jnp.exp(li - m1)[..., None]
        k0, v0, q0 = k[:, :, 0], v[:, :, 0], q[:, :, 0]            # [B,H,dh]
        C1 = fs[..., None] * C + is_[..., None] * jnp.einsum(
            "bhd,bhe->bhde", k0, v0)
        n1 = fs * n0 + is_ * k0
        num = jnp.einsum("bhde,bhd->bhe", C1, q0)
        den = jnp.maximum(jnp.abs((n1 * q0).sum(-1, keepdims=True)),
                          jnp.exp(-m1)[..., None]) + _EPS
        h = (num / den)[:, :, None, :]                             # [B,H,1,dh]
        new_state = {"C": C1, "n": n1, "m": m1}

    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    # per-channel group norm (paper: head-wise LayerNorm on h)
    mean = h.mean(-1, keepdims=True)
    var = h.var(-1, keepdims=True)
    h = (h - mean) * jax.lax.rsqrt(var + _EPS) * p["norm"].astype(jnp.float32)
    h = h * jax.nn.silu(g)
    out = dense(p["down"], h.astype(x.dtype), cfg.compute_dtype)
    return hint(out, "batch", "seq", "embed"), new_state


def mlstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return {"C": jnp.zeros((batch, H, dh, dh), dtype),
            "n": jnp.zeros((batch, H, dh), dtype),
            "m": jnp.full((batch, H), -30.0, dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        # 4 gates (z, i, f, o) from input
        "wx": dense_init(ks[0], d, 4 * d, ("embed", "ff"), bias=True, dtype=dt),
        # block-diagonal recurrent connections per head: [4, H, dh, dh]
        "r": ParamMeta(jax.random.normal(ks[1], (4, H, dh, dh), dt) * dh ** -0.5,
                      (None, "heads", None, None)),
        "down": dense_init(ks[2], d, d, ("embed", "embed"), dtype=dt),
    }


def _slstm_step(p, cfg, carry, gx):
    """carry: (h, c, n, m) each [B, H, dh]; gx [B, 4, H, dh] (input gates)."""
    h, c, n, m = carry
    r = p["r"].astype(jnp.float32)                      # [4,H,dh,dh]
    rec = jnp.einsum("bhd,ghde->bghe", h, r)            # [B,4,H,dh]
    z = jnp.tanh(gx[:, 0] + rec[:, 0])
    li = gx[:, 1] + rec[:, 1]                           # log-space input gate
    lf = jax.nn.log_sigmoid(gx[:, 2] + rec[:, 2])       # log forget gate
    o = jax.nn.sigmoid(gx[:, 3] + rec[:, 3])
    m1 = jnp.maximum(lf + m, li)
    i_ = jnp.exp(li - m1)
    f_ = jnp.exp(lf + m - m1)
    c1 = f_ * c + i_ * z
    n1 = jnp.maximum(f_ * n + i_, _EPS)
    h1 = o * (c1 / n1)
    return (h1, c1, n1, m1)


def slstm_apply(p, cfg: ModelConfig, x, *, state=None):
    """x [B, S, D]. state (decode): {"h","c","n","m"} each [B,H,dh]."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    gx = dense(p["wx"], x, jnp.float32).reshape(B, S, 4, H, dh)

    if state is None:
        carry = tuple(jnp.zeros((B, H, dh), jnp.float32) for _ in range(3)) \
            + (jnp.full((B, H, dh), -30.0, jnp.float32),)

        def step(carry, gxt):
            new = _slstm_step(p, cfg, carry, gxt)
            return new, new[0]

        carry, hs = jax.lax.scan(step, carry, gx.transpose(1, 0, 2, 3, 4))
        h = hs.transpose(1, 0, 2, 3).reshape(B, S, D)   # [B,S,H,dh]->[B,S,D]
        new_state = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3]}
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])
        new = _slstm_step(p, cfg, carry, gx[:, 0])
        h = new[0].reshape(B, 1, D)
        new_state = {"h": new[0], "c": new[1], "n": new[2], "m": new[3]}

    out = dense(p["down"], h.astype(x.dtype), cfg.compute_dtype)
    return hint(out, "batch", "seq", "embed"), new_state


def slstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), dtype)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, H, dh), -30.0, dtype)}
