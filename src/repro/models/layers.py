"""Foundational layers: norms, RoPE, GQA attention (global/local/cross),
gated MLPs, embeddings. Pure-functional: ``*_init`` builds ParamMeta pytrees
(value + logical axes), ``*_apply`` consumes plain value pytrees.

Dtype policy: params in cfg.param_dtype (fp32 by default), activations and
matmuls in cfg.compute_dtype (bf16), softmax/norm statistics in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .partition import ParamMeta, hint

NEG_INF = -2.0 ** 30  # large-negative that stays finite in bf16


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(rng, d_in: int, d_out: int, axes, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    k1, _ = jax.random.split(rng)
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": ParamMeta(jax.random.normal(k1, (d_in, d_out), dtype) * std,
                        axes)}
    if bias:
        p["b"] = ParamMeta(jnp.zeros((d_out,), dtype), (axes[-1],))
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    out = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        out = out + p["b"].astype(compute_dtype)
    return out


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": ParamMeta(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [B, S, H, hd], positions int32 [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * freqs[None, None,
                                                                  None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; causal / bidirectional / sliding window / cross)
# ---------------------------------------------------------------------------

def attn_init(rng, cfg: ModelConfig, *, cross: bool = False):
    ks = jax.random.split(rng, 6)
    d, hd = cfg.d_model, cfg.head_dim
    dt = _dtype(cfg)
    p = {
        "wq": ParamMeta(jax.random.normal(ks[0], (d, cfg.n_heads, hd), dt)
                        * d ** -0.5, ("embed", "heads", "head_dim")),
        "wk": ParamMeta(jax.random.normal(ks[1], (d, cfg.n_kv_heads, hd), dt)
                        * d ** -0.5, ("embed", "kv", "head_dim")),
        "wv": ParamMeta(jax.random.normal(ks[2], (d, cfg.n_kv_heads, hd), dt)
                        * d ** -0.5, ("embed", "kv", "head_dim")),
        "wo": ParamMeta(jax.random.normal(ks[3], (cfg.n_heads, hd, d), dt)
                        * (cfg.n_heads * hd) ** -0.5,
                        ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamMeta(jnp.zeros((cfg.n_heads, hd), dt),
                            ("heads", "head_dim"))
        p["bk"] = ParamMeta(jnp.zeros((cfg.n_kv_heads, hd), dt),
                            ("kv", "head_dim"))
        p["bv"] = ParamMeta(jnp.zeros((cfg.n_kv_heads, hd), dt),
                            ("kv", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"] = ParamMeta(jnp.ones((hd,), dt), ("head_dim",))
        p["k_norm"] = ParamMeta(jnp.ones((hd,), dt), ("head_dim",))
    return p


def _qk_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def project_qkv(p, cfg: ModelConfig, x, positions, *, use_rope: bool = True):
    """x [B, S, D] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] (RoPE'd, normed)."""
    cd = jnp.dtype(cfg.compute_dtype)
    xq = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xq, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xq, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and not cfg.learned_pos:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = hint(q, "batch", "seq", "heads", None)
    k = hint(k, "batch", "seq", "kv", None)
    return q, k, v


def attention(q, k, v, cfg: ModelConfig, *, mask: jnp.ndarray | None):
    """Grouped-query attention core (direct form).

    q [B,S,H,hd]; k/v [B,T,Hkv,hd]; mask broadcastable to [B,1,1,S,T]
    (True = attend). Softmax in fp32. For large S*T use chunked_attention.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    g = H // k.shape[2]
    qg = q.reshape(B, S, k.shape[2], g, hd)
    scores = jnp.einsum("bsngh,btnh->bnsgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    # scores [B, Hkv, S, g, T]
    if mask is not None:
        scores = jnp.where(mask[:, None, :, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnsgt,btnh->bsngh", w.astype(k.dtype), v)
    return out.reshape(B, S, H, hd)


# Above this many score elements per head, route through the blockwise path.
# (1024^2: whisper's 1500-frame encoder at batch 256 already costs 268 GiB
# of temp via the direct path — see EXPERIMENTS.md §Perf notes.)
CHUNKED_THRESHOLD = 1024 * 1024
CHUNK_Q = 256
CHUNK_K = 1024


def chunked_attention(q, k, v, cfg: ModelConfig, *, positions_q, positions_kv,
                      causal: bool, window: int | None,
                      bq: int = CHUNK_Q, bk: int = CHUNK_K):
    """Blockwise online-softmax (flash-style) attention in pure JAX.

    Never materializes the [S, T] score matrix: an outer lax.map over query
    blocks runs an inner lax.scan over key/value blocks carrying the running
    (max, denominator, accumulator). Each query block is jax.checkpoint'ed so
    the backward pass re-computes blocks instead of saving per-step
    residuals — O(bq*bk) live memory at 32k x 32k sequture lengths.

    positions_*: int32 [B, S] / [B, T]; padded kv positions must be < 0.
    """
    B, S, H, hd = q.shape
    T, n_kv = k.shape[1], k.shape[2]
    g = H // n_kv
    scale = hd ** -0.5

    pad_s = (-S) % bq
    pad_t = (-T) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    pq = jnp.pad(positions_q, ((0, 0), (0, pad_s)), constant_values=0)
    kp = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    pkv = jnp.pad(positions_kv, ((0, 0), (0, pad_t)), constant_values=-1)
    Sp, Tp = S + pad_s, T + pad_t
    nq, nk = Sp // bq, Tp // bk

    # The head dim stays FLAT (H) throughout: reshaping H -> (n_kv, g) here
    # breaks "heads"-sharding when n_kv doesn't divide the model axis and
    # XLA re-gathers q per block (measured 1.2 TB/chip of all-gather on
    # granite-3-8b x prefill_32k — EXPERIMENTS.md §Perf B1). K/V are instead
    # group-expanded per kv-block inside the scan, which is bandwidth-cheap
    # ([bk, H, hd] per step) and keeps every einsum sharding-invariant.
    qb = qp.reshape(B, nq, bq, H, hd).transpose(1, 0, 2, 3, 4)
    pqb = pq.reshape(B, nq, bq).transpose(1, 0, 2)
    kb = kp.reshape(B, nk, bk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    pkb = pkv.reshape(B, nk, bk).transpose(1, 0, 2)
    # Pin the scan-operand layouts: without these constraints XLA propagates
    # a downstream consumer's sharding (e.g. the head_dim-sharded KV cache
    # write) back into kb/vb and re-gathers an 8 MiB block on EVERY
    # (q-block, kv-block) step — measured 1.28 TB/chip on granite-3-8b x
    # prefill_32k (EXPERIMENTS.md §Perf B1).
    qb = hint(qb, None, "batch", None, "heads", None)
    kb = hint(kb, None, "batch", None, "kv", None)
    vb = hint(vb, None, "batch", None, "kv", None)

    # Sliding-window block skipping: with a window, q-block i only needs kv
    # blocks covering [i*bq - window + 1, (i+1)*bq) — a CONSTANT number
    # nw = ceil((bq + window)/bk) + 1, selected per q-block by
    # dynamic_slice. At 32k prefill with window 2048 (recurrentgemma) this
    # is 4 of 32 kv blocks = 8x less attention compute; masks stay exact.
    nw = min(nk, (bq + (window or 0) + bk - 1) // bk + 1) if window else nk
    skip = window is not None and causal and nw < nk

    def one_q_block(args):
        qi, pqi, iq = args                              # [B,bq,H,hd], [B,bq]
        m0 = jnp.full((B, H, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        if skip:
            s = jnp.clip((iq * bq - window + 1) // bk, 0, nk - nw)
            kb_s = jax.lax.dynamic_slice_in_dim(kb, s, nw, axis=0)
            vb_s = jax.lax.dynamic_slice_in_dim(vb, s, nw, axis=0)
            pkb_s = jax.lax.dynamic_slice_in_dim(pkb, s, nw, axis=0)
        else:
            kb_s, vb_s, pkb_s = kb, vb, pkb

        def kv_step(carry, kv):
            m, l, acc = carry
            kj, vj, pkj = kv
            if g > 1:                                   # GQA group expansion
                kj = jnp.repeat(kj, g, axis=2)
                vj = jnp.repeat(vj, g, axis=2)
            s = jnp.einsum("bqhd,bthd->bhqt", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            valid = pkj[:, None, :] >= 0
            if causal:
                valid &= pkj[:, None, :] <= pqi[:, :, None]
            if window is not None:
                valid &= pkj[:, None, :] > pqi[:, :, None] - window
            s = jnp.where(valid[:, None], s, -jnp.inf)   # [B,1,bq,bk] mask
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (padded queries): keep m finite
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqt,bthd->bhqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kb_s, vb_s, pkb_s))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,H,bq,hd]
        return out.transpose(0, 2, 1, 3)                 # [B,bq,H,hd]

    blocks = jax.lax.map(jax.checkpoint(one_q_block),
                         (qb, pqb, jnp.arange(nq, dtype=jnp.int32)))
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)
    return out[:, :S].astype(v.dtype)


def attn_out(p, cfg: ModelConfig, ctx):
    cd = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx.astype(cd), p["wo"].astype(cd))
    return hint(out, "batch", "seq", "embed")


def causal_mask(positions_q, positions_kv, window: int | None = None,
                kv_valid=None):
    """True where q may attend kv. positions_* int32 [B, S]/[B, T]."""
    m = positions_kv[:, None, :] <= positions_q[:, :, None]
    if window is not None:
        m &= positions_kv[:, None, :] > positions_q[:, :, None] - window
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    return m


def attn_apply(p, cfg: ModelConfig, x, positions, *, kind: str = "attn",
               cache=None, cross_kv=None):
    """One attention sub-layer (pre-norm residual handled by caller).

    kind: attn|local|enc. cache: optional dict with k/v [B, T, Hkv, hd] and
    scalar int32 ``pos`` — decode path updates in place at ``pos``.
    Returns (out [B,S,D], new_cache).
    """
    if cross_kv is not None:
        q, _, _ = project_qkv(p, cfg, x, positions, use_rope=False)
        k, v = cross_kv
        if q.shape[1] * k.shape[1] > CHUNKED_THRESHOLD:
            T = k.shape[1]
            pos_kv = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                      (x.shape[0], T))
            out = chunked_attention(q, k, v, cfg, positions_q=positions,
                                    positions_kv=pos_kv, causal=False,
                                    window=None)
        else:
            out = attention(q, k, v, cfg, mask=None)
        return attn_out(p, cfg, out), cache

    q, k, v = project_qkv(p, cfg, x, positions,
                          use_rope=not cfg.learned_pos)
    window = cfg.window if kind == "local" else None
    if cache is None:
        S = q.shape[1]
        if S * S > CHUNKED_THRESHOLD:
            out = chunked_attention(q, k, v, cfg, positions_q=positions,
                                    positions_kv=positions,
                                    causal=kind != "enc", window=window)
        elif kind == "enc":
            out = attention(q, k, v, cfg, mask=None)
        else:
            out = attention(q, k, v, cfg,
                            mask=causal_mask(positions, positions, window))
        return attn_out(p, cfg, out), None

    # cache path: S == 1 -> decode step at cache["pos"]; S > 1 -> prefill.
    # Two cache layouts:
    #  * linear (global attention): k/v [B, T, ...] indexed by position;
    #  * ring   (local attention, cache has "kpos"): fixed window-sized
    #    buffer, slot = pos % W — this is what keeps RecurrentGemma-style
    #    models O(window) memory at 500k-token contexts.
    T = cache["k"].shape[1]
    S = q.shape[1]
    B = x.shape[0]
    ring = "kpos" in cache
    if S == 1:
        pos = cache["pos"]                   # int32 scalar
        if ring:
            slot = pos % T
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            kpos = jax.lax.dynamic_update_slice(
                cache["kpos"],
                jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32), (0, slot))
            valid = (kpos <= pos) & (kpos >= 0)
            if window is not None:
                valid &= kpos > pos - window
            new_cache = {"k": k_all, "v": v_all, "kpos": kpos, "pos": pos + 1}
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            kv_pos = jnp.arange(T, dtype=jnp.int32)
            valid = kv_pos[None, :] <= pos
            if window is not None:
                valid &= kv_pos[None, :] > pos - window
            valid = jnp.broadcast_to(valid, (B, T))
            new_cache = {"k": k_all, "v": v_all, "pos": pos + 1}
        mask = valid[:, None, :]
        out = attention(q, k_all, v_all, cfg, mask=mask)
        return attn_out(p, cfg, out), new_cache

    # prefill: attend over the fresh keys directly (cache starts empty),
    # then write the prefix (ring: its last `window` entries) into the cache.
    if S * S > CHUNKED_THRESHOLD:
        out = chunked_attention(q, k, v, cfg, positions_q=positions,
                                positions_kv=positions, causal=True,
                                window=window)
    else:
        out = attention(q, k, v, cfg,
                        mask=causal_mask(positions, positions, window))
    if ring:
        weff = min(S, T)
        tail = jnp.arange(S - weff, S, dtype=jnp.int32)
        slots = tail % T
        k_all = cache["k"].at[:, slots].set(k[:, -weff:].astype(cache["k"].dtype))
        v_all = cache["v"].at[:, slots].set(v[:, -weff:].astype(cache["v"].dtype))
        kpos = cache["kpos"].at[:, slots].set(
            jnp.broadcast_to(tail, (B, weff)))
        new_cache = {"k": k_all, "v": v_all, "kpos": kpos,
                     "pos": jnp.asarray(S, jnp.int32)}
    else:
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": k_all, "v": v_all, "pos": jnp.asarray(S, jnp.int32)}
    return attn_out(p, cfg, out), new_cache


def cross_kv_project(p, cfg: ModelConfig, enc_out):
    """Precompute a decoder layer's cross-attention K/V from encoder output
    (done once per sequence; cached across decode steps)."""
    cd = jnp.dtype(cfg.compute_dtype)
    k = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd), p["wv"].astype(cd))
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ModelConfig, d_ff: int | None = None,
             gated: bool = True):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    d = cfg.d_model
    dt = _dtype(cfg)
    p = {
        "wi": ParamMeta(jax.random.normal(ks[0], (d, d_ff), dt) * d ** -0.5,
                        ("embed", "ff")),
        "wo": ParamMeta(jax.random.normal(ks[1], (d_ff, d), dt) * d_ff ** -0.5,
                        ("ff", "embed")),
    }
    if gated:
        p["wg"] = ParamMeta(jax.random.normal(ks[2], (d, d_ff), dt) * d ** -0.5,
                            ("embed", "ff"))
    return p


def mlp_apply(p, cfg: ModelConfig, x):
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h = xc @ p["wi"].astype(cd)
    if "wg" in p:
        h = jax.nn.silu(xc @ p["wg"].astype(cd)) * h
    else:
        h = jax.nn.gelu(h)
    h = hint(h, "batch", "seq", "ff")
    return hint(h @ p["wo"].astype(cd), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embeddings / logits
# ---------------------------------------------------------------------------

def embed_init(rng, cfg: ModelConfig):
    dt = _dtype(cfg)
    p = {"tok": ParamMeta(
        jax.random.normal(rng, (cfg.vocab, cfg.d_model), dt) * 0.02,
        ("vocab", "embed"))}
    if cfg.learned_pos:
        p["pos"] = ParamMeta(
            jax.random.normal(jax.random.fold_in(rng, 1),
                              (max(cfg.enc_seq, 8192), cfg.d_model), dt) * 0.02,
            (None, "embed"))
    return p


def embed_apply(p, cfg: ModelConfig, tokens, positions=None):
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(p["tok"], tokens, axis=0).astype(cd)
    if cfg.learned_pos and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cd)
    return hint(x, "batch", "seq", "embed")


def logits_init(rng, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    dt = _dtype(cfg)
    return {"w": ParamMeta(
        jax.random.normal(rng, (cfg.d_model, cfg.vocab), dt)
        * cfg.d_model ** -0.5, ("embed", "vocab"))}


def logits_apply(p, embed_params, cfg: ModelConfig, x):
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = embed_params["tok"].astype(cd).T
    else:
        w = p["w"].astype(cd)
    out = (x.astype(cd) @ w).astype(jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        out = jnp.tanh(out / c) * c
    return hint(out, "batch", "seq", "vocab")
