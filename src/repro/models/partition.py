"""Logical-axis partitioning context.

Model code annotates parameters and activations with LOGICAL axis names
("embed", "ff", "heads", "experts", "batch", "seq", ...). The launcher
installs a (mesh, rules) context; ``hint`` then applies
with_sharding_constraint with the resolved PartitionSpec. Outside a context
(unit tests, single-device smoke runs) everything is a no-op.

Params are built as ParamMeta leaves carrying their logical axes; split_meta
separates values from specs so the same init code serves real runs,
eval_shape dry-runs, and the sharding rule engine.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

_CTX: contextvars.ContextVar[tuple[Any, dict] | None] = \
    contextvars.ContextVar("partitioning", default=None)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ParamMeta:
    value: Any                      # jnp array (or ShapeDtypeStruct)
    axes: tuple[str | None, ...]    # logical name per dim

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def split_meta(tree):
    """pytree of ParamMeta -> (values pytree, axes pytree)."""
    values = jax.tree.map(lambda m: m.value, tree, is_leaf=is_meta)
    axes = jax.tree.map(lambda m: m.axes, tree, is_leaf=is_meta)
    return values, axes


@contextlib.contextmanager
def partitioning(mesh, rules: dict[str, tuple[str, ...] | str | None]):
    """rules: logical axis name -> mesh axes (or None = replicate)."""
    token = _CTX.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _CTX.reset(token)


def current() -> tuple[Any, dict] | None:
    return _CTX.get()


def resolve_spec(axes: tuple[str | None, ...], shape: tuple[int, ...] | None,
                 mesh, rules) -> PartitionSpec:
    """Logical axes -> PartitionSpec under divisibility + no-reuse checks.

    shape=None skips divisibility checks (activation hints where XLA pads).
    """
    used: set[str] = set()
    parts = []
    if shape is not None and len(axes) != len(shape):   # rank-mismatch hint:
        return PartitionSpec()                          # no constraint
    for i, name in enumerate(axes):
        assigned = None
        if name is not None:
            cand = rules.get(name)
            if cand is not None:
                mesh_axes = (cand,) if isinstance(cand, str) else tuple(cand)
                if not any(a in used for a in mesh_axes):
                    size = 1
                    for a in mesh_axes:
                        size *= mesh.shape[a]
                    if shape is None or shape[i] % size == 0:
                        assigned = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                        used.update(mesh_axes)
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def hint(x: jnp.ndarray, *axes: str | None) -> jnp.ndarray:
    """Annotate an activation with logical axes (no-op outside a context)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(tuple(axes), tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
