"""Model assembly: block dispatch, lax.scan'd layer segments, and the
functional Model API (init / forward_train / prefill / decode_step).

Layer stacks are grouped into (kind, count) segments (cfg.block_pattern);
each segment's parameters are stacked along a leading "layers" axis and the
segment is executed with lax.scan — the lowered HLO contains ONE instance of
each block kind regardless of depth, which keeps 48-layer x 512-device
dry-run compiles tractable and is how production JAX LM frameworks ship.

Caches are pytrees stacked the same way; decode scans (params, cache)
together. Training applies jax.checkpoint around each block when
cfg.remat == "full".
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import layers, moe as moe_mod, rglru, xlstm
from .config import ModelConfig
from .partition import ParamMeta, hint, is_meta, split_meta

ATTN_KINDS = ("attn", "local", "enc", "moe", "xdec")


def _stack_meta(metas: list):
    """Stack per-layer ParamMeta pytrees along a leading 'layers' axis."""
    return jax.tree.map(
        lambda *ms: ParamMeta(jnp.stack([m.value for m in ms]),
                              ("layers",) + tuple(ms[0].axes)),
        *metas, is_leaf=is_meta)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(rng, cfg: ModelConfig, kind: str):
    ks = jax.random.split(rng, 8)
    d = cfg.d_model
    if kind == "griffin":     # composite: rglru, rglru, local attention
        return {"b1": block_init(ks[0], cfg, "rglru"),
                "b2": block_init(ks[1], cfg, "rglru"),
                "b3": block_init(ks[2], cfg, "local")}
    if kind == "xunit":       # composite: mlstm, slstm
        return {"b1": block_init(ks[0], cfg, "mlstm"),
                "b2": block_init(ks[1], cfg, "slstm")}
    p = {"ln1": layers.rmsnorm_init(d)}
    if kind in ("attn", "local", "enc", "moe"):
        p["attn"] = layers.attn_init(ks[0], cfg)
        p["ln2"] = layers.rmsnorm_init(d)
        if kind == "moe":
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        elif cfg.d_ff:
            p["mlp"] = layers.mlp_init(ks[1], cfg, gated=cfg.gated_mlp)
    elif kind == "xdec":
        p["attn"] = layers.attn_init(ks[0], cfg)
        p["lnx"] = layers.rmsnorm_init(d)
        p["xattn"] = layers.attn_init(ks[1], cfg, cross=True)
        p["ln2"] = layers.rmsnorm_init(d)
        if cfg.d_ff:
            p["mlp"] = layers.mlp_init(ks[2], cfg, gated=cfg.gated_mlp)
    elif kind == "rglru":
        p["rec"] = rglru.rglru_init(ks[0], cfg)
        p["ln2"] = layers.rmsnorm_init(d)
        if cfg.d_ff:
            p["mlp"] = layers.mlp_init(ks[1], cfg)
    elif kind == "mlstm":
        p["core"] = xlstm.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["core"] = xlstm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


_COMPOSITE = {"griffin": ("rglru", "rglru", "local"),
              "xunit": ("mlstm", "slstm")}


def block_apply(p, cfg: ModelConfig, kind: str, x, positions, *,
                cache=None, enc_out=None):
    """Returns (x, new_cache, aux) — aux is a dict of scalar extra losses."""
    aux = {}
    if kind in _COMPOSITE:
        new_cache = {} if cache is not None else None
        for i, sub in enumerate(_COMPOSITE[kind]):
            key = f"b{i + 1}"
            sub_c = None if cache is None else cache[key]
            x, c2, a = block_apply(p[key], cfg, sub, x, positions,
                                   cache=sub_c, enc_out=enc_out)
            for k, v in a.items():
                aux[k] = aux.get(k, 0.0) + v
            if new_cache is not None:
                new_cache[key] = c2
        return x, new_cache, aux
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "local", "enc", "moe"):
        attn_cache = None if cache is None else cache.get("attn")
        a, new_attn = layers.attn_apply(p["attn"], cfg, h, positions,
                                        kind=kind, cache=attn_cache)
        x = x + a
        h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            mo, aux = moe_mod.moe_apply(p["moe"], cfg, h2)
            x = x + mo
        elif "mlp" in p:
            x = x + layers.mlp_apply(p["mlp"], cfg, h2)
        new_cache = None if new_attn is None else {"attn": new_attn}
    elif kind == "xdec":
        attn_cache = None if cache is None else cache.get("attn")
        a, new_attn = layers.attn_apply(p["attn"], cfg, h, positions,
                                        kind="attn", cache=attn_cache)
        x = x + a
        hx = layers.rmsnorm(p["lnx"], x, cfg.norm_eps)
        if cache is not None and "ck" in cache and x.shape[1] == 1:
            ckv = (cache["ck"], cache["cv"])      # decode: cached cross-K/V
        else:
            ckv = layers.cross_kv_project(p["xattn"], cfg, enc_out)
        xa, _ = layers.attn_apply(p["xattn"], cfg, hx, positions,
                                  cross_kv=ckv)
        x = x + xa
        h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "mlp" in p:
            x = x + layers.mlp_apply(p["mlp"], cfg, h2)
        new_cache = None if new_attn is None else \
            {"attn": new_attn, "ck": ckv[0], "cv": ckv[1]}
    elif kind in ("rglru", "mlstm", "slstm"):
        # recurrent kinds: S > 1 runs the parallel form (which also yields
        # the exact final state for prefill); S == 1 is the O(1) decode step.
        prefill = x.shape[1] > 1
        key = "rec" if kind == "rglru" else "core"
        st = None if (cache is None or prefill) else cache[key]
        apply = {"rglru": rglru.rglru_apply, "mlstm": xlstm.mlstm_apply,
                 "slstm": xlstm.slstm_apply}[kind]
        r, new_st = apply(p[key], cfg, h, state=st)
        x = x + r
        if kind == "rglru":
            h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
            if "mlp" in p:
                x = x + layers.mlp_apply(p["mlp"], cfg, h2)
        new_cache = None if cache is None else {key: new_st}
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     enc_len: int = 0):
    """Zero cache pytree for one block of the given kind."""
    if kind in _COMPOSITE:
        return {f"b{i + 1}": block_cache_init(cfg, sub, batch, cache_len,
                                              enc_len)
                for i, sub in enumerate(_COMPOSITE[kind])}
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    dt = jnp.dtype(cfg.compute_dtype)
    if kind == "local":
        wc = min(cache_len, cfg.window)      # ring buffer: O(window) memory
        return {"attn": {
            "k": jnp.zeros((batch, wc, hkv, hd), dt),
            "v": jnp.zeros((batch, wc, hkv, hd), dt),
            "kpos": jnp.full((batch, wc), -1, jnp.int32),
            "pos": jnp.zeros((), jnp.int32)}}
    if kind in ("attn", "moe"):
        return {"attn": {
            "k": jnp.zeros((batch, cache_len, hkv, hd), dt),
            "v": jnp.zeros((batch, cache_len, hkv, hd), dt),
            "pos": jnp.zeros((), jnp.int32)}}
    if kind == "xdec":
        return {"attn": {
            "k": jnp.zeros((batch, cache_len, hkv, hd), dt),
            "v": jnp.zeros((batch, cache_len, hkv, hd), dt),
            "pos": jnp.zeros((), jnp.int32)},
            "ck": jnp.zeros((batch, enc_len, hkv, hd), dt),
            "cv": jnp.zeros((batch, enc_len, hkv, hd), dt)}
    if kind == "rglru":
        return {"rec": rglru.rglru_state_init(cfg, batch)}
    if kind == "mlstm":
        return {"core": xlstm.mlstm_state_init(cfg, batch)}
    if kind == "slstm":
        return {"core": xlstm.slstm_state_init(cfg, batch)}
    raise ValueError(kind)


def block_cache_axes(cfg: ModelConfig, kind: str):
    """Logical-axes pytree mirroring block_cache_init (for the sharding
    rule engine)."""
    if kind in _COMPOSITE:
        return {f"b{i + 1}": block_cache_axes(cfg, sub)
                for i, sub in enumerate(_COMPOSITE[kind])}
    kv4 = ("batch", "kv_seq", "kv", "head_dim")
    if kind == "local":
        return {"attn": {"k": kv4, "v": kv4, "kpos": ("batch", "kv_seq"),
                         "pos": ()}}
    if kind in ("attn", "moe"):
        return {"attn": {"k": kv4, "v": kv4, "pos": ()}}
    if kind == "xdec":
        return {"attn": {"k": kv4, "v": kv4, "pos": ()},
                "ck": ("batch", "enc_seq", "kv", "head_dim"),
                "cv": ("batch", "enc_seq", "kv", "head_dim")}
    if kind == "rglru":
        return {"rec": {"h": ("batch", "rec"), "conv": ("batch", None, "rec")}}
    if kind == "mlstm":
        return {"core": {"C": ("batch", "heads", None, None),
                         "n": ("batch", "heads", None),
                         "m": ("batch", "heads")}}
    if kind == "slstm":
        return {"core": {k: ("batch", "heads", None)
                         for k in ("h", "c", "n", "m")}}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def init_meta(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 4 + len(cfg.block_pattern))
        p = {"embed": layers.embed_init(ks[0], cfg),
             "final_norm": layers.rmsnorm_init(cfg.d_model),
             "lm_head": layers.logits_init(ks[1], cfg)}
        if cfg.n_enc_layers:
            enc = [block_init(jax.random.fold_in(ks[2], i), cfg, "enc")
                   for i in range(cfg.n_enc_layers)]
            p["encoder"] = _stack_meta(enc)
            p["enc_norm"] = layers.rmsnorm_init(cfg.d_model)
        segs = {}
        for si, (kind, count) in enumerate(cfg.block_pattern):
            ms = [block_init(jax.random.fold_in(ks[3 + si], i), cfg, kind)
                  for i in range(count)]
            segs[f"seg{si}_{kind}"] = _stack_meta(ms)
        p["segments"] = segs
        return p

    def init(self, rng):
        """-> (params values, logical axes pytree)."""
        return split_meta(self.init_meta(rng))

    def abstract_params(self, rng=None):
        """Shape/spec-only init (never allocates) for dry-runs."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        meta_shape = jax.eval_shape(self.init_meta, rng)
        values = jax.tree.map(lambda m: m.value, meta_shape, is_leaf=is_meta)
        concrete_meta = None
        # axes come from a cheap non-abstract trace of the SAME structure:
        axes = jax.tree.map(lambda m: m.axes, meta_shape, is_leaf=is_meta)
        return values, axes

    # -- forward (training / scoring) ----------------------------------------
    def forward_train(self, params, tokens, *, enc_feats=None,
                      vis_embeds=None):
        """tokens int32 [B, S] -> (logits fp32 [B, S, V], aux dict)."""
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = layers.embed_apply(params["embed"], cfg, tokens, positions)
        if vis_embeds is not None:  # vision stub: patch embeds replace prefix
            P = vis_embeds.shape[1]
            x = jax.lax.dynamic_update_slice(
                x, vis_embeds.astype(x.dtype), (0, 0, 0))
        enc_out = None
        if cfg.n_enc_layers:
            enc_out = self._encode(params, enc_feats)
        aux_total = {}
        x = self._run_segments(params, x, positions, enc_out=enc_out,
                               aux_out=aux_total, remat=cfg.remat == "full")
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.logits_apply(params["lm_head"], params["embed"], cfg, x)
        return logits, aux_total

    def _encode(self, params, enc_feats):
        cfg = self.cfg
        B, T, _ = enc_feats.shape
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x = enc_feats.astype(jnp.dtype(cfg.compute_dtype))
        if cfg.learned_pos:
            x = x + jnp.take(params["embed"]["pos"], pos, axis=0).astype(x.dtype)

        def enc_step(xc, p):
            out, _, _ = block_apply(p, cfg, "enc", xc, pos)
            return out, None

        x, _ = jax.lax.scan(enc_step, x, params["encoder"])
        return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def _run_segments(self, params, x, positions, *, enc_out=None,
                      caches=None, aux_out=None, remat=False):
        cfg = self.cfg
        new_caches = {}
        for si, (kind, count) in enumerate(cfg.block_pattern):
            name = f"seg{si}_{kind}"
            seg_p = params["segments"][name]

            if caches is None:
                def step(xc, p, _kind=kind):
                    out, _, aux = block_apply(p, cfg, _kind, xc, positions,
                                              enc_out=enc_out)
                    return out, aux
                if remat:
                    step = jax.checkpoint(
                        step, policy=jax.checkpoint_policies.nothing_saveable)
                x, auxs = jax.lax.scan(lambda c, p: step(c, p), x, seg_p)
                if aux_out is not None:
                    for k, v in auxs.items():
                        aux_out[k] = aux_out.get(k, 0.0) + v.sum()
            else:
                def step_c(xc, pc, _kind=kind):
                    p, c = pc
                    out, c2, _ = block_apply(p, cfg, _kind, xc, positions,
                                             cache=c, enc_out=enc_out)
                    return out, c2
                x, c2 = jax.lax.scan(step_c, x, (seg_p, caches[name]))
                new_caches[name] = c2
        if caches is not None:
            return x, new_caches
        return x

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, enc_len: int | None = None):
        cfg = self.cfg
        enc_len = enc_len if enc_len is not None else cfg.enc_seq
        caches = {}
        for si, (kind, count) in enumerate(cfg.block_pattern):
            name = f"seg{si}_{kind}"
            one = block_cache_init(cfg, kind, batch, cache_len, enc_len)
            caches[name] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one)
        return caches

    def cache_axes(self):
        """Logical axes for init_cache's pytree (leading 'layers' dim)."""
        axes = {}
        for si, (kind, count) in enumerate(self.cfg.block_pattern):
            one = block_cache_axes(self.cfg, kind)
            axes[f"seg{si}_{kind}"] = jax.tree.map(
                lambda a: ("layers",) + a, one,
                is_leaf=lambda x: isinstance(x, tuple))
        return axes

    def decode_step(self, params, caches, tokens, pos):
        """One token: tokens [B, 1], pos int32 [] (same position across the
        batch; per-request offsets live in the serving layer).
        Returns (logits [B, 1, V], new caches)."""
        cfg = self.cfg
        B = tokens.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        x = layers.embed_apply(params["embed"], cfg, tokens, positions)
        # keep every layer's attn cache pos in sync with the global pos
        caches = jax.tree.map(lambda a: a, caches)
        caches = self._set_cache_pos(caches, pos)
        x, new_caches = self._run_segments(params, x, positions, caches=caches)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.logits_apply(params["lm_head"], params["embed"], cfg, x)
        return logits, new_caches

    def _set_cache_pos(self, caches, pos):
        def set_pos(path_cache):
            if isinstance(path_cache, dict) and "attn" in path_cache:
                path_cache = dict(path_cache)
                a = dict(path_cache["attn"])
                a["pos"] = jnp.broadcast_to(pos, a["pos"].shape).astype(jnp.int32)
                path_cache["attn"] = a
            return path_cache
        return {k: set_pos(v) for k, v in caches.items()}

    def prefill(self, params, tokens, cache_len: int, *, enc_feats=None):
        """Parallel prefill: one forward pass that both produces logits and
        fills every block's cache/state exactly (attention K/V written in
        parallel; recurrent blocks return their closed-form final state).
        Returns (logits [B, S, V], caches positioned at S)."""
        cfg = self.cfg
        B, S = tokens.shape
        caches = self.init_cache(B, cache_len)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        x = layers.embed_apply(params["embed"], cfg, tokens, positions)
        enc_out = self._encode(params, enc_feats) if cfg.n_enc_layers else None
        x, new_caches = self._run_segments(params, x, positions,
                                           enc_out=enc_out, caches=caches)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.logits_apply(params["lm_head"], params["embed"], cfg, x)
        return logits, new_caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
