from .config import ModelConfig, MoEConfig, LAYERS_PER_KIND
from .transformer import Model, build_model, block_init, block_apply
from .partition import partitioning, hint, split_meta, resolve_spec

__all__ = ["ModelConfig", "MoEConfig", "LAYERS_PER_KIND", "Model",
           "build_model", "block_init", "block_apply", "partitioning",
           "hint", "split_meta", "resolve_spec"]
