"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (per the paper):
  x -> [linear gate branch: GeLU(W_g x)] ⊙ [conv1d(width 4) -> RG-LRU] -> W_out

RG-LRU recurrence (diagonal, per channel):
  r_t = sigmoid(W_a x_t + b_a)          recurrence gate
  i_t = sigmoid(W_x x_t + b_x)          input gate
  a_t = exp(c * softplus(Λ) * (-r_t))   in (0,1), c = 8
  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses jax.lax.associative_scan over the affine maps
(a_t, b_t) — O(log S) depth, sequence-shardable; decode is the O(1) state
update. This is the sub-quadratic path that makes long_500k lowerable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, dense_init
from .partition import ParamMeta, hint

_C = 8.0
CONV_W = 4


def rglru_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    dr = d  # recurrence width == d_model (RecurrentGemma uses d_rnn ~ d)
    ks = jax.random.split(rng, 7)
    dt = jnp.dtype(cfg.param_dtype)
    # Λ init so that a^c spans ~(0.9, 0.999) as in the paper
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, dr, dtype=jnp.float32)) / _C))
    return {
        "w_in": dense_init(ks[0], d, dr, ("embed", "rec"), dtype=dt),
        "w_gate": dense_init(ks[1], d, dr, ("embed", "rec"), dtype=dt),
        "conv": ParamMeta(jax.random.normal(ks[2], (CONV_W, dr), dt) * 0.1,
                          (None, "rec")),
        "w_a": dense_init(ks[3], dr, dr, ("rec", "rec"), bias=True, dtype=dt,
                          scale=dr ** -0.5),
        "w_x": dense_init(ks[4], dr, dr, ("rec", "rec"), bias=True, dtype=dt,
                          scale=dr ** -0.5),
        "lam": ParamMeta(lam.astype(dt), ("rec",)),
        "w_out": dense_init(ks[5], dr, d, ("rec", "embed"), dtype=dt),
    }


def _gates(p, u):
    """u [B, S, dr] (post-conv) -> (log_a, b) of the affine recurrence."""
    r = jax.nn.sigmoid(dense(p["w_a"], u, jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_x"], u, jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * i * u.astype(jnp.float32)
    return a, b


def _causal_conv(p, u, state=None):
    """Width-4 causal depthwise conv. state [B, CONV_W-1, dr] for decode."""
    w = p["conv"].astype(jnp.float32)
    if state is None:
        pads = jnp.pad(u, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    else:
        pads = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(pads[:, i:i + u.shape[1], :] * w[i] for i in range(CONV_W))
    new_state = pads[:, -(CONV_W - 1):, :]
    return out, new_state


def rglru_apply(p, cfg: ModelConfig, x, *, state=None):
    """x [B, S, D]; state (decode) = {"h": [B, dr], "conv": [B, 3, dr]}.

    Returns (out [B, S, D], new_state or None).
    """
    u = dense(p["w_in"], x, jnp.float32)                   # [B, S, dr]
    gate = jax.nn.gelu(dense(p["w_gate"], x, jnp.float32))

    if state is None:
        u_raw = u
        u, conv_tail = _causal_conv(p, u)
        a, b = _gates(p, u)
        # associative scan over affine maps (a, b): compose((a1,b1),(a2,b2))
        #   = (a2*a1, a2*b1 + b2), scanned along time.
        def compose(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(compose, (a, b), axis=1)
        h = hint(h, "batch", "seq", "rec")
        # final state (exact): enables parallel prefill -> O(1) decode
        new_state = {"h": h[:, -1, :], "conv": conv_tail}
    else:
        u, conv_state = _causal_conv(p, u, state["conv"])
        a, b = _gates(p, u)
        h_prev = state["h"].astype(jnp.float32)[:, None, :]
        h = a * h_prev + b                                  # S == 1
        new_state = {"h": h[:, -1, :], "conv": conv_state}

    out = dense(p["w_out"], (h * gate).astype(x.dtype), cfg.compute_dtype)
    return hint(out, "batch", "seq", "embed"), new_state


def rglru_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    dr = cfg.d_model
    return {"h": jnp.zeros((batch, dr), dtype),
            "conv": jnp.zeros((batch, CONV_W - 1, dr), dtype)}
