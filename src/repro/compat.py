"""Version compatibility shims for jax.

The repo targets jax 0.4.37 (the baked toolchain) but was written against
newer spellings in places. Everything version-dependent funnels through
here so call sites stay clean:

* ``shard_map`` — moved from ``jax.experimental.shard_map`` to ``jax``
  top-level in 0.6; the replication-check kwarg was renamed
  ``check_rep`` -> ``check_vma``. We accept the new spelling and translate.
* ``make_mesh`` — ``axis_types=`` (and ``jax.sharding.AxisType``) only
  exist on newer jax; on 0.4.x every mesh axis is Auto already, so the
  argument is dropped.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

try:  # jax >= 0.6 spelling
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """``jax.shard_map`` with the ``check_vma`` kwarg accepted everywhere.

    On jax 0.4.x the same switch is spelled ``check_rep``; passing the
    wrong name raises TypeError, so translate to whatever this jax has.
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    Newer jax grew explicit/auto axis types; pinning Auto keeps the
    historical shard_map/pjit behaviour. jax 0.4.x has no ``axis_types``
    kwarg and every axis is Auto, so the plain call is equivalent.
    """
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))
