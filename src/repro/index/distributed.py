"""Mesh-sharded COBS query engine.

Sharding layout (TPU adaptation of the paper's external-memory scan):

* arena columns (packed document words) shard over the ``doc_axes``
  (("pod", "data") on the production mesh) — every chip scans only its own
  documents; this is the embarrassingly-parallel axis and carries ZERO
  communication until result selection.
* arena rows optionally shard over ``row_axis`` ("model") — each chip holds
  a horizontal stripe of the Bloom rows; a term's row lives on exactly one
  stripe, partial scores are psum'd over the row axis. Row sharding requires
  n_hashes == 1 (the paper's default): with k > 1 the AND over hash rows
  does not commute with the score reduction across stripes.

Result selection is a distributed top-k: per-shard lax.top_k of local
document scores, all_gather of (score, global_slot) candidates over the
document axes, then a final top-k — O(shards * topk) bytes, negligible next
to the row scan.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from ..core import dna, hashing
from ..core.index import BitSlicedIndex
from ..core.query import plan_rows
from ..kernels import ops


def _pad_to(x: np.ndarray, axis: int, multiple: int) -> np.ndarray:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


class DistributedIndex:
    """A BitSlicedIndex resident on a device mesh.

    doc_axes: mesh axes sharding the document (word-column) dimension.
    row_axis: optional mesh axis sharding the Bloom-row dimension.
    """

    def __init__(self, index: BitSlicedIndex, mesh: Mesh,
                 doc_axes: tuple[str, ...] = ("data",),
                 row_axis: str | None = None,
                 score_method: str = "vertical",
                 score_dtype=jnp.int32):
        if row_axis is not None and index.params.n_hashes != 1:
            raise ValueError("row sharding requires n_hashes == 1 "
                             "(AND over hashes does not commute with psum)")
        self.mesh = mesh
        self.doc_axes = tuple(doc_axes)
        self.row_axis = row_axis
        self.params = index.params
        self.score_method = score_method
        # int16 halves the psum bytes over the row axis; safe while
        # ell <= 32767 (§Perf cell C iteration)
        self.score_dtype = score_dtype
        self.n_docs = index.n_docs
        self.block_docs_orig = index.block_docs

        n_doc_shards = math.prod(mesh.shape[a] for a in self.doc_axes)
        n_row_shards = mesh.shape[row_axis] if row_axis else 1

        # full_host reads mmap'd shards directly — index.arena would first
        # concatenate an out-of-core index dense in device memory
        arena = index.storage.full_host()
        arena = _pad_to(arena, 1, n_doc_shards)       # pad doc words
        arena = _pad_to(arena, 0, n_row_shards)       # pad rows (zeros, never
        self.doc_words = arena.shape[1]               # addressed by queries)
        self.total_rows = arena.shape[0]
        self.row_stripe = self.total_rows // n_row_shards
        self.words_local = self.doc_words // n_doc_shards
        self.n_blocks = index.n_blocks
        self.slots_per_block = self.doc_words * 32

        spec = P(self.row_axis, self.doc_axes if len(self.doc_axes) > 1
                 else self.doc_axes[0])
        self.arena = jax.device_put(arena, NamedSharding(mesh, spec))
        rep = NamedSharding(mesh, P())
        self.row_offset = jax.device_put(np.asarray(index.row_offset), rep)
        self.block_width = jax.device_put(np.asarray(index.block_width), rep)
        self.doc_slot = np.asarray(index.doc_slot)
        # original-id lookup: slot -> doc id (-1 for padding slots)
        self.slot_doc = np.full(self.n_blocks * self.slots_per_block, -1,
                                dtype=np.int64)
        # doc i sits at slot: block*B_orig + pos, where B_orig = orig block
        # width*32. After column padding the per-block slot capacity grew, so
        # remap: orig slot (b, pos) -> padded slot b*slots_per_block + pos.
        b = self.doc_slot // index.block_docs
        pos = self.doc_slot % index.block_docs
        padded_slots = b * self.slots_per_block + pos
        self.slot_doc[padded_slots] = np.arange(index.n_docs)
        self._padded_doc_slot = padded_slots  # int64 [n_docs]
        # score_fn() output is SHARD-major (shard_map stitches per-shard
        # [nb*Wl*32] score vectors along the doc axis):
        #   flat = shard*(nb*Wl*32) + block*(Wl*32) + word_local*32 + bit
        word, bit = pos // 32, pos % 32
        shard_of = word // self.words_local
        word_l = word % self.words_local
        per_shard = self.n_blocks * self.words_local * 32
        self._flat_doc_slot = (shard_of * per_shard + b * self.words_local * 32
                               + word_l * 32 + bit)
        self._score_jit = None
        self._topk_jit = {}

    # ------------------------------------------------------------------
    def _shard_body(self, topk: int | None):
        n_hashes = self.params.n_hashes
        nb = self.n_blocks
        row_axis, doc_axes = self.row_axis, self.doc_axes
        row_stripe = self.row_stripe
        words_local = self.words_local
        slots_per_block = self.slots_per_block
        method = self.score_method
        sdtype = self.score_dtype

        def one_query(arena_l, row_offset, block_width, terms, n_valid):
            L = terms.shape[0]
            h = hashing.hash_terms(terms, n_hashes)            # [L, k]
            rows = plan_rows(h, row_offset, block_width)       # [L, k, nb]
            valid = jnp.arange(L, dtype=jnp.int32) < n_valid
            if row_axis is not None:
                m = jax.lax.axis_index(row_axis)
                base = (m * row_stripe).astype(jnp.int32)
                local = rows - base
                own = (local >= 0) & (local < row_stripe)
                local = jnp.clip(local, 0, row_stripe - 1)
            else:
                local, own = rows, None
            if method == "lookup" and n_hashes == 1:
                # fused path: rows stream straight from the arena shard —
                # the [L, nb, Wl] gathered copy never materializes
                idx = local[:, 0].T                            # [nb, L]
                msk = jnp.broadcast_to(valid[None, :], idx.shape)
                if own is not None:
                    msk = msk & own[:, 0].T
                scores = ops.bitslice_lookup_score_blocks(
                    arena_l, idx, msk.astype(jnp.int32))
                return scores.astype(sdtype)
            g = arena_l[local]                                 # [L,k,nb,Wl]
            if own is not None:
                g = jnp.where(own[..., None], g, jnp.uint32(0))
            anded = g[:, 0]
            for i in range(1, n_hashes):
                anded = anded & g[:, i]
            anded = jnp.where(valid[:, None, None], anded, jnp.uint32(0))
            flat = anded.reshape(L, nb * words_local)
            m_ = "vertical" if method == "lookup" else method
            return ops.bitslice_score(flat, method=m_).astype(sdtype)

        def body(arena_l, row_offset, block_width, terms, n_valid):
            scores = jax.vmap(one_query, in_axes=(None, None, None, 0, 0))(
                arena_l, row_offset, block_width, terms, n_valid)
            if row_axis is not None:
                scores = jax.lax.psum(scores, row_axis)        # [Q, local]
            if topk is None:
                return scores
            # ---- distributed top-k over the document axes ----
            q, n_local = scores.shape
            k = min(topk, n_local)
            vals, idx = jax.lax.top_k(scores, k)               # [Q, k]
            d = jax.lax.axis_index(doc_axes)                   # flat doc rank
            blk = idx // (words_local * 32)
            rem = idx % (words_local * 32)
            word_l, bit = rem // 32, rem % 32
            gslot = (blk * slots_per_block
                     + (d * words_local + word_l) * 32 + bit)
            vals_g = jax.lax.all_gather(vals, doc_axes, axis=1,
                                        tiled=True)            # [Q, P*k]
            slot_g = jax.lax.all_gather(gslot, doc_axes, axis=1, tiled=True)
            best_v, pos = jax.lax.top_k(vals_g, min(topk, vals_g.shape[1]))
            best_s = jnp.take_along_axis(slot_g, pos, axis=1)
            return best_v, best_s

        return body

    def _specs(self, topk: int | None):
        doc = self.doc_axes if len(self.doc_axes) > 1 else self.doc_axes[0]
        arena_spec = P(self.row_axis, doc)
        in_specs = (arena_spec, P(), P(), P(), P())
        if topk is None:
            out_specs = P(None, doc)
        else:
            out_specs = (P(), P())
        return in_specs, out_specs

    def score_fn(self):
        """jit'd (terms [Q, L, 2], n_valid [Q]) -> scores [Q, n_slots]
        (slot order, sharded over the doc axes)."""
        if self._score_jit is None:
            body = self._shard_body(topk=None)
            in_specs, out_specs = self._specs(None)
            fn = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
            self._score_jit = jax.jit(fn)
        return self._score_jit

    def topk_fn(self, topk: int):
        """jit'd (terms, n_valid) -> (scores [Q, topk], slots [Q, topk])."""
        if topk not in self._topk_jit:
            body = self._shard_body(topk=topk)
            in_specs, out_specs = self._specs(topk)
            fn = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
            self._topk_jit[topk] = jax.jit(fn)
        return self._topk_jit[topk]

    # ------------------------------------------------------------------
    def search_batch(self, patterns: list, threshold: float = 0.8,
                     topk: int = 32, term_pad: int = 64):
        """Host-level batched search mirroring QueryEngine.search_batch but
        running the sharded engine; returns per-query (doc_ids, scores)."""
        term_sets = []
        for p in patterns:
            codes = dna.encode_dna(p) if isinstance(p, str) else p
            term_sets.append(dna.unique_terms(
                dna.pack_kmers(codes, self.params.kmer, self.params.canonical)))
        ells = np.array([t.shape[0] for t in term_sets], dtype=np.int32)
        pad = max(term_pad, ((int(ells.max(initial=1)) + term_pad - 1)
                             // term_pad) * term_pad)
        buf = np.zeros((len(patterns), pad, 2), dtype=np.uint32)
        for i, t in enumerate(term_sets):
            buf[i, :t.shape[0]] = t
        vals, slots = self.topk_fn(topk)(
            self.arena, self.row_offset, self.block_width,
            jnp.asarray(buf), jnp.asarray(ells))
        vals, slots = np.asarray(vals), np.asarray(slots)
        out = []
        for i, ell in enumerate(ells):
            cut = max(1, math.ceil(threshold * int(ell)))
            ids = self.slot_doc[slots[i]]
            keep = (vals[i] >= cut) & (ids >= 0)
            out.append((ids[keep].astype(np.int32), vals[i][keep]))
        return out

    def scores_for(self, terms: np.ndarray, term_pad: int = 64) -> np.ndarray:
        """Full score vector in ORIGINAL document order (test/oracle path)."""
        L = terms.shape[0]
        pad = max(term_pad, ((L + term_pad - 1) // term_pad) * term_pad)
        buf = np.zeros((1, pad, 2), dtype=np.uint32)
        buf[0, :L] = terms
        slots = self.score_fn()(self.arena, self.row_offset, self.block_width,
                                jnp.asarray(buf),
                                jnp.asarray([L], dtype=np.int32))
        return np.asarray(slots)[0][self._flat_doc_slot]
