"""Straggler mitigation: hedged query execution over replicated shards.

Queries against a sharded signature index are stateless scans, which makes
the classic 'hedged request' policy (Dean & Barroso, 'The Tail at Scale')
directly applicable: issue to the primary replica; if no completion within
the hedge deadline (e.g. p95 latency), issue a backup request to the next
replica and take whichever finishes first.

The executor drives BOTH pure simulation and the serving frontend's real
dispatch path:

* ``run_query(query_id, replicas)`` — simulation only: per-attempt latency
  comes from the ``ShardSim`` latency model of the chosen node (injected
  clock, fully deterministic; the original surface).
* ``run(query_id, replicas, call)`` — real dispatch: ``call(node)``
  actually executes the work (a ShardWorker scoring a shard) and returns
  its result. Latency per attempt still comes from the node's ShardSim
  model when one is registered (deterministic tests/benchmarks) and from
  the wall clock otherwise (production). An attempt whose ``call`` raises
  ``AttemptFailed`` is treated as a dead replica and the executor fails
  over to the next one.

  In SYNCHRONOUS call mode hedges are only issued against backup nodes
  that HAVE a latency model: in-process calls are synchronous, so once a
  wall-clock primary has returned, duplicating the work on a replica can
  never finish earlier — pure wall-clock mode therefore applies failover
  but no backup requests.
* ``run_async(query_id, replicas, begin, cancel)`` — the real-world
  hedging seam: ``begin(node)`` launches the attempt and returns a
  Future (an RPC in flight), so hedged backups are genuine duplicate
  requests fired on the wall clock. The first success wins; every still
  outstanding loser is cancelled through ``cancel(node, future)`` (on
  the RPC plane that sends a CANCEL frame the worker observes between
  shard tiles). A future failing with ``AttemptFailed`` triggers
  failover to the next untried replica.

Tail-latency statistics plus hedge-fire/-win/-cancel and failover
counters are recorded so benchmarks can show the p99 win and the serving
metrics can export them. ``failovers`` counts only at-call-time failures
(a replica that died under an actual attempt); replicas already known
dead are filtered up front and counted separately as ``skipped_dead`` —
a permanently dead primary must not inflate the failover rate.
"""
from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, wait
from dataclasses import dataclass, field
from typing import Callable, Optional


class AttemptFailed(Exception):
    """Raised by a dispatch ``call`` to signal a dead/unreachable replica."""


class AllReplicasFailed(RuntimeError):
    """Every replica of a dispatch target is down — the caller's failure
    domain (distinct type so serving code can tell replica loss apart from
    unrelated runtime errors, e.g. a kernel crash)."""


class SimClock:
    """Deterministic event clock for tests/benchmarks."""

    def __init__(self):
        self.now = 0.0

    def advance(self, dt: float):
        self.now += dt


@dataclass
class ShardSim:
    """Latency model of one shard/node: base latency plus optional
    per-window straggle injected by tests."""
    name: str
    base_latency: float = 1.0
    straggle_until: float = -1.0
    straggle_factor: float = 10.0
    failed: bool = False

    def latency(self, now: float) -> float | None:
        if self.failed:
            return None
        if now < self.straggle_until:
            return self.base_latency * self.straggle_factor
        return self.base_latency


@dataclass
class _Attempt:
    done_at: float
    shard: str
    query_id: int
    hedged: bool
    result: object = None

    def __lt__(self, other: "_Attempt") -> bool:
        return self.done_at < other.done_at


@dataclass
class HedgedExecutor:
    """Executes shard requests with hedging + failover.

    shards: node name -> ShardSim latency model. In real-dispatch mode a
        node without a model is timed on the wall clock instead.
    replicas: query placement ranking, e.g. ShardPlacement.replicas
    hedge_after: backup request deadline (same unit as ShardSim latency /
        seconds in wall-clock mode)
    """
    shards: dict[str, ShardSim]
    hedge_after: float = 2.0
    max_hedges: int = 1
    clock: SimClock = field(default_factory=SimClock)
    # bounded history for the percentile stats (a long-lived frontend would
    # otherwise grow this forever); the integer counters stay exact totals
    completions: "deque[tuple[int, str, float, bool]]" = field(
        default_factory=lambda: deque(maxlen=65536))
    hedges_fired: int = 0
    hedges_won: int = 0
    hedges_cancelled: int = 0
    failovers: int = 0
    skipped_dead: int = 0
    # run_async executes from concurrent scatter threads; the counter
    # read-modify-writes go through this lock (the synchronous paths
    # are single-threaded by contract and skip it)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    # -- dispatch ------------------------------------------------------------
    def run_query(self, query_id: int, replicas: list[str]
                  ) -> tuple[str, float]:
        """Pure simulation: returns (serving_shard, completion_latency).
        Raises if every replica is failed."""
        shard, latency, _ = self._run(query_id, replicas, call=None)
        return shard, latency

    def run(self, query_id: int, replicas: list[str],
            call: Callable[[str], object]) -> tuple[str, float, object]:
        """Real dispatch: executes ``call(node)`` per attempt and returns
        (serving_node, completion_latency, result) of the winning attempt.
        Hedge/failover policy is identical to the simulation."""
        return self._run(query_id, replicas, call=call)

    def run_async(self, query_id: int, replicas: list[str],
                  begin: Callable[[str], Future],
                  cancel: Optional[Callable[[str, Future], None]] = None
                  ) -> tuple[str, float, object]:
        """Asynchronous dispatch over futures: ``begin(node)`` launches
        the attempt (an RPC in flight) and the executor hedges on the
        WALL clock — a backup fires ``hedge_after`` seconds after the
        previous attempt if nothing has completed, as a real duplicate
        request. First success wins; outstanding losers are cancelled
        via ``cancel(node, future)`` and counted in ``hedges_cancelled``.

        ``begin`` raising ``AttemptFailed`` (known-unreachable channel)
        or a future resolving to ``AttemptFailed`` fails over to the next
        untried replica. Returns (winning_node, latency_s, result)."""
        start = time.perf_counter()
        live = [r for r in replicas
                if not (r in self.shards and self.shards[r].failed)]
        with self._lock:
            self.skipped_dead += len(replicas) - len(live)
        # replicas not yet attempted, in placement-ranking order
        untried = deque(live)
        pending: dict[Future, tuple[str, bool]] = {}

        def issue(hedged: bool) -> bool:
            """Launch the next untried replica; False when exhausted.
            A begin() that refuses synchronously counts as a failover
            (it was this attempt's turn) and the walk continues."""
            while untried:
                node = untried.popleft()
                try:
                    fut = begin(node)
                except AttemptFailed:
                    with self._lock:
                        self.failovers += 1
                    continue
                pending[fut] = (node, hedged)
                return True
            return False

        if not issue(hedged=False):
            raise AllReplicasFailed(
                f"query {query_id}: all replicas failed")

        hedges_issued = 0
        next_hedge_at = start + self.hedge_after
        winner: Optional[tuple[str, bool, object]] = None
        error: Optional[BaseException] = None
        try:
            while pending:
                timeout = None
                if hedges_issued < self.max_hedges and untried:
                    timeout = max(0.0, next_hedge_at - time.perf_counter())
                done, _ = wait(list(pending), timeout=timeout,
                               return_when=FIRST_COMPLETED)
                if not done:
                    # hedge deadline passed with every attempt still in
                    # flight: fire a real duplicate request at the next
                    # untried replica
                    if issue(hedged=True):
                        with self._lock:
                            self.hedges_fired += 1
                    hedges_issued += 1
                    next_hedge_at += self.hedge_after
                    continue
                for fut in done:
                    node, hedged = pending.pop(fut)
                    exc = fut.exception()
                    if exc is None:
                        winner = (node, hedged, fut.result())
                        break
                    if not isinstance(exc, AttemptFailed):
                        error = exc           # not a replica death
                        break
                    with self._lock:
                        self.failovers += 1
                if winner is not None or error is not None:
                    break
                if not pending and not issue(hedged=False):
                    raise AllReplicasFailed(
                        f"query {query_id}: all replicas failed")
        finally:
            # cancel the losers (or everything, on an unexpected error)
            for fut, (node, hedged) in pending.items():
                fut.cancel()
                if cancel is not None:
                    try:
                        cancel(node, fut)
                    except Exception:
                        pass
                if winner is not None:
                    with self._lock:
                        self.hedges_cancelled += 1
        if error is not None:
            raise error
        if winner is None:
            raise AllReplicasFailed(
                f"query {query_id}: all replicas failed")
        node, hedged, result = winner
        latency = time.perf_counter() - start
        if hedged:
            with self._lock:
                self.hedges_won += 1
        self.completions.append((query_id, node, latency, hedged))
        return node, latency, result

    def _attempt_latency(self, node: str, at: float,
                         call: Optional[Callable[[str], object]]
                         ) -> tuple[float | None, object]:
        """(latency, result) of one attempt; latency None = replica dead.
        With a registered model the latency is simulated (the call, when
        present, still executes so the result is real); without one the
        call is timed on the wall clock."""
        model = self.shards.get(node)
        if model is not None:
            lat = model.latency(at)
            if lat is None:
                return None, None
            if call is None:
                return lat, None
            try:
                return lat, call(node)
            except AttemptFailed:
                return None, None
        if call is None:
            raise KeyError(f"no latency model for simulated node {node!r}")
        t0 = time.perf_counter()
        try:
            result = call(node)
        except AttemptFailed:
            return None, None
        return time.perf_counter() - t0, result

    def _run(self, query_id: int, replicas: list[str],
             call: Optional[Callable[[str], object]]
             ) -> tuple[str, float, object]:
        start = self.clock.now
        events: list[_Attempt] = []

        def issue(shard_name: str, at: float, hedged: bool) -> bool:
            lat, result = self._attempt_latency(shard_name, at, call)
            if lat is None:
                return False
            heapq.heappush(events, _Attempt(at + lat, shard_name, query_id,
                                            hedged, result))
            return True

        # known-dead replicas (model.failed) are skipped up front; a replica
        # that turns out dead at call time fails over to the next one here.
        live = [r for r in replicas
                if not (r in self.shards and self.shards[r].failed)]
        primary_i = 0
        while primary_i < len(live) and not issue(live[primary_i], start,
                                                  hedged=False):
            primary_i += 1
        # at-call-time deaths are failovers; replicas filtered as known
        # dead ahead of the serving primary are skips, not failovers — a
        # permanently dead primary must not inflate the failover rate
        self.failovers += primary_i
        if primary_i >= len(live):
            self.skipped_dead += len(replicas) - len(live)
            raise AllReplicasFailed(
                f"query {query_id}: all replicas failed")
        self.skipped_dead += replicas.index(live[primary_i]) - primary_i
        live = live[primary_i:]

        # replicas not yet attempted, in placement-ranking order: each
        # hedge walks to the NEXT untried node, so the budget is spent on
        # distinct backups and never wraps back onto an already-issued
        # attempt (the old modulo indexing burned budget on the primary
        # with 2 live replicas and max_hedges >= 2)
        untried = deque(live[1:])
        hedges_issued = 0
        next_hedge_at = start + self.hedge_after
        while events:
            attempt = events[0]
            # hedge fires before the fastest outstanding attempt completes?
            while (hedges_issued < self.max_hedges
                   and next_hedge_at < attempt.done_at
                   and untried):
                # only hedge nodes with a latency model: a synchronous
                # wall-clock backup finishes AFTER the already-returned
                # primary by construction — it could never win (see
                # module docstring), so skip it WITHOUT spending budget
                backup = None
                while untried:
                    cand = untried.popleft()
                    if call is None or cand in self.shards:
                        backup = cand
                        break
                if backup is None:
                    break
                if issue(backup, next_hedge_at, hedged=True):
                    self.hedges_fired += 1
                hedges_issued += 1
                next_hedge_at += self.hedge_after
                attempt = events[0]
            heapq.heappop(events)
            self.clock.now = max(self.clock.now, attempt.done_at)
            latency = attempt.done_at - start
            if attempt.hedged:
                self.hedges_won += 1
            self.completions.append((query_id, attempt.shard, latency,
                                     attempt.hedged))
            return attempt.shard, latency, attempt.result
        raise RuntimeError("no attempt completed")

    # -- statistics ----------------------------------------------------------
    def latencies(self) -> list[float]:
        return [c[2] for c in self.completions]

    def hedged_fraction(self) -> float:
        if not self.completions:
            return 0.0
        return sum(1 for c in self.completions if c[3]) / len(self.completions)

    def percentile(self, q: float) -> float:
        ls = sorted(self.latencies())
        if not ls:
            return 0.0
        i = min(len(ls) - 1, int(q * len(ls)))
        return ls[i]
