"""Straggler mitigation: hedged query execution over replicated shards.

Queries against a sharded signature index are stateless scans, which makes
the classic 'hedged request' policy (Dean & Barroso, 'The Tail at Scale')
directly applicable: issue to the primary replica; if no completion within
the hedge deadline (e.g. p95 latency), issue a backup request to the next
replica and take whichever finishes first.

The executor drives BOTH pure simulation and the serving frontend's real
dispatch path:

* ``run_query(query_id, replicas)`` — simulation only: per-attempt latency
  comes from the ``ShardSim`` latency model of the chosen node (injected
  clock, fully deterministic; the original surface).
* ``run(query_id, replicas, call)`` — real dispatch: ``call(node)``
  actually executes the work (a ShardWorker scoring a shard) and returns
  its result. Latency per attempt still comes from the node's ShardSim
  model when one is registered (deterministic tests/benchmarks) and from
  the wall clock otherwise (production). An attempt whose ``call`` raises
  ``AttemptFailed`` is treated as a dead replica and the executor fails
  over to the next one.

  Hedges are only issued against backup nodes that HAVE a latency model:
  in-process calls are synchronous, so once a wall-clock primary has
  returned, duplicating the work on a replica can never finish earlier —
  pure wall-clock mode therefore applies failover but no backup requests
  (an async transport is the seam where real-world hedging plugs in;
  until then hedging semantics live in the simulated-latency mode).

Tail-latency statistics plus hedge-fire/failover counters are recorded so
benchmarks can show the p99 win and the serving metrics can export them.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


class AttemptFailed(Exception):
    """Raised by a dispatch ``call`` to signal a dead/unreachable replica."""


class AllReplicasFailed(RuntimeError):
    """Every replica of a dispatch target is down — the caller's failure
    domain (distinct type so serving code can tell replica loss apart from
    unrelated runtime errors, e.g. a kernel crash)."""


class SimClock:
    """Deterministic event clock for tests/benchmarks."""

    def __init__(self):
        self.now = 0.0

    def advance(self, dt: float):
        self.now += dt


@dataclass
class ShardSim:
    """Latency model of one shard/node: base latency plus optional
    per-window straggle injected by tests."""
    name: str
    base_latency: float = 1.0
    straggle_until: float = -1.0
    straggle_factor: float = 10.0
    failed: bool = False

    def latency(self, now: float) -> float | None:
        if self.failed:
            return None
        if now < self.straggle_until:
            return self.base_latency * self.straggle_factor
        return self.base_latency


@dataclass
class _Attempt:
    done_at: float
    shard: str
    query_id: int
    hedged: bool
    result: object = None

    def __lt__(self, other: "_Attempt") -> bool:
        return self.done_at < other.done_at


@dataclass
class HedgedExecutor:
    """Executes shard requests with hedging + failover.

    shards: node name -> ShardSim latency model. In real-dispatch mode a
        node without a model is timed on the wall clock instead.
    replicas: query placement ranking, e.g. ShardPlacement.replicas
    hedge_after: backup request deadline (same unit as ShardSim latency /
        seconds in wall-clock mode)
    """
    shards: dict[str, ShardSim]
    hedge_after: float = 2.0
    max_hedges: int = 1
    clock: SimClock = field(default_factory=SimClock)
    # bounded history for the percentile stats (a long-lived frontend would
    # otherwise grow this forever); the integer counters stay exact totals
    completions: "deque[tuple[int, str, float, bool]]" = field(
        default_factory=lambda: deque(maxlen=65536))
    hedges_fired: int = 0
    hedges_won: int = 0
    failovers: int = 0

    # -- dispatch ------------------------------------------------------------
    def run_query(self, query_id: int, replicas: list[str]
                  ) -> tuple[str, float]:
        """Pure simulation: returns (serving_shard, completion_latency).
        Raises if every replica is failed."""
        shard, latency, _ = self._run(query_id, replicas, call=None)
        return shard, latency

    def run(self, query_id: int, replicas: list[str],
            call: Callable[[str], object]) -> tuple[str, float, object]:
        """Real dispatch: executes ``call(node)`` per attempt and returns
        (serving_node, completion_latency, result) of the winning attempt.
        Hedge/failover policy is identical to the simulation."""
        return self._run(query_id, replicas, call=call)

    def _attempt_latency(self, node: str, at: float,
                         call: Optional[Callable[[str], object]]
                         ) -> tuple[float | None, object]:
        """(latency, result) of one attempt; latency None = replica dead.
        With a registered model the latency is simulated (the call, when
        present, still executes so the result is real); without one the
        call is timed on the wall clock."""
        model = self.shards.get(node)
        if model is not None:
            lat = model.latency(at)
            if lat is None:
                return None, None
            if call is None:
                return lat, None
            try:
                return lat, call(node)
            except AttemptFailed:
                return None, None
        if call is None:
            raise KeyError(f"no latency model for simulated node {node!r}")
        t0 = time.perf_counter()
        try:
            result = call(node)
        except AttemptFailed:
            return None, None
        return time.perf_counter() - t0, result

    def _run(self, query_id: int, replicas: list[str],
             call: Optional[Callable[[str], object]]
             ) -> tuple[str, float, object]:
        start = self.clock.now
        events: list[_Attempt] = []

        def issue(shard_name: str, at: float, hedged: bool) -> bool:
            lat, result = self._attempt_latency(shard_name, at, call)
            if lat is None:
                return False
            heapq.heappush(events, _Attempt(at + lat, shard_name, query_id,
                                            hedged, result))
            return True

        # known-dead replicas (model.failed) are skipped up front; a replica
        # that turns out dead at call time fails over to the next one here.
        live = [r for r in replicas
                if not (r in self.shards and self.shards[r].failed)]
        primary_i = 0
        while primary_i < len(live) and not issue(live[primary_i], start,
                                                  hedged=False):
            primary_i += 1
        if primary_i >= len(live):
            raise AllReplicasFailed(
                f"query {query_id}: all replicas failed")
        # how far down the preference ranking the primary had to move
        self.failovers += replicas.index(live[primary_i])
        live = live[primary_i:]

        hedges_issued = 0
        next_hedge_at = start + self.hedge_after
        while events:
            attempt = events[0]
            # hedge fires before the fastest outstanding attempt completes?
            while (hedges_issued < self.max_hedges
                   and next_hedge_at < attempt.done_at
                   and hedges_issued + 1 < len(live) + 1):
                backup = live[(hedges_issued + 1) % len(live)]
                # only hedge nodes with a latency model: a synchronous
                # wall-clock backup finishes AFTER the already-returned
                # primary by construction — it could never win (see
                # module docstring), so issuing it is pure waste
                if ((backup != attempt.shard or len(live) == 1)
                        and (call is None or backup in self.shards)):
                    if issue(backup, next_hedge_at, hedged=True):
                        self.hedges_fired += 1
                hedges_issued += 1
                next_hedge_at += self.hedge_after
                attempt = events[0]
            heapq.heappop(events)
            self.clock.now = max(self.clock.now, attempt.done_at)
            latency = attempt.done_at - start
            if attempt.hedged:
                self.hedges_won += 1
            self.completions.append((query_id, attempt.shard, latency,
                                     attempt.hedged))
            return attempt.shard, latency, attempt.result
        raise RuntimeError("no attempt completed")

    # -- statistics ----------------------------------------------------------
    def latencies(self) -> list[float]:
        return [c[2] for c in self.completions]

    def hedged_fraction(self) -> float:
        if not self.completions:
            return 0.0
        return sum(1 for c in self.completions if c[3]) / len(self.completions)

    def percentile(self, q: float) -> float:
        ls = sorted(self.latencies())
        if not ls:
            return 0.0
        i = min(len(ls) - 1, int(q * len(ls)))
        return ls[i]
