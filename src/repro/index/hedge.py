"""Straggler mitigation: hedged query execution over replicated shards.

Queries against a sharded signature index are stateless scans, which makes
the classic 'hedged request' policy (Dean & Barroso, 'The Tail at Scale')
directly applicable: issue to the primary replica; if no completion within
the hedge deadline (e.g. p95 latency), issue a backup request to the next
replica and take whichever finishes first.

The executor is written against an injected clock + shard-latency model so
the policy is unit-testable and deterministic on one host; on a real
deployment the same class drives per-pod RPCs. Tail-latency statistics are
recorded so benchmarks can show the p99 win.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field


class SimClock:
    """Deterministic event clock for tests/benchmarks."""

    def __init__(self):
        self.now = 0.0

    def advance(self, dt: float):
        self.now += dt


@dataclass
class ShardSim:
    """Latency model of one shard/node: base latency plus optional
    per-window straggle injected by tests."""
    name: str
    base_latency: float = 1.0
    straggle_until: float = -1.0
    straggle_factor: float = 10.0
    failed: bool = False

    def latency(self, now: float) -> float | None:
        if self.failed:
            return None
        if now < self.straggle_until:
            return self.base_latency * self.straggle_factor
        return self.base_latency


@dataclass
class _Attempt:
    done_at: float
    shard: str
    query_id: int
    hedged: bool


@dataclass
class HedgedExecutor:
    """Executes (simulated) shard requests with hedging + failover.

    shards: name -> ShardSim
    replicas_of: query placement, e.g. BlockPlacement.replicas
    hedge_after: backup request deadline (same unit as ShardSim latency)
    """
    shards: dict[str, ShardSim]
    hedge_after: float = 2.0
    max_hedges: int = 1
    clock: SimClock = field(default_factory=SimClock)
    completions: list[tuple[int, str, float, bool]] = field(default_factory=list)

    def run_query(self, query_id: int, replicas: list[str]) -> tuple[str, float]:
        """Returns (serving_shard, completion_latency). Raises if every
        replica is failed."""
        start = self.clock.now
        events: list[tuple[float, _Attempt]] = []

        def issue(shard_name: str, at: float, hedged: bool) -> bool:
            lat = self.shards[shard_name].latency(at)
            if lat is None:
                return False
            a = _Attempt(at + lat, shard_name, query_id, hedged)
            heapq.heappush(events, (a.done_at, a))
            return True

        live = [r for r in replicas if not self.shards[r].failed]
        if not live:
            raise RuntimeError(f"query {query_id}: all replicas failed")
        issue(live[0], start, hedged=False)

        hedges_issued = 0
        next_hedge_at = start + self.hedge_after
        while events:
            done_at, attempt = events[0]
            # hedge fires before the fastest outstanding attempt completes?
            while (hedges_issued < self.max_hedges
                   and next_hedge_at < done_at
                   and hedges_issued + 1 < len(live) + 1):
                backup = live[(hedges_issued + 1) % len(live)]
                if backup != attempt.shard or len(live) == 1:
                    issue(backup, next_hedge_at, hedged=True)
                hedges_issued += 1
                next_hedge_at += self.hedge_after
                done_at, attempt = events[0]
            heapq.heappop(events)
            self.clock.now = max(self.clock.now, attempt.done_at)
            latency = attempt.done_at - start
            self.completions.append((query_id, attempt.shard, latency,
                                     attempt.hedged))
            return attempt.shard, latency
        raise RuntimeError("no attempt completed")

    # -- statistics ----------------------------------------------------------
    def latencies(self) -> list[float]:
        return [c[2] for c in self.completions]

    def hedged_fraction(self) -> float:
        if not self.completions:
            return 0.0
        return sum(1 for c in self.completions if c[3]) / len(self.completions)

    def percentile(self, q: float) -> float:
        ls = sorted(self.latencies())
        if not ls:
            return 0.0
        i = min(len(ls) - 1, int(q * len(ls)))
        return ls[i]
