"""Parallel / fault-tolerant compact-index construction.

The paper parallelizes compact construction over sub-indexes ('for compact
index construction we parallelized construction of the subindices'). Blocks
are independent, so we (1) build them in a worker pool, (2) checkpoint each
finished block to disk, and (3) on restart resume from the completed-block
manifest — a node loss during a 100k-document build costs only the blocks
in flight, not hours of work.
"""
from __future__ import annotations

import json
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from ..core import bloom, theory
from ..core.index import BitSlicedIndex, IndexParams, _pad32


def build_compact_parallel(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    block_docs: int = 1024,
    row_align: int = bloom.ROW_ALIGN,
    workers: int = 4,
    checkpoint_dir: str | Path | None = None,
) -> BitSlicedIndex:
    """Semantically identical to core.build_compact (bit-exact output —
    asserted in tests), built block-parallel with optional per-block
    checkpoint/restart."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    block_docs = _pad32(block_docs)
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    order = np.argsort(counts, kind="stable")
    doc_slot = np.empty(n_docs, dtype=np.int32)
    doc_slot[order] = np.arange(n_docs, dtype=np.int32)
    n_blocks = (n_docs + block_docs - 1) // block_docs

    widths = []
    for b in range(n_blocks):
        ids = order[b * block_docs:(b + 1) * block_docs]
        v_max = int(counts[ids].max()) if ids.size else 0
        widths.append(bloom.aligned_width(
            theory.bloom_size(max(v_max, 1), params.fpr, params.n_hashes),
            row_align))

    ckpt = Path(checkpoint_dir) if checkpoint_dir else None
    done: dict[int, np.ndarray] = {}
    if ckpt is not None:
        ckpt.mkdir(parents=True, exist_ok=True)
        manifest = ckpt / "blocks.json"
        if manifest.exists():
            for b in json.loads(manifest.read_text()).get("done", []):
                f = ckpt / f"block{b:06d}.npy"
                if f.exists():
                    done[int(b)] = np.load(f)

    def build_one(b: int) -> tuple[int, np.ndarray]:
        if b in done:
            return b, done[b]
        ids = order[b * block_docs:(b + 1) * block_docs]
        m = bloom.build_block_matrix([doc_terms[i] for i in ids], widths[b],
                                     params.n_hashes, block_docs)
        if ckpt is not None:
            np.save(ckpt / f"block{b:06d}.npy", m)
        return b, m

    results: dict[int, np.ndarray] = {}
    if workers <= 1:
        for b in range(n_blocks):
            results.update([build_one(b)])
    else:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            for b, m in pool.map(build_one, range(n_blocks)):
                results[b] = m
                if ckpt is not None:
                    (ckpt / "blocks.json").write_text(
                        json.dumps({"done": sorted(results.keys())}))

    offsets = np.concatenate([[0], np.cumsum(widths)[:-1]]).astype(np.int32)
    return BitSlicedIndex(
        arena=jnp.asarray(np.concatenate([results[b] for b in range(n_blocks)],
                                         axis=0)),
        row_offset=jnp.asarray(offsets),
        block_width=jnp.asarray(np.array(widths, dtype=np.int32)),
        doc_slot=jnp.asarray(doc_slot),
        doc_n_terms=jnp.asarray(counts.astype(np.int32)),
        block_docs=block_docs,
        n_docs=n_docs,
        params=params,
    )
