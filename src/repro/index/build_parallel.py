"""Parallel / fault-tolerant / out-of-core compact-index construction.

The paper parallelizes compact construction over sub-indexes ('for compact
index construction we parallelized construction of the subindices'). Blocks
are independent, so we (1) build them in a worker pool, (2) checkpoint each
finished block to disk, and (3) on restart resume from the completed-block
manifest — a node loss during a 100k-document build costs only the blocks
in flight, not hours of work.

``build_compact_streaming`` is the out-of-core variant: finished block
groups are written straight to a cobs-jax-v2 shard store (repro.core.store)
and dropped from host memory, so peak host usage is one block group — the
full arena is never concatenated anywhere. The returned index is backed by
an np.memmap MappedArena over the store just written, and resuming an
interrupted build skips every shard already on disk.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from ..core import bloom
from ..core.arena import DeviceArena
from ..core.index import BitSlicedIndex, IndexParams, plan_compact_layout
from ..core.store import ShardStoreWriter, load_index_v2


def build_compact_parallel(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    block_docs: int = 1024,
    row_align: int = bloom.ROW_ALIGN,
    workers: int = 4,
    checkpoint_dir: str | Path | None = None,
) -> BitSlicedIndex:
    """Semantically identical to core.build_compact (bit-exact output —
    asserted in tests), built block-parallel with optional per-block
    checkpoint/restart."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    layout, order = plan_compact_layout(counts, params, block_docs, row_align)
    block_docs = layout.block_docs
    n_blocks = layout.n_blocks

    ckpt = Path(checkpoint_dir) if checkpoint_dir else None
    done: dict[int, np.ndarray] = {}
    if ckpt is not None:
        ckpt.mkdir(parents=True, exist_ok=True)
        manifest = ckpt / "blocks.json"
        if manifest.exists():
            for b in json.loads(manifest.read_text()).get("done", []):
                f = ckpt / f"block{b:06d}.npy"
                if f.exists():
                    done[int(b)] = np.load(f)

    def build_one(b: int) -> tuple[int, np.ndarray]:
        if b in done:
            return b, done[b]
        ids = order[b * block_docs:(b + 1) * block_docs]
        m = bloom.build_block_matrix(
            [doc_terms[i] for i in ids], int(layout.block_width[b]),
            params.n_hashes, block_docs)
        if ckpt is not None:
            np.save(ckpt / f"block{b:06d}.npy", m)
        return b, m

    def checkpoint_manifest(results: dict[int, np.ndarray]) -> None:
        if ckpt is not None:
            (ckpt / "blocks.json").write_text(
                json.dumps({"done": sorted(results.keys())}))

    results: dict[int, np.ndarray] = {}
    if workers <= 1:
        for b in range(n_blocks):
            results.update([build_one(b)])
            checkpoint_manifest(results)
    else:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            for b, m in pool.map(build_one, range(n_blocks)):
                results[b] = m
                checkpoint_manifest(results)

    return BitSlicedIndex(
        layout=layout,
        storage=DeviceArena(jnp.asarray(
            np.concatenate([results[b] for b in range(n_blocks)], axis=0))),
        params=params,
    )


@dataclasses.dataclass
class StreamingBuildStats:
    """Host-memory accounting for a streaming build (the out-of-core
    acceptance evidence): ``peak_block_bytes`` is the high-water mark of
    simultaneously-live block-group matrices inside the builder, and
    ``max_shard_bytes``/``total_arena_bytes`` give the shard-size
    arithmetic it must stay proportional to. ``comp_bytes``/``comp_ratio``
    record the store's on-disk compression (1.0 for raw builds)."""
    n_shards: int
    n_resumed: int
    max_shard_bytes: int
    total_arena_bytes: int
    peak_block_bytes: int
    comp_bytes: int = 0
    comp_ratio: float = 1.0
    n_compressed_shards: int = 0


def build_compact_streaming(
    doc_terms: list[np.ndarray],
    store_path: str | Path,
    params: IndexParams = IndexParams(),
    block_docs: int = 1024,
    row_align: int = bloom.ROW_ALIGN,
    blocks_per_shard: int = 1,
    workers: int = 1,
    codec: str = "raw",
) -> tuple[BitSlicedIndex, StreamingBuildStats]:
    """Build a compact index straight into a cobs-jax-v2 store.

    Bit-identical to ``core.build_compact`` (same plan_compact_layout, same
    block matrices) but never holds more than ``workers`` block groups in
    host memory: each finished group is written as one shard and released.
    Shards already present in ``store_path`` (from an interrupted run) are
    skipped. ``codec`` selects the per-shard tile codec ("auto" for
    smallest-wins; see repro.core.codec) — the opened index decodes
    bit-identically, the store just costs fewer bytes. Returns the
    mmap-backed index plus allocation accounting."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    layout, order = plan_compact_layout(counts, params, block_docs, row_align)
    writer = ShardStoreWriter(store_path, layout, params, blocks_per_shard,
                              codec=codec)

    lock = threading.Lock()
    live_bytes = 0
    peak_bytes = 0
    n_resumed = 0

    def account(delta: int) -> None:
        nonlocal live_bytes, peak_bytes
        with lock:
            live_bytes += delta
            peak_bytes = max(peak_bytes, live_bytes)

    def build_shard(s: int) -> None:
        nonlocal n_resumed
        if writer.have_shard(s):
            with lock:
                n_resumed += 1
            return
        b0, b1 = writer.shard_blocks(s)
        nbytes = writer.shard_shape(s)[0] * layout.doc_words * 4
        account(+nbytes)
        try:
            groups = []
            for b in range(b0, b1):
                ids = order[b * layout.block_docs:(b + 1) * layout.block_docs]
                groups.append(bloom.build_block_matrix(
                    [doc_terms[i] for i in ids], int(layout.block_width[b]),
                    params.n_hashes, layout.block_docs))
            matrix = groups[0] if len(groups) == 1 else \
                np.concatenate(groups, axis=0)
            writer.write_shard(s, matrix)
        finally:
            account(-nbytes)

    if workers <= 1:
        for s in range(writer.n_shards):
            build_shard(s)
    else:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            list(pool.map(build_shard, range(writer.n_shards)))
    writer.finalize()

    index = load_index_v2(store_path)
    shard_bytes = [index.storage.shard_nbytes(s)
                   for s in range(index.storage.n_shards)]
    raw_total, comp_total, n_comp = index.storage.comp_summary()
    stats = StreamingBuildStats(
        n_shards=writer.n_shards,
        n_resumed=n_resumed,
        max_shard_bytes=max(shard_bytes),
        total_arena_bytes=sum(shard_bytes),
        peak_block_bytes=peak_bytes,
        comp_bytes=comp_total,
        comp_ratio=round(raw_total / comp_total, 4) if comp_total else 1.0,
        n_compressed_shards=n_comp,
    )
    return index, stats
