"""Block -> node placement for fault tolerance and elastic scaling.

COBS' compact index is a concatenation of INDEPENDENT sub-indexes (paper
section 2.3) — the unit of distribution, recovery, and elasticity here is
therefore the block:

* placement uses rendezvous (highest-random-weight) hashing, so adding or
  removing a node moves only ~1/n of the blocks (elastic scaling);
* each block is placed on ``replication`` distinct nodes; node failure
  flips queries to the next-highest replica with zero data movement, and
  recovery rebuilds only the lost node's blocks (not the whole index).

This is host-side control-plane logic (pure python, deterministic), used by
the launcher to assign sub-indexes to pods/hosts; the data plane is
DistributedIndex.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field


def _weight(block_id: int, node: str) -> int:
    h = hashlib.blake2b(f"{block_id}:{node}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


@dataclass
class BlockPlacement:
    nodes: list[str]
    n_blocks: int
    replication: int = 2
    _down: set[str] = field(default_factory=set)

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("need at least one node")
        if self.replication < 1:
            raise ValueError("replication >= 1")
        self.nodes = list(dict.fromkeys(self.nodes))  # dedupe, keep order

    # -- placement ----------------------------------------------------------
    def replicas(self, block_id: int) -> list[str]:
        """All replica nodes for a block, preference order (HRW ranking)."""
        ranked = sorted(self.nodes, key=lambda n: _weight(block_id, n),
                        reverse=True)
        return ranked[: min(self.replication, len(ranked))]

    def owner(self, block_id: int) -> str:
        """Preferred LIVE node for a block (failover-aware)."""
        for n in self.replicas(block_id):
            if n not in self._down:
                return n
        raise RuntimeError(f"block {block_id}: all replicas down")

    def assignment(self) -> dict[str, list[int]]:
        """node -> blocks currently served (live owners only)."""
        out: dict[str, list[int]] = {n: [] for n in self.nodes
                                     if n not in self._down}
        for b in range(self.n_blocks):
            out[self.owner(b)].append(b)
        return out

    def is_covered(self) -> bool:
        """Every block has at least one live replica."""
        try:
            for b in range(self.n_blocks):
                self.owner(b)
            return True
        except RuntimeError:
            return False

    # -- failures -----------------------------------------------------------
    def fail(self, node: str) -> list[int]:
        """Mark node down; returns blocks whose PRIMARY moved (these flip to
        a replica — no rebuild needed while replication holds)."""
        if node not in self.nodes:
            raise KeyError(node)
        moved = [b for b in range(self.n_blocks) if self.owner(b) == node]
        self._down.add(node)
        return moved

    def recover(self, node: str) -> list[int]:
        """Node back up; returns blocks to restore onto it (rebuild/copy set
        = exactly its replica set, nothing else)."""
        self._down.discard(node)
        return [b for b in range(self.n_blocks) if node in self.replicas(b)]

    @property
    def live_nodes(self) -> list[str]:
        return [n for n in self.nodes if n not in self._down]

    # -- elasticity ---------------------------------------------------------
    def add_node(self, node: str) -> list[int]:
        """Scale up; returns blocks that must MOVE to the new node (HRW
        guarantees expected n_blocks * replication / (n+1))."""
        before = {b: set(self.replicas(b)) for b in range(self.n_blocks)}
        self.nodes.append(node)
        return [b for b in range(self.n_blocks)
                if set(self.replicas(b)) != before[b]]

    def remove_node(self, node: str) -> list[int]:
        """Scale down; returns blocks that must be re-homed."""
        if node not in self.nodes:
            raise KeyError(node)
        before = {b: set(self.replicas(b)) for b in range(self.n_blocks)}
        self.nodes.remove(node)
        self._down.discard(node)
        return [b for b in range(self.n_blocks)
                if set(self.replicas(b)) != before[b]]
