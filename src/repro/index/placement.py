"""Item -> node placement for fault tolerance and elastic scaling.

COBS' compact index is a concatenation of INDEPENDENT sub-indexes (paper
section 2.3) — the unit of distribution, recovery, and elasticity is
therefore an independent sub-range of the index. Two granularities exist:

* ``BlockPlacement`` — one Bloom-filter block per item (the original
  control-plane granularity, used with the mesh data plane in
  ``repro.index.distributed``);
* ``ShardPlacement`` — one cobs-jax-v2 *manifest row* (shard file) per
  item. Since the out-of-core refactor the shard is the on-disk placement
  unit: a host opens a sub-store view of exactly the shard files assigned
  to it (``repro.core.store.open_substore``) and serves them through a
  ``repro.serve.ShardWorker``.

Both use rendezvous (highest-random-weight) hashing, so adding or removing
a node moves only ~replication/n of the items (elastic scaling); each item
is placed on ``replication`` distinct nodes, node failure flips queries to
the next-highest replica with zero data movement, and recovery rebuilds
only the lost node's items (not the whole index).

This is host-side control-plane logic (pure python, deterministic), used
by the launcher to assign sub-indexes to pods/hosts; the data planes are
DistributedIndex (mesh) and the ShardWorker/Frontend pair (multi-host
serving).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from pathlib import Path


def _weight(item_id: int, node: str) -> int:
    h = hashlib.blake2b(f"{item_id}:{node}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


@dataclass
class RendezvousPlacement:
    """HRW placement of ``n_items`` integer-identified items over nodes."""

    nodes: list[str]
    n_items: int
    replication: int = 2
    _down: set[str] = field(default_factory=set)

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("need at least one node")
        if self.replication < 1:
            raise ValueError("replication >= 1")
        self.nodes = list(dict.fromkeys(self.nodes))  # dedupe, keep order

    # -- placement ----------------------------------------------------------
    def replicas(self, item_id: int) -> list[str]:
        """All replica nodes for an item, preference order (HRW ranking)."""
        ranked = sorted(self.nodes, key=lambda n: _weight(item_id, n),
                        reverse=True)
        return ranked[: min(self.replication, len(ranked))]

    def owner(self, item_id: int) -> str:
        """Preferred LIVE node for an item (failover-aware)."""
        for n in self.replicas(item_id):
            if n not in self._down:
                return n
        raise RuntimeError(f"item {item_id}: all replicas down")

    def assignment(self) -> dict[str, list[int]]:
        """node -> items currently served (live owners only)."""
        out: dict[str, list[int]] = {n: [] for n in self.nodes
                                     if n not in self._down}
        for b in range(self.n_items):
            out[self.owner(b)].append(b)
        return out

    def replica_assignment(self) -> dict[str, list[int]]:
        """node -> every item it REPLICATES (owner or backup). This is the
        set of shards a host must materialize to be able to take over as a
        failover/hedge target without data movement."""
        out: dict[str, list[int]] = {n: [] for n in self.nodes}
        for b in range(self.n_items):
            for n in self.replicas(b):
                out[n].append(b)
        return out

    def is_covered(self) -> bool:
        """Every item has at least one live replica."""
        try:
            for b in range(self.n_items):
                self.owner(b)
            return True
        except RuntimeError:
            return False

    # -- failures -----------------------------------------------------------
    def fail(self, node: str) -> list[int]:
        """Mark node down; returns items whose PRIMARY moved (these flip to
        a replica — no rebuild needed while replication holds)."""
        if node not in self.nodes:
            raise KeyError(node)
        moved = [b for b in range(self.n_items) if self.owner(b) == node]
        self._down.add(node)
        return moved

    def recover(self, node: str) -> list[int]:
        """Node back up; returns items to restore onto it (rebuild/copy set
        = exactly its replica set, nothing else)."""
        self._down.discard(node)
        return [b for b in range(self.n_items) if node in self.replicas(b)]

    @property
    def live_nodes(self) -> list[str]:
        return [n for n in self.nodes if n not in self._down]

    # -- elasticity ---------------------------------------------------------
    def add_node(self, node: str) -> list[int]:
        """Scale up; returns items that must MOVE to the new node (HRW
        guarantees expected n_items * replication / (n+1))."""
        before = {b: set(self.replicas(b)) for b in range(self.n_items)}
        self.nodes.append(node)
        return [b for b in range(self.n_items)
                if set(self.replicas(b)) != before[b]]

    def remove_node(self, node: str) -> list[int]:
        """Scale down; returns items that must be re-homed."""
        if node not in self.nodes:
            raise KeyError(node)
        before = {b: set(self.replicas(b)) for b in range(self.n_items)}
        self.nodes.remove(node)
        self._down.discard(node)
        return [b for b in range(self.n_items)
                if set(self.replicas(b)) != before[b]]


class BlockPlacement(RendezvousPlacement):
    """HRW placement at Bloom-filter-block granularity (legacy surface)."""

    def __init__(self, nodes: list[str], n_blocks: int, replication: int = 2):
        super().__init__(nodes, n_blocks, replication)

    @property
    def n_blocks(self) -> int:
        return self.n_items


class ShardPlacement(RendezvousPlacement):
    """HRW placement of cobs-jax-v2 manifest rows (shard files) over hosts.

    The shard is the multi-host serving unit: ``replica_assignment()[h]``
    is exactly the shard subset host ``h`` opens via ``open_substore``, and
    ``owner``/``replicas`` drive the frontend's scatter and hedged-failover
    dispatch.
    """

    def __init__(self, nodes: list[str], n_shards: int, replication: int = 2):
        super().__init__(nodes, n_shards, replication)

    @property
    def n_shards(self) -> int:
        return self.n_items

    @classmethod
    def for_store(cls, path, nodes: list[str],
                  replication: int = 2) -> "ShardPlacement":
        """Placement over the manifest rows of a v2 store directory."""
        import json

        from ..core.store import FORMAT_V2
        manifest = json.loads((Path(path) / "manifest.json").read_text())
        if manifest.get("format") != FORMAT_V2:
            raise ValueError(f"not a {FORMAT_V2} store: {path}")
        return cls(nodes, len(manifest["shards"]), replication)
