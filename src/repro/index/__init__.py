"""Distributed index runtime: mesh-sharded COBS, placement, straggler
mitigation, elastic scaling — the paper's 'future work: distributed index
construction and query processing', built on shard_map + lax collectives."""
from .distributed import DistributedIndex
from .placement import BlockPlacement, RendezvousPlacement, ShardPlacement
from .hedge import AttemptFailed, HedgedExecutor, SimClock, ShardSim
from .build_parallel import (StreamingBuildStats, build_compact_parallel,
                             build_compact_streaming)

__all__ = ["DistributedIndex", "BlockPlacement", "RendezvousPlacement",
           "ShardPlacement", "AttemptFailed", "HedgedExecutor", "SimClock",
           "ShardSim", "StreamingBuildStats", "build_compact_parallel",
           "build_compact_streaming"]
