"""qwen2.5-3b [dense]: GQA with QKV bias. 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936. [hf:Qwen/Qwen2.5-0.5B; hf]

Full attention -> long_500k skipped.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab=151_936,
        family="dense",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        family="dense",
        qkv_bias=True,
        tie_embeddings=True,
    )
