"""xlstm-125m [ssm]: sLSTM + mLSTM blocks. 12L d_model=768 4H d_ff=0
vocab=50304. [arXiv:2405.04517; unverified]

12 layers = 6 xunit composites (mlstm, slstm alternating). d_ff=0: no
separate FFN — the projection factors live inside the blocks. Linear
recurrence -> runs long_500k.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab=50_304,
        block_pattern=(("xunit", 6),),
        family="ssm",
        tie_embeddings=True,
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab=512,
        block_pattern=(("xunit", 2),),
        family="ssm",
        tie_embeddings=True,
        sub_quadratic=True,
    )
