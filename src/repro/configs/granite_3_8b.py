"""granite-3-8b [dense]: GQA. 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]

Full attention -> long_500k skipped.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab=49_155,
        family="dense",
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        family="dense",
        tie_embeddings=True,
    )
