"""qwen3-moe-30b-a3b [moe]: 128 experts top-8. 48L d_model=2048 32H
(GQA kv=4) d_ff=768 (per expert) vocab=151936. [hf:Qwen/Qwen3-30B-A3B; hf]

Expert parallelism: 128 experts shard 8-per-chip over the model axis.
Full attention -> long_500k skipped.
"""
from repro.models import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab=151_936,
        block_pattern=(("moe", 48),),
        family="moe",
        qk_norm=True,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab=512,
        block_pattern=(("moe", 2),),
        family="moe",
        qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0),
    )
