"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2 recurrent : 1
local-attn (Griffin pattern), 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000. [arXiv:2402.19427; hf]

26 layers = 8 griffin units (rglru, rglru, local) + 2 trailing rglru.
Sub-quadratic (window 2048 + linear recurrence) -> runs long_500k.
10 heads do not divide the model axis (16); the sharding rule engine
falls back to head_dim/replicated sharding for attention tensors.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256_000,
        block_pattern=(("griffin", 8), ("rglru", 2)),
        family="hybrid",
        window=2048,
        logits_softcap=30.0,
        tie_embeddings=True,
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        n_layers=5,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab=512,
        block_pattern=(("griffin", 1), ("rglru", 2)),
        family="hybrid",
        window=16,
        logits_softcap=30.0,
        tie_embeddings=True,
        sub_quadratic=True,
    )
