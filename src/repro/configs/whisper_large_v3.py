"""whisper-large-v3 [audio]: encoder-decoder, conv frontend STUB.
32L (enc) + 32L (dec) d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120
vocab=51866. [arXiv:2212.04356; unverified]

input_specs() provides precomputed frame embeddings [B, 1500, d_model]
(the conv1d+mel frontend is stubbed per the assignment). GELU (non-gated)
MLP, learned positions. Full attention -> long_500k skipped. The assigned
LM shapes exercise the DECODER backbone; enc_seq stays 1500 frames.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab=51_866,
        block_pattern=(("xdec", 32),),
        family="audio",
        n_enc_layers=32,
        enc_seq=1500,
        learned_pos=True,
        gated_mlp=False,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        block_pattern=(("xdec", 2),),
        family="audio",
        n_enc_layers=2,
        enc_seq=16,
        learned_pos=True,
        gated_mlp=False,
        frontend="audio",
    )
