"""Architecture registry: one module per assigned architecture, each
exporting full() and smoke() ModelConfigs. ``get(name, smoke=...)`` is what
the launcher, dry-run, and tests use; COBS index presets live in cobs.py.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "recurrentgemma-2b",
    "phi4-mini-3.8b",
    "qwen3-4b",
    "qwen2.5-3b",
    "granite-3-8b",
    "whisper-large-v3",
    "qwen3-moe-30b-a3b",
    "llama4-scout-17b-a16e",
    "qwen2-vl-7b",
    "xlstm-125m",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get(name: str, smoke: bool = False):
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    mod = importlib.import_module(f"{__name__}.{_MOD[name]}")
    return mod.smoke() if smoke else mod.full()


def list_archs() -> tuple[str, ...]:
    return ARCHS
