"""qwen2-vl-7b [vlm]: M-RoPE, dynamic resolution (patch frontend STUB).
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
[arXiv:2409.12191; hf]

input_specs() provides precomputed patch embeddings; M-RoPE is simplified
to 1-D RoPE on the text backbone (DESIGN.md §Arch-applicability). Full
attention -> long_500k skipped.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab=152_064,
        family="vlm",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        family="vlm",
        qkv_bias=True,
        frontend="vision",
    )
