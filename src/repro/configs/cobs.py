"""COBS index presets mirroring the paper's experimental parameters."""
from repro.core import IndexParams


def paper_default() -> IndexParams:
    """Section 3: k-mer 31, one hash, FPR 0.3, canonicalization off (the
    pre-processed McCortex inputs are already canonical)."""
    return IndexParams(n_hashes=1, fpr=0.3, kmer=31, canonical=False)


def small_test() -> IndexParams:
    """CI-scale: shorter k-mers so smaller synthetic docs have enough
    distinct terms."""
    return IndexParams(n_hashes=1, fpr=0.3, kmer=15, canonical=False)


PAPER_BLOCK_DOCS = 1024   # B for the 100k-document compact index (section 3)
