"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA, 32L d_model=3072 24H (GQA kv=8)
d_ff=8192 vocab=200064. [arXiv:2412.08905; hf]

Full attention -> long_500k skipped (see DESIGN.md §Arch-applicability).
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=200_064,
        family="dense",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        family="dense",
        tie_embeddings=True,
    )
