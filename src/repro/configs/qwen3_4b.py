"""qwen3-4b [dense]: qk_norm, GQA. 36L d_model=2560 32H (GQA kv=8)
d_ff=9728 vocab=151936. [hf:Qwen/Qwen3-8B; hf]

Full attention -> long_500k skipped.
"""
from repro.models import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151_936,
        family="dense",
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        family="dense",
        qk_norm=True,
        tie_embeddings=True,
    )
