"""llama4-scout-17b-a16e [moe]: 16 experts top-1 + shared expert, early
fusion (vision frontend STUB). 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Simplifications noted in DESIGN.md: iRoPE/chunked attention not modeled ->
treated as full attention, long_500k skipped.
"""
from repro.models import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202_048,
        block_pattern=(("moe", 48),),
        family="moe",
        rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert=True),
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab=512,
        block_pattern=(("moe", 2),),
        family="moe",
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64,
                      shared_expert=True,
                      capacity_factor=8.0),
        frontend="vision",
    )
