"""Analytic machinery from the paper (section 2.1).

* Bloom filter false positive rate and parameter selection.
* Theorem 1 (Solomon & Kingsford): false positive rate of a *query* of
  ell distinct terms at threshold K against a filter with per-lookup FPR p.
* The Chernoff bound variant.

All plain numpy / math — used for sizing filters at build time and for
validating empirical FPRs in tests and benchmarks.
"""
from __future__ import annotations

import math

import numpy as np


def bloom_fpr(w: int, k: int, v: int) -> float:
    """FPR (1 - e^{-kv/w})^k of a w-bit filter, k hashes, v inserted terms."""
    if v <= 0:
        return 0.0
    return (1.0 - math.exp(-k * v / w)) ** k


def bloom_size(v: int, fpr: float, k: int) -> int:
    """Minimal width w such that a filter with k hashes holding v terms has
    false positive rate <= fpr:  w = -k*v / ln(1 - fpr^(1/k)).

    For the paper's defaults (k=1, fpr=0.3): w ≈ 2.804 * v.
    """
    if not 0.0 < fpr < 1.0:
        raise ValueError("fpr must be in (0, 1)")
    if v <= 0:
        return 1
    return max(1, math.ceil(-k * v / math.log(1.0 - fpr ** (1.0 / k))))


def optimal_k(w: int, v: int) -> int:
    """Textbook optimum k = w/v * ln 2 (the paper argues k=1 is better for
    this workload; kept for completeness/tests)."""
    if v <= 0:
        return 1
    return max(1, round(w / v * math.log(2.0)))


def fill_rate(w: int, k: int, v: int) -> float:
    """Expected fraction of set bits: 1 - (1 - 1/w)^{kv}."""
    if v <= 0:
        return 0.0
    return 1.0 - (1.0 - 1.0 / w) ** (k * v)


def _log_binom_pmf_cumsum(ell: int, p: float) -> np.ndarray:
    """log pmf of Binomial(ell, p) for i = 0..ell, computed stably."""
    i = np.arange(ell + 1, dtype=np.float64)
    log_comb = np.concatenate(
        [[0.0], np.cumsum(np.log(np.arange(1, ell + 1)[::-1] / np.arange(1, ell + 1)))]
    )
    # log C(ell, i) via cumulative sum of log((ell - i + 1) / i)
    return log_comb + i * math.log(max(p, 1e-300)) + (ell - i) * math.log1p(-p)


def query_fpr(ell: int, p: float, theta: float) -> float:
    """Theorem 1: P[more than floor(theta*ell) lookups are false positives]
    = 1 - sum_{i=0}^{floor(theta*ell)} C(ell,i) p^i (1-p)^(ell-i)."""
    if ell <= 0:
        return 0.0
    if p <= 0.0:
        return 0.0
    if p >= 1.0:
        return 1.0
    t = int(math.floor(theta * ell))
    if t >= ell:
        return 0.0
    logs = _log_binom_pmf_cumsum(ell, p)[: t + 1]
    m = logs.max()
    cdf = math.exp(m) * np.exp(logs - m).sum()
    return float(max(0.0, 1.0 - cdf))


def query_fpr_chernoff(ell: int, p: float, theta: float) -> float:
    """Chernoff bound from the paper: exp(-ell (theta - p)^2 / (2 (1 - p)))
    valid for theta >= p."""
    if theta < p:
        return 1.0
    return math.exp(-ell * (theta - p) ** 2 / (2.0 * (1.0 - p)))


def expected_false_positive_docs(n_docs: int, ell: int, p: float, theta: float) -> float:
    """Expected count of false-positive documents for one query (paper's
    '143 false positives in one million documents' example)."""
    return n_docs * query_fpr(ell, p, theta)
