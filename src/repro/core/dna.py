"""DNA / q-gram handling: 2-bit encoding, k-mer packing, canonicalization.

Host-side (numpy) preparation layer. The jit boundary of the framework is
*packed terms*: each q-gram/k-mer (k <= 31) is packed into two uint32 words
(lo = first 16 bases, hi = remaining bases), which is what the hashing and
index layers consume. 64-bit packing is deliberately avoided so the same
representation works on the TPU VPU (32-bit lanes) and under jax's default
x64-disabled mode.

For non-DNA corpora (the paper also indexes English text q-grams) the same
packing applies to any byte alphabet via ``pack_qgrams_bytes``.
"""
from __future__ import annotations

import numpy as np

# 2-bit DNA codes. Order matters: complement(c) == 3 - c.
_BASES = "ACGT"
_CODE = np.full(256, 255, dtype=np.uint8)
for _i, _b in enumerate(_BASES):
    _CODE[ord(_b)] = _i
    _CODE[ord(_b.lower())] = _i

MAX_K = 31  # 31 bases * 2 bits = 62 bits <= two uint32 words


def encode_dna(seq: str) -> np.ndarray:
    """Encode an ACGT string to uint8 2-bit codes. Non-ACGT chars are dropped
    (the paper's input pipeline de-noises reads before indexing)."""
    raw = np.frombuffer(seq.encode("ascii"), dtype=np.uint8)
    codes = _CODE[raw]
    return codes[codes != 255]


def decode_dna(codes: np.ndarray) -> str:
    return "".join(_BASES[c] for c in np.asarray(codes))


def _pack_windows(win: np.ndarray) -> np.ndarray:
    """Pack 2-bit code windows [n, k] into uint32 pairs [n, 2] (lo, hi)."""
    n, k = win.shape
    lo_n = min(k, 16)
    out = np.zeros((n, 2), dtype=np.uint32)
    if n == 0:
        return out
    sh_lo = (2 * np.arange(lo_n, dtype=np.uint32))[None, :]
    out[:, 0] = np.bitwise_or.reduce(win[:, :lo_n].astype(np.uint32) << sh_lo, axis=1)
    if k > 16:
        hi_n = k - 16
        sh_hi = (2 * np.arange(hi_n, dtype=np.uint32))[None, :]
        out[:, 1] = np.bitwise_or.reduce(
            win[:, 16:].astype(np.uint32) << sh_hi, axis=1
        )
    return out


def pack_kmers(codes: np.ndarray, k: int, canonical: bool = False) -> np.ndarray:
    """All k-mers of a code string as packed uint32 pairs [n, 2].

    canonical=True replaces each k-mer by min(kmer, reverse_complement(kmer))
    (compared as 2k-bit integers), matching COBS' optional canonicalization.
    """
    if not 1 <= k <= MAX_K:
        raise ValueError(f"k must be in [1, {MAX_K}], got {k}")
    codes = np.asarray(codes, dtype=np.uint8)
    n = codes.shape[0] - k + 1
    if n <= 0:
        return np.zeros((0, 2), dtype=np.uint32)
    win = np.lib.stride_tricks.sliding_window_view(codes, k)
    fwd = _pack_windows(win)
    if not canonical:
        return fwd
    rc_win = (3 - win)[:, ::-1]
    rev = _pack_windows(np.ascontiguousarray(rc_win))
    fwd64 = fwd[:, 0].astype(np.uint64) | (fwd[:, 1].astype(np.uint64) << np.uint64(32))
    rev64 = rev[:, 0].astype(np.uint64) | (rev[:, 1].astype(np.uint64) << np.uint64(32))
    take_rev = rev64 < fwd64
    return np.where(take_rev[:, None], rev, fwd)


def pack_qgrams_bytes(data: bytes, q: int) -> np.ndarray:
    """q-grams over raw bytes (e.g. English text), q <= 8 so that 8 bits * 8
    chars fit 64 bits; packed into the same uint32-pair representation."""
    if not 1 <= q <= 8:
        raise ValueError("byte q-grams support q in [1, 8]")
    raw = np.frombuffer(data, dtype=np.uint8)
    n = raw.shape[0] - q + 1
    if n <= 0:
        return np.zeros((0, 2), dtype=np.uint32)
    win = np.lib.stride_tricks.sliding_window_view(raw, q)
    out = np.zeros((n, 2), dtype=np.uint32)
    lo_n = min(q, 4)
    sh_lo = (8 * np.arange(lo_n, dtype=np.uint32))[None, :]
    out[:, 0] = np.bitwise_or.reduce(win[:, :lo_n].astype(np.uint32) << sh_lo, axis=1)
    if q > 4:
        sh_hi = (8 * np.arange(q - 4, dtype=np.uint32))[None, :]
        out[:, 1] = np.bitwise_or.reduce(win[:, 4:].astype(np.uint32) << sh_hi, axis=1)
    return out


def unique_terms(terms: np.ndarray) -> np.ndarray:
    """Distinct packed terms (the paper scores distinct q-grams |G_q(P)|)."""
    if terms.shape[0] == 0:
        return terms
    as64 = terms[:, 0].astype(np.uint64) | (terms[:, 1].astype(np.uint64) << np.uint64(32))
    _, idx = np.unique(as64, return_index=True)
    return terms[np.sort(idx)]


def document_terms(
    reads: list[np.ndarray], k: int, canonical: bool = False
) -> np.ndarray:
    """Union of distinct k-mers over a document's reads (reads are k-merized
    independently, as COBS does for FASTA read files)."""
    parts = [pack_kmers(r, k, canonical) for r in reads]
    if not parts:
        return np.zeros((0, 2), dtype=np.uint32)
    return unique_terms(np.concatenate(parts, axis=0))
