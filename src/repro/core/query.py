"""Query processing (paper Fig. 3): HASH -> GATHER rows -> AND -> ADD -> select.

The engine consumes packed terms (uint32 [L, 2]) with a validity count,
produces per-document scores, and applies the coverage threshold K — the
fraction of the query's distinct q-grams that must hit a document for it to
be reported. Single queries and padded batches are supported; scoring runs
through the Pallas kernels (repro.kernels.ops) with a pure-jnp method for
oracle comparisons.

Planning (term compilation, padding, threshold math, hit selection) is kept
in PURE module-level functions so the synchronous QueryEngine and the
serving subsystem (repro.serve) share one implementation — the server's
micro-batcher pads with ``pad_term_batch`` and its planner keys buckets off
``padded_len``, so batched results are byte-identical to ``search``.

Out-of-core indexes (storage with more than one shard — MappedArena over a
cobs-jax-v2 store) run PAGED execution: ``plan_shards`` rebases each
shard's block row offsets to the shard's first row, the engine pages one
shard tile at a time to device (through a DeviceTileCache), scores it with
the same kernels, and the score-combine step concatenates per-shard slot
scores in block order — blocks partition the document slots, so the
combine is exact and results are bit-identical to dense execution.

Distribution (mesh-sharded arenas, psum'd partial scores, distributed top-k)
lives in repro.index.distributed and reuses the same planning functions.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import codec as _codec
from . import dna, hashing
from .arena import ArenaLayout, DeviceTileCache, common_tile_rows
from .index import BitSlicedIndex, IndexParams
from ..kernels import ops


# --------------------------------------------------------------------------
# Pure planning helpers (no device state; shared by engine / server / dist)
# --------------------------------------------------------------------------

def plan_rows(
    hashes: jnp.ndarray, row_offset: jnp.ndarray, block_width: jnp.ndarray
) -> jnp.ndarray:
    """Map term hashes to arena rows, per block.

    hashes: uint32 [..., k]; returns int32 [..., k, n_blocks] — the paper's
    'large output range then modulo per sub-index' addressing."""
    w = block_width.astype(jnp.uint32)
    rows = hashes[..., None] % w
    return (rows + row_offset.astype(jnp.uint32)).astype(jnp.int32)


@dataclass(frozen=True)
class ShardPlan:
    """Per-shard query addressing: the shard's blocks with row offsets
    rebased to the shard's first arena row. Scoring shard ``shard`` with
    (row_offset, block_width) against its device tile yields the slot
    scores of blocks [block_start, block_end) — per-shard outputs
    concatenated in shard order ARE the global slot scores."""
    shard: int
    block_start: int
    block_end: int
    row_offset: np.ndarray   # int32 [nb_s], shard-local
    block_width: np.ndarray  # int32 [nb_s]


def plan_shards(layout: ArenaLayout, shard_row_starts: np.ndarray
                ) -> list[ShardPlan]:
    """Map every storage shard to the blocks it holds (pure; shared by the
    QueryEngine and the serving planner). The all-shards special case of
    ``plan_shards_subset`` — one copy of the rebasing arithmetic."""
    return plan_shards_subset(layout, shard_row_starts,
                              range(len(shard_row_starts) - 1))


def plan_shards_subset(layout: ArenaLayout, global_row_starts: np.ndarray,
                       shard_ids) -> list[ShardPlan]:
    """Per-placement variant of ``plan_shards``: addressing for a SUBSET of
    a store's shards, as held by one host's sub-store view.

    ``global_row_starts`` are the parent store's shard boundaries and
    ``shard_ids`` the (sorted) global manifest rows this host holds.
    ``ShardPlan.shard`` is the LOCAL tile index into the sub-store's
    storage; block ranges stay GLOBAL, so a worker's per-shard slot scores
    land at global slots [block_start * block_docs, block_end * block_docs)
    — the frontend's gather is exact by construction."""
    ranges = layout.shard_blocks(np.asarray(global_row_starts, np.int64))
    plans = []
    for local, g in enumerate(shard_ids):
        b0, b1 = ranges[g]
        base = np.int32(global_row_starts[g])
        plans.append(ShardPlan(
            shard=local, block_start=b0, block_end=b1,
            row_offset=layout.row_offset[b0:b1] - base,
            block_width=layout.block_width[b0:b1]))
    return plans


def compile_pattern(pattern, params: IndexParams) -> np.ndarray:
    """Pattern (DNA string or uint8 code array) -> distinct packed terms
    [ell, 2] under the index's k-mer parameters. Host-side and pure."""
    codes = dna.encode_dna(pattern) if isinstance(pattern, str) else pattern
    return dna.unique_terms(
        dna.pack_kmers(codes, params.kmer, params.canonical))


def padded_len(n_terms: int, term_pad: int) -> int:
    """Smallest multiple of ``term_pad`` holding ``n_terms`` (>= term_pad).

    This is the jit-cache key of a query's shape: every query padded to the
    same length shares one compiled scoring executable, which is what the
    serving batcher's shape buckets are built on."""
    return max(term_pad,
               ((n_terms + term_pad - 1) // term_pad) * term_pad)


def pad_terms(terms: np.ndarray, term_pad: int) -> tuple[np.ndarray, int]:
    """Packed terms [L, 2] -> (zero-padded [padded_len, 2], L)."""
    L = terms.shape[0]
    out = np.zeros((padded_len(L, term_pad), 2), dtype=np.uint32)
    out[:L] = terms
    return out, L


def pad_term_batch(term_sets: list[np.ndarray], term_pad: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Term sets -> (shared-padding buffer [Q, pad, 2], ells int32 [Q])."""
    ells = np.array([t.shape[0] for t in term_sets], dtype=np.int32)
    pad = padded_len(int(ells.max(initial=1)), term_pad)
    buf = np.zeros((len(term_sets), pad, 2), dtype=np.uint32)
    for i, t in enumerate(term_sets):
        buf[i, : t.shape[0]] = t
    return buf, ells


def coverage_cutoff(threshold: float, n_terms: int) -> int:
    """The paper's K-threshold: minimum score = ceil(threshold * ell),
    never below 1 (a zero cutoff would report every document)."""
    return max(1, math.ceil(threshold * n_terms))


def select_hits(scores: np.ndarray, n_terms: int, threshold: float
                ) -> "SearchResult":
    """Apply the coverage cutoff and order hits best-first (stable)."""
    if n_terms == 0:
        return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
    cut = coverage_cutoff(threshold, n_terms)
    hits = np.nonzero(scores >= cut)[0]
    order = np.argsort(-scores[hits], kind="stable")
    return SearchResult(hits[order].astype(np.int32),
                        scores[hits][order].astype(np.int32), n_terms, cut)


def select_top_k(scores: np.ndarray, n_terms: int, k: int) -> "SearchResult":
    """Best-k documents by score (the paper's top-k selection). The
    reported threshold is the k-th best score — the effective cutoff.

    Stable sort (not argpartition) so ties — including at the k boundary —
    resolve to ascending doc id deterministically."""
    k = min(k, scores.shape[0])
    if k == 0:
        return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32),
                            n_terms, 0)
    order = np.argsort(-scores, kind="stable")[:k]
    top = scores[order].astype(np.int32)
    return SearchResult(order.astype(np.int32), top, n_terms, int(top[-1]))


def run_paged(tiles, shard_args, fn, *args) -> list[np.ndarray]:
    """Dispatch ``fn`` once per shard tile with double-buffered prefetch,
    shared by the QueryEngine and the serving QueryServer.

    While shard i's scoring call is in flight (jax dispatch is async),
    shard i+1 stages host->device through ``tiles.prefetch`` — transfer
    overlaps compute. Results are forced to host only after every dispatch
    is issued. ``shard_args`` is [(shard, row_offset_dev, block_width_dev)]
    and ``fn(tile, offs, widths, *args)`` the planned scorer."""
    parts = []
    for i, (s, offs, widths) in enumerate(shard_args):
        tile = tiles.get(s)
        out = fn(tile, offs, widths, *args)
        if i + 1 < len(shard_args):
            tiles.prefetch(shard_args[i + 1][0])
        parts.append(out)
    return [np.asarray(p) for p in parts]


def run_paged_compressed(tiles, shard_args, fn_raw, fn_comp, *args
                         ) -> list[np.ndarray]:
    """``run_paged`` with per-shard codec dispatch: dict-coded shards stage
    their COMPRESSED (dict, refs) form to device and score through
    ``fn_comp(dict_rows, refs, offs, widths, *args)`` — the fused-decode
    kernels — while raw shards take ``fn_raw`` unchanged. Prefetch is
    codec-aware, so the overlap stages the form that will actually be
    scored. Outputs are bit-identical to the all-raw path."""
    storage = tiles.storage
    comp = [storage.shard_codec(s) in _codec.DICT_CODECS
            for (s, _, _) in shard_args]
    parts = []
    for i, (s, offs, widths) in enumerate(shard_args):
        if comp[i]:
            dict_rows, refs = tiles.get_compressed(s)
            out = fn_comp(dict_rows, refs, offs, widths, *args)
        else:
            out = fn_raw(tiles.get(s), offs, widths, *args)
        if i + 1 < len(shard_args):
            nxt = shard_args[i + 1][0]
            (tiles.prefetch_compressed if comp[i + 1]
             else tiles.prefetch)(nxt)
        parts.append(out)
    return [np.asarray(p) for p in parts]


# --------------------------------------------------------------------------
# Batched row dedup (the serving hot-path bandwidth optimization)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DedupBatchPlan:
    """Unique-row addressing for one micro-batch.

    Queries in a batch share rows heavily (overlapping k-mers), but the
    fused multi-query kernel re-streams an arena row per (query, block,
    term) cell. This plan collapses the batch's (block, row) pairs into
    ``uniq_rows`` (each arena row listed ONCE, padded to a power of two so
    jit entries stay bounded) plus the ``indir`` indirection that maps
    every cell back to its unique row — the kernels then gather U rows
    from the arena instead of Q*nb*L.

    For k>1 indexes the unit of dedup is the (row-set, AND) TUPLE: one
    term addresses k rows whose AND is scored, so ``uniq_rows`` is
    [U_pad, k] and equal row-sets across cells collapse to one k-row
    gather + AND (``np.unique(axis=0)`` over tuples).
    """
    uniq_rows: np.ndarray   # int32 [U_pad] (k=1) or [U_pad, k] (0-padded)
    indir: np.ndarray       # int32 [Q, nb, L] -> index into uniq_rows
    mask: np.ndarray        # int32 [Q, nb, L] (1 = live term)
    n_unique: int           # live unique rows/row-sets (<= U_pad)
    n_gathers: int          # live (query, block, term) cells

    @property
    def dedup_rate(self) -> float:
        """Fraction of the fused path's row gathers the dedup path saves:
        1 - unique/total. 0 = fully disjoint batch, ->1 = heavy sharing."""
        if self.n_gathers == 0:
            return 0.0
        return 1.0 - self.n_unique / self.n_gathers


def _pad_unique(n: int) -> int:
    """Unique-row count -> padded buffer length: power of two (bounds the
    jit cache at log2(max U) entries per bucket), floor 8 (sublane)."""
    return max(8, 1 << max(0, int(n) - 1).bit_length())


def plan_dedup_batch(terms: np.ndarray, n_valid: np.ndarray,
                     row_offset: np.ndarray, block_width: np.ndarray,
                     n_hashes: int = 1) -> DedupBatchPlan:
    """Host-side dedup planning for one padded micro-batch.

    terms uint32 [Q, L, 2]; n_valid int32 [Q]; (row_offset, block_width)
    the addressing of the arena (or of ONE shard, already rebased — the
    paged path plans per shard). Pure numpy: hashing reuses the
    bit-identical host mirror of the device hash, so the rows the fused
    kernel would gather and the rows planned here are the same set.

    k=1 dedups single rows; k>1 dedups (row-set) tuples — every cell's k
    hash rows, deduped as a unit via ``np.unique(axis=0)``, so the device
    gathers + ANDs each distinct row-set once (see DedupBatchPlan).
    """
    terms = np.asarray(terms)
    n_valid = np.asarray(n_valid, dtype=np.int32)
    Q, L = terms.shape[0], terms.shape[1]
    k = int(n_hashes)
    w = np.asarray(block_width).astype(np.uint32)
    off = np.asarray(row_offset).astype(np.uint32)
    valid = np.arange(L, dtype=np.int32)[None, :] < n_valid[:, None]
    if k == 1:
        h = hashing.hash_terms_np(terms, 1)[..., 0]           # [Q, L]
        rows = (h[..., None] % w[None, None, :] + off)        # [Q, L, nb]
        rows = np.swapaxes(rows, 1, 2).astype(np.int64)       # [Q, nb, L]
        cell_shape = rows.shape
        mask = np.broadcast_to(valid[:, None, :], cell_shape)
        live = rows[mask]                                     # [N]
        uniq, inv = np.unique(live, return_inverse=True)
        uniq_pad = np.zeros(_pad_unique(uniq.size), dtype=np.int32)
        uniq_pad[: uniq.size] = uniq
    else:
        h = hashing.hash_terms_np(terms, k)                   # [Q, L, k]
        rows = (h[..., None] % w + off)                       # [Q, L, k, nb]
        rows = np.transpose(rows, (0, 3, 1, 2)).astype(np.int64)  # [Q,nb,L,k]
        cell_shape = rows.shape[:3]
        mask = np.broadcast_to(valid[:, None, :], cell_shape)
        live = rows[mask]                                     # [N, k]
        uniq, inv = np.unique(live, axis=0, return_inverse=True)
        uniq_pad = np.zeros((_pad_unique(uniq.shape[0]), k), dtype=np.int32)
        uniq_pad[: uniq.shape[0]] = uniq
    indir = np.zeros(cell_shape, dtype=np.int32)
    indir[mask] = np.asarray(inv).reshape(-1).astype(np.int32)
    n_uniq = int(uniq.shape[0])
    return DedupBatchPlan(uniq_rows=uniq_pad, indir=indir,
                          mask=mask.astype(np.int32),
                          n_unique=n_uniq, n_gathers=int(live.shape[0]))


def make_dedup_score_fn(word_block: int | None = None):
    """Returns score(arena, uniq_rows [U], indir [Q,nb,L], mask [Q,nb,L])
    -> int32 [Q, n_slots] — the two-kernel dedup path (unique-row gather +
    indirected Harley-Seal accumulate). Bit-identical to the fused
    multi-query kernel on the expanded indices."""

    def score(arena, uniq_rows, indir, mask):
        return ops.bitslice_lookup_score_dedup(arena, uniq_rows, indir,
                                               mask, word_block=word_block)

    return score


def make_comp_dedup_score_fn(word_block: int | None = None):
    """Compressed twin of ``make_dedup_score_fn``: score(dict_rows, refs,
    uniq_rows, indir, mask) -> int32 [Q, n_slots], decoding each unique
    row (or AND'd row-set) out of the shard dict inside the gather kernel."""

    def score(dict_rows, refs, uniq_rows, indir, mask):
        return ops.bitslice_lookup_score_dedup_comp(
            dict_rows, refs, uniq_rows, indir, mask, word_block=word_block)

    return score


def run_paged_dedup(tiles, shard_plans: list[ShardPlan], fn,
                    terms: np.ndarray, n_valid: np.ndarray,
                    n_hashes: int = 1, fn_comp=None) -> np.ndarray:
    """Dedup-scored batch across shard tiles (one tile = the whole arena
    for dense storage): per shard, plan the unique-row set against the
    shard's REBASED addressing, score through ``fn`` (from
    ``make_dedup_score_fn``), prefetch the next tile while the dispatch is
    in flight, and concatenate per-shard slot scores — the dedup analogue
    of ``run_paged``.

    With ``fn_comp`` (from ``make_comp_dedup_score_fn``) dict-coded shards
    stage compressed and score through the fused-decode kernels; raw
    shards keep ``fn``. ``n_hashes`` > 1 plans row-SET dedup."""
    storage = tiles.storage
    comp = [fn_comp is not None
            and storage.shard_codec(sp.shard) in _codec.DICT_CODECS
            for sp in shard_plans]
    parts = []
    for i, sp in enumerate(shard_plans):
        dp = plan_dedup_batch(terms, n_valid, sp.row_offset, sp.block_width,
                              n_hashes=n_hashes)
        planned = (jnp.asarray(dp.uniq_rows), jnp.asarray(dp.indir),
                   jnp.asarray(dp.mask))
        if comp[i]:
            dict_rows, refs = tiles.get_compressed(sp.shard)
            out = fn_comp(dict_rows, refs, *planned)
        else:
            out = fn(tiles.get(sp.shard), *planned)
        if i + 1 < len(shard_plans):
            nxt = shard_plans[i + 1].shard
            (tiles.prefetch_compressed if comp[i + 1]
             else tiles.prefetch)(nxt)
        parts.append(out)
    return np.concatenate([np.asarray(p) for p in parts], axis=1)


# --------------------------------------------------------------------------
# Pruned scoring (branch-and-bound over the coverage threshold)
# --------------------------------------------------------------------------
#
# The fused path scores every (query, block, term) cell before the threshold
# is consulted. The pruned path executes terms in CHUNKS (rarest first when
# the store recorded popcount stats) and keeps a per-(query, block) running
# count on device; after each chunk any block whose best possible final
# score — running max + terms remaining — cannot reach the required cutoff
# is dropped. Work for dropped blocks (host row reads, device staging,
# kernel cells) is never issued, and a shard whose every block is dropped
# is never touched again. Partial sums in dropped blocks stay strictly
# below the cutoff, so reported hits and scores are bit-identical to the
# exhaustive engine.
#
# I/O model: instead of staging whole shard tiles, each (chunk, shard)
# visit host-gathers only the chunk's unique touched rows out of the mmap
# (dict-coded shards gather decoded rows through their dictionary) and
# stages that small matrix. When a shard's cumulative gathered bytes
# approach its tile size — dense corpora, long queries, low thresholds —
# the executor PROMOTES the shard: the full tile is staged once through
# the DeviceTileCache (prefetched ahead at half the threshold so the H2D
# copy overlaps the remaining gather-fed chunks) and later chunks read it
# on device — the fused in-kernel gather for k=1, a device gather+AND of
# the chunk's unique row sets for k>1. Pruned shards never promote, so
# the tile cache records zero faults for them — "tiles skipped" is
# directly observable.


@dataclass
class PruneStats:
    """Work accounting for one pruned batch (mutated in place).

    ``bytes_read`` is the headline number: host arena bytes actually read
    (row gathers + promoted tile stagings) — the quantity the exhaustive
    path pays ``sum(shard_nbytes)`` for."""
    blocks_total: int = 0        # live (query, block) cells at entry
    blocks_pruned: int = 0       # cells dropped before the final chunk
    chunks: int = 0              # term chunks executed
    shard_visits: int = 0        # (chunk, shard) visits dispatched
    shard_visits_skipped: int = 0  # visits skipped (no live cell)
    tiles_promoted: int = 0      # shards escalated to full-tile staging
    kernel_dispatches: int = 0
    bytes_gathered: int = 0      # host bytes read by row gathers
    bytes_tile_staged: int = 0   # bytes of promoted full tiles

    @property
    def bytes_read(self) -> int:
        return self.bytes_gathered + self.bytes_tile_staged

    @property
    def prune_rate(self) -> float:
        if self.blocks_total == 0:
            return 0.0
        return self.blocks_pruned / self.blocks_total

    def merge(self, other: "PruneStats") -> None:
        """Accumulate another batch's counters (serving aggregates)."""
        for f in ("blocks_total", "blocks_pruned", "chunks", "shard_visits",
                  "shard_visits_skipped", "tiles_promoted",
                  "kernel_dispatches", "bytes_gathered", "bytes_tile_staged"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


def order_terms_rarest(storage, shard_plans: list[ShardPlan],
                       terms: np.ndarray, n_valid: np.ndarray,
                       n_hashes: int = 1, max_blocks: int = 8) -> np.ndarray:
    """Per-query term execution order for pruned scoring: int32 [Q, L]
    permutation, valid terms first, rarest first.

    Rare terms kill blocks early — a block missing a rare term loses score
    headroom immediately — so ascending estimated popcount maximizes
    early-exit leverage. The estimate samples up to ``max_blocks`` blocks
    spread over the arena and sums each term's row popcounts there (min
    over the k hash rows: a term's hits need all k bits), read from the
    store's popcount sidecars. Stores without stats (pre-v2 or external
    arenas) fall back to natural order — the executor stays correct, just
    prunes later."""
    terms = np.asarray(terms)
    n_valid = np.asarray(n_valid, dtype=np.int32)
    Q, L = terms.shape[0], terms.shape[1]
    natural = np.broadcast_to(np.arange(L, dtype=np.int32), (Q, L)).copy()
    has = getattr(storage, "has_popcounts", None)
    if L == 0 or has is None or not has():
        return natural
    starts = np.asarray(storage.shard_row_starts, dtype=np.int64)
    offs = [sp.row_offset.astype(np.int64) + int(starts[sp.shard])
            for sp in shard_plans]
    wids = [sp.block_width.astype(np.int64) for sp in shard_plans]
    off = np.concatenate(offs)
    wid = np.concatenate(wids)
    sel = np.unique(np.linspace(0, off.shape[0] - 1,
                                min(max_blocks, off.shape[0])).astype(np.int64))
    off, wid = off[sel], wid[sel]
    h = hashing.hash_terms_np(terms, n_hashes).astype(np.int64)  # [Q, L, k]
    rows = h[..., None] % wid + off                       # [Q, L, k, S]
    uniq, inv = np.unique(rows.reshape(-1), return_inverse=True)
    pops = np.asarray(storage.row_popcounts(uniq), dtype=np.int64)
    est = pops[inv].reshape(rows.shape).min(axis=2).sum(axis=-1)  # [Q, L]
    est[np.arange(L, dtype=np.int32)[None, :] >= n_valid[:, None]] = (
        np.iinfo(np.int64).max)                           # padding last
    return np.argsort(est, axis=1, kind="stable").astype(np.int32)


def run_paged_pruned(tiles, shard_plans: list[ShardPlan], terms: np.ndarray,
                     n_valid: np.ndarray, required: np.ndarray,
                     topk: np.ndarray, *, n_hashes: int = 1,
                     chunk_terms: int = 32, word_block: int | None = None,
                     promote_ratio: float = 0.5, order: np.ndarray | None = None,
                     stats: PruneStats | None = None) -> np.ndarray:
    """Branch-and-bound batch scoring across shard tiles.

    terms uint32 [Q, L, 2] (shared padding), n_valid int32 [Q];
    ``required`` int32 [Q] is each query's fixed score cutoff
    (``coverage_cutoff`` — use 0 for top-k queries) and ``topk`` int32 [Q]
    the per-query k (0 = threshold query; the cutoff then tightens
    dynamically to the merged k-th largest running count). Returns int32
    [Q, n_slots] slot scores, bit-identical to ``run_paged`` on every slot
    that can meet its query's cutoff — pruned blocks hold partial sums
    that are provably below it, so downstream ``select_hits`` /
    ``select_top_k`` report identical results.

    ``order`` overrides the term execution order ([Q, L] permutation,
    valid-first); default is ``order_terms_rarest``. ``stats`` (a
    PruneStats) is mutated with work/IO accounting."""
    terms = np.asarray(terms)
    n_valid = np.asarray(n_valid, dtype=np.int32)
    required = np.asarray(required, dtype=np.int64).copy()
    topk = np.asarray(topk, dtype=np.int32)
    if stats is None:
        stats = PruneStats()
    storage = tiles.storage
    Q, L = terms.shape[0], terms.shape[1]
    W = int(storage.shape[1])
    k = int(n_hashes)
    ct = max(1, int(chunk_terms))
    n_sh = len(shard_plans)
    nbs = [sp.row_offset.shape[0] for sp in shard_plans]
    l_max = int(n_valid.max(initial=0))
    if l_max == 0 or Q == 0:
        return np.zeros((Q, sum(nbs) * W * 32), dtype=np.int32)

    if order is None:
        order = order_terms_rarest(storage, shard_plans, terms, n_valid,
                                   n_hashes=k)
    h = hashing.hash_terms_np(terms, k)                   # [Q, L, k]
    h_ord = np.take_along_axis(h, np.asarray(order, np.int64)[..., None],
                               axis=1)

    alive = [np.ones((Q, nb), dtype=bool) for nb in nbs]
    acc = [None] * n_sh
    block_max = [np.zeros((Q, nb), dtype=np.int64) for nb in nbs]
    tk_lower = [None] * n_sh                # [Q, kmax] per shard (top-k)
    promoted = [False] * n_sh
    prefetch_issued = [False] * n_sh        # promotion prefetch dispatched
    resident = [None] * n_sh                # device tile or (dict, refs)
    gathered = [0] * n_sh                   # cumulative gather bytes
    decode_counted = [False] * n_sh
    stats.blocks_total += int(Q * sum(nbs))
    kmax = int(topk.max(initial=0))
    is_topk = topk > 0

    n_chunks = -(-l_max // ct)
    offs = [sp.row_offset.astype(np.uint32) for sp in shard_plans]
    wids = [sp.block_width.astype(np.uint32) for sp in shard_plans]
    codecs = [storage.shard_codec(sp.shard) for sp in shard_plans]

    for c in range(n_chunks):
        stats.chunks += 1
        j0 = c * ct
        h_chunk = np.zeros((Q, ct, k), dtype=h_ord.dtype)
        width = min(ct, L - j0)
        h_chunk[:, :width] = h_ord[:, j0:j0 + width]
        valid_chunk = (j0 + np.arange(ct, dtype=np.int32)[None, :]
                       < n_valid[:, None])                # [Q, ct]
        visited = []
        for s, sp in enumerate(shard_plans):
            live = alive[s][:, :, None] & valid_chunk[:, None, :]  # [Q,nb,ct]
            if not live.any():
                stats.shard_visits_skipped += 1
                continue
            stats.shard_visits += 1
            visited.append(s)
            rows = (h_chunk[..., None] % wids[s] + offs[s])  # [Q, ct, k, nb]
            rows = np.transpose(rows, (0, 3, 1, 2)).astype(np.int64)
            if acc[s] is None:
                acc[s] = ops.chunk_acc_init(Q, nbs[s], W,
                                            word_block=word_block)
            hbm = storage.shard_hbm_nbytes(sp.shard)
            if (not promoted[s] and not prefetch_issued[s]
                    and gathered[s] >= 0.5 * promote_ratio * hbm):
                # Double-buffer the promotion: once gathers cross half the
                # promote threshold the full tile is prefetched (a
                # non-blocking H2D dispatch), so it overlaps the remaining
                # gather-fed chunks and is already resident when the
                # threshold trips — promotion never stalls on a staging.
                prefetch_issued[s] = True
                if codecs[s] in _codec.DICT_CODECS:
                    tiles.prefetch_compressed(sp.shard)
                else:
                    tiles.prefetch(sp.shard)
            if not promoted[s] and gathered[s] >= promote_ratio * hbm:
                promoted[s] = True
                if codecs[s] in _codec.DICT_CODECS:
                    resident[s] = tiles.get_compressed(sp.shard)
                else:
                    resident[s] = tiles.get(sp.shard)
                stats.tiles_promoted += 1
                stats.bytes_tile_staged += hbm
            mask = jnp.asarray(live.astype(np.int32))
            if promoted[s] and k == 1:
                idx = jnp.asarray(rows[..., 0].astype(np.int32))
                if codecs[s] in _codec.DICT_CODECS:
                    d, r = resident[s]
                    acc[s], bmax = ops.bitslice_chunk_score_multi_comp(
                        d, r, idx, mask, acc[s], word_block=word_block)
                else:
                    acc[s], bmax = ops.bitslice_chunk_score_multi(
                        resident[s], idx, mask, acc[s], word_block=word_block)
            elif promoted[s]:
                # k>1 promoted path: the chunk's unique row SETS are still
                # planned host-side (np.unique over live cells), but the
                # rows themselves are gathered and ANDed on DEVICE out of
                # the resident tile — no host arena reads after promotion.
                cells = rows[live]                        # [N, k]
                uniq, inv = np.unique(cells, axis=0, return_inverse=True)
                u_idx = np.zeros((_pad_unique(uniq.shape[0]), k),
                                 dtype=np.int32)
                u_idx[: uniq.shape[0]] = uniq
                if codecs[s] in _codec.DICT_CODECS:
                    d, r = resident[s]
                    mat_dev = ops.gather_and_rows_comp(
                        d, r, jnp.asarray(u_idx))
                else:
                    mat_dev = ops.gather_and_rows(
                        resident[s], jnp.asarray(u_idx))
                indir = np.zeros((Q, nbs[s], ct), dtype=np.int32)
                indir[live] = np.asarray(inv).reshape(-1).astype(np.int32)
                acc[s], bmax = ops.bitslice_chunk_score_dedup(
                    mat_dev, jnp.asarray(indir), mask, acc[s],
                    word_block=word_block)
            else:
                cells = rows[live]                        # [N, k]
                if k == 1:
                    uniq, inv = np.unique(cells[:, 0], return_inverse=True)
                else:
                    uniq, inv = np.unique(cells, axis=0, return_inverse=True)
                if codecs[s] in _codec.DICT_CODECS:
                    d_host, r_host = storage.shard_dict_host(sp.shard)
                    refs = np.asarray(r_host)[uniq]       # [U] or [U, k]
                    mat = np.asarray(d_host[refs.reshape(-1)],
                                     dtype=np.uint32)
                    nread = int(np.unique(refs).size)
                else:
                    if (codecs[s] != _codec.CODEC_RAW
                            and not decode_counted[s]):
                        # non-dict compressed shards decode whole on touch
                        decode_counted[s] = True
                        stats.bytes_gathered += storage.shard_nbytes(sp.shard)
                    host = storage.shard_host(sp.shard)
                    mat = np.asarray(host[uniq.reshape(-1)],
                                     dtype=np.uint32)
                    nread = int(uniq.reshape(-1).size)
                if codecs[s] == _codec.CODEC_RAW:
                    stats.bytes_gathered += nread * W * 4
                elif codecs[s] in _codec.DICT_CODECS:
                    stats.bytes_gathered += nread * W * 4
                gathered[s] += mat.shape[0] * W * 4
                if k > 1:
                    mat = mat.reshape(-1, k, W)
                    anded = mat[:, 0]
                    for i in range(1, k):
                        anded = anded & mat[:, i]
                    mat = anded
                u_pad = np.zeros((_pad_unique(mat.shape[0]), W),
                                 dtype=np.uint32)
                u_pad[: mat.shape[0]] = mat
                indir = np.zeros((Q, nbs[s], ct), dtype=np.int32)
                indir[live] = np.asarray(inv).reshape(-1).astype(np.int32)
                acc[s], bmax = ops.bitslice_chunk_score_dedup(
                    jnp.asarray(u_pad), jnp.asarray(indir), mask, acc[s],
                    word_block=word_block)
            stats.kernel_dispatches += 1
            block_max[s] = np.asarray(bmax).astype(np.int64)

        if c == n_chunks - 1:
            break
        if kmax > 0:
            for s in visited:
                tk_lower[s] = np.asarray(ops.chunk_topk_lower(acc[s], kmax))
            have = [t for t in tk_lower if t is not None]
            if have:
                merged = -np.sort(-np.concatenate(have, axis=1), axis=1)
                for q in np.nonzero(is_topk)[0]:
                    kq = int(topk[q])
                    if merged.shape[1] >= kq:
                        required[q] = max(required[q], int(merged[q, kq - 1]))
        executed = np.minimum(n_valid, (c + 1) * ct).astype(np.int64)
        remaining = n_valid.astype(np.int64) - executed
        any_alive = False
        for s in range(n_sh):
            keep = (block_max[s] + remaining[:, None]) >= required[:, None]
            newly = alive[s] & ~keep
            stats.blocks_pruned += int(newly.sum())
            alive[s] &= keep
            any_alive = any_alive or bool(alive[s].any())
        if not any_alive:
            break

    parts = []
    for s in range(n_sh):
        if acc[s] is None:
            parts.append(np.zeros((Q, nbs[s] * W * 32), dtype=np.int32))
        else:
            parts.append(np.asarray(ops.chunk_acc_scores(acc[s], W)))
    return np.concatenate(parts, axis=1)


# --------------------------------------------------------------------------
# Shard-major streaming execution (the offline bulk lane)
# --------------------------------------------------------------------------
#
# The interactive path is query-major: every micro-batch visits every
# shard, so a bounded DeviceTileCache restages tiles once per batch and a
# Q-query workload split into Q/B batches pays Q/B stagings per shard.
# ``run_shard_major`` inverts the loop for deadline-free bulk jobs: each
# shard tile is staged into HBM ONCE (raw or dict form, the next shard
# prefetched while the current one scores), the ENTIRE query set streams
# against it in query-chunks sized by ``ops.bulk_query_chunk``, and
# per-(query, block) running counts accumulate in the same chunk
# machinery ``run_paged_pruned`` uses — rarest-first term order and the
# threshold early-exit both carry over, so a decontamination scan prunes
# within each shard. Results are written into a persistent host slot
# buffer as each shard completes, which is also the resumability story:
# (out, next_shard, required) round-trips through a checkpoint.


@dataclass
class BulkStats:
    """Work accounting for shard-major bulk sweeps (additive: pass the
    same object across resumed calls for cumulative totals).

    ``bytes_staged`` is the headline number — arena bytes actually
    H2D-staged (raw + dict forms, measured off the tile-cache counters),
    the quantity the interactive path pays once per micro-batch sweep."""
    shards_swept: int = 0        # shards fully scored (all queries)
    tiles_staged: int = 0        # H2D stagings issued (demand + prefetch)
    bytes_staged: int = 0        # bytes those stagings moved
    query_chunks: int = 0        # query slabs dispatched
    kernel_dispatches: int = 0
    blocks_total: int = 0        # (query, block) cells entering sweeps
    blocks_pruned: int = 0       # cells retired by threshold early-exit

    @property
    def prune_rate(self) -> float:
        if self.blocks_total == 0:
            return 0.0
        return self.blocks_pruned / self.blocks_total

    def merge(self, other: "BulkStats") -> None:
        for f in ("shards_swept", "tiles_staged", "bytes_staged",
                  "query_chunks", "kernel_dispatches", "blocks_total",
                  "blocks_pruned"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


def run_shard_major(tiles, shard_plans: list[ShardPlan], terms: np.ndarray,
                    n_valid: np.ndarray, required: np.ndarray,
                    topk: np.ndarray, *, n_hashes: int = 1,
                    chunk_terms: int = 32, query_chunk: int | None = None,
                    word_block: int | None = None,
                    order: np.ndarray | None = None,
                    stats: BulkStats | None = None, start_shard: int = 0,
                    out: np.ndarray | None = None,
                    should_yield=None) -> tuple[np.ndarray, int, np.ndarray]:
    """Shard-major streaming scan: one tile staging amortized over Q.

    terms uint32 [Q, L, 2] (shared padding), n_valid int32 [Q];
    ``required`` int64 [Q] per-query score cutoffs (``coverage_cutoff``,
    0 for top-k) and ``topk`` int32 [Q] per-query k (0 = threshold).
    Returns ``(out, next_shard, required)``: int32 [Q, n_slots] slot
    scores (global block addressing — each shard lands at columns
    [block_start, block_end) * W * 32), the index of the first unswept
    shard, and the tightened cutoffs. Slots in pruned (query, block)
    cells hold partial sums provably below the query's cutoff, so
    ``select_hits`` / ``select_top_k`` downstream are bit-identical to
    the exhaustive engine — same soundness argument as
    ``run_paged_pruned``.

    ``tiles`` is one DeviceTileCache or a sequence parallel to
    ``shard_plans`` (the multi-host sweep walks each shard's primary
    worker's cache). ``should_yield()`` is polled at shard boundaries:
    returning True suspends the sweep — the caller checkpoints
    ``(out, next_shard, required)`` and re-enters with ``start_shard`` /
    ``out`` / the returned cutoffs to resume. Top-k cutoffs tighten after
    every completed shard from the k-th largest accumulated count (a
    sound lower bound: unswept slots are zero, pruned slots are partial),
    so later shards prune harder."""
    plans = list(shard_plans)
    n_sh = len(plans)
    caches = (list(tiles) if isinstance(tiles, (list, tuple))
              else [tiles] * n_sh)
    terms = np.asarray(terms)
    n_valid = np.asarray(n_valid, dtype=np.int32)
    required = np.asarray(required, dtype=np.int64).copy()
    topk = np.asarray(topk, dtype=np.int32)
    if stats is None:
        stats = BulkStats()
    Q, L = terms.shape[0], terms.shape[1]
    k = int(n_hashes)
    ct = max(1, int(chunk_terms))
    if not plans:
        return np.zeros((Q, 0), dtype=np.int32), 0, required
    storage0 = caches[0].storage
    W = int(storage0.shape[1])
    ncols = max(sp.block_end for sp in plans) * W * 32
    if out is None:
        out = np.zeros((Q, ncols), dtype=np.int32)
    l_max = int(n_valid.max(initial=0))
    if Q == 0 or l_max == 0:
        return out, n_sh, required

    if order is None:
        # Popcount estimation uses the first cache's storage and only the
        # plans addressed against it (multi-host sweeps mix storages);
        # the order is a heuristic, correctness never depends on it.
        own = [sp for ca, sp in zip(caches, plans) if ca is caches[0]]
        order = order_terms_rarest(storage0, own, terms, n_valid,
                                   n_hashes=k)
    h = hashing.hash_terms_np(terms, k)                   # [Q, L, k]
    h_ord = np.take_along_axis(h, np.asarray(order, np.int64)[..., None],
                               axis=1)
    n_chunks = -(-l_max // ct)
    is_topk = topk > 0
    any_topk = bool(is_topk.any())

    for si in range(start_shard, n_sh):
        if (should_yield is not None and si > start_shard
                and should_yield()):
            return out, si, required
        sp, cache = plans[si], caches[si]
        storage = cache.storage
        dict_coded = storage.shard_codec(sp.shard) in _codec.DICT_CODECS

        def _staged(cache, fn, *a):
            # Under the cache's own (reentrant) lock so the byte-counter
            # delta can't absorb a concurrent interactive staging — the
            # bulk lane runs unserialized against the scoring workers.
            with cache._lock:
                b0 = cache.raw_bytes_staged + cache.comp_bytes_staged
                r = fn(*a)
                moved = (cache.raw_bytes_staged
                         + cache.comp_bytes_staged) - b0
            if moved:
                stats.tiles_staged += 1
                stats.bytes_staged += moved
            return r

        tile = _staged(cache, cache.get_compressed if dict_coded
                       else cache.get, sp.shard)
        if si + 1 < n_sh:                     # double-buffer the next tile
            nsp, ncache = plans[si + 1], caches[si + 1]
            ndict = ncache.storage.shard_codec(nsp.shard) in \
                _codec.DICT_CODECS
            _staged(ncache, ncache.prefetch_compressed if ndict
                    else ncache.prefetch, nsp.shard)

        nb = int(sp.block_end - sp.block_start)
        col0, col1 = sp.block_start * W * 32, sp.block_end * W * 32
        offs = sp.row_offset.astype(np.uint32)
        wids = sp.block_width.astype(np.uint32)
        qc = int(query_chunk) if query_chunk else ops.bulk_query_chunk(
            nb, W, word_block=word_block)
        # never dispatch slabs wider than the (pow2-padded) set itself —
        # the VMEM budget is an upper bound, not a padding target
        qc = min(qc, max(8, 1 << max(0, Q - 1).bit_length()))
        for q0 in range(0, Q, qc):
            qn = min(qc, Q - q0)
            sl = slice(q0, q0 + qn)
            stats.query_chunks += 1
            stats.blocks_total += qn * nb
            # Pad the final slab up to qc so every slab of the sweep
            # shares one compiled kernel shape; padded queries carry
            # n_valid = 0 and are fully masked.
            hv = np.zeros((qc, L, k), dtype=h_ord.dtype)
            hv[:qn] = h_ord[sl]
            nv = np.zeros(qc, dtype=np.int32)
            nv[:qn] = n_valid[sl]
            req = np.zeros(qc, dtype=np.int64)
            req[:qn] = required[sl]
            alive = np.zeros((qc, nb), dtype=bool)
            alive[:qn] = True
            acc = ops.chunk_acc_init(qc, nb, W, word_block=word_block)
            for c in range(n_chunks):
                j0 = c * ct
                valid_chunk = (j0 + np.arange(ct, dtype=np.int32)[None, :]
                               < nv[:, None])
                live = alive[:, :, None] & valid_chunk[:, None, :]
                if not live.any():
                    break
                h_chunk = np.zeros((qc, ct, k), dtype=h_ord.dtype)
                width = min(ct, L - j0)
                h_chunk[:, :width] = hv[:, j0:j0 + width]
                rows = (h_chunk[..., None] % wids + offs)  # [qc, ct, k, nb]
                rows = np.transpose(rows, (0, 3, 1, 2)).astype(np.int64)
                mask = jnp.asarray(live.astype(np.int32))
                if k == 1:
                    idx = jnp.asarray(rows[..., 0].astype(np.int32))
                    if dict_coded:
                        d, r = tile
                        acc, bmax = ops.bitslice_chunk_score_multi_comp(
                            d, r, idx, mask, acc, word_block=word_block)
                    else:
                        acc, bmax = ops.bitslice_chunk_score_multi(
                            tile, idx, mask, acc, word_block=word_block)
                else:
                    # k>1: host-plan the chunk's unique row sets, gather
                    # and AND them on device out of the resident tile.
                    cells = rows[live]                    # [N, k]
                    uniq, inv = np.unique(cells, axis=0,
                                          return_inverse=True)
                    u_idx = np.zeros((_pad_unique(uniq.shape[0]), k),
                                     dtype=np.int32)
                    u_idx[: uniq.shape[0]] = uniq
                    if dict_coded:
                        d, r = tile
                        mat_dev = ops.gather_and_rows_comp(
                            d, r, jnp.asarray(u_idx))
                    else:
                        mat_dev = ops.gather_and_rows(tile,
                                                      jnp.asarray(u_idx))
                    indir = np.zeros((qc, nb, ct), dtype=np.int32)
                    indir[live] = np.asarray(inv).reshape(-1).astype(
                        np.int32)
                    acc, bmax = ops.bitslice_chunk_score_dedup(
                        mat_dev, jnp.asarray(indir), mask, acc,
                        word_block=word_block)
                stats.kernel_dispatches += 1
                if c < n_chunks - 1:
                    executed = np.minimum(nv, (c + 1) * ct).astype(np.int64)
                    remaining = nv.astype(np.int64) - executed
                    keep = (np.asarray(bmax).astype(np.int64)
                            + remaining[:, None]) >= req[:, None]
                    newly = alive & ~keep
                    stats.blocks_pruned += int(newly[:qn].sum())
                    alive &= keep
            out[sl, col0:col1] = np.asarray(
                ops.chunk_acc_scores(acc, W))[:qn]
        stats.shards_swept += 1
        if any_topk:
            # Completed-shard tightening: every accumulated count is a
            # lower bound on some doc's final score (unswept slots are 0,
            # pruned slots partial), so the k-th largest is a sound,
            # monotonically tightening cutoff for the remaining shards.
            ns = out.shape[1]
            for q in np.nonzero(is_topk)[0]:
                kq = int(topk[q])
                if ns >= kq > 0:
                    lb = int(np.partition(out[q], ns - kq)[ns - kq])
                    if lb > required[q]:
                        required[q] = lb
    return out, n_sh, required


def gather_rows(arena: jnp.ndarray, rows: jnp.ndarray, valid: jnp.ndarray
                ) -> jnp.ndarray:
    """Gather + AND + mask: (arena [R, Wb], rows int32 [L, k, nb],
    valid bool [L]) -> uint32 [L, nb * Wb]."""
    L, k, nb = rows.shape
    g = arena[rows]                               # [L, k, nb, Wb]
    anded = g[:, 0]
    for i in range(1, k):
        anded = anded & g[:, i]
    anded = jnp.where(valid[:, None, None], anded, jnp.uint32(0))
    return anded.reshape(L, nb * arena.shape[1])


def gather_rows_comp(dict_rows: jnp.ndarray, refs: jnp.ndarray,
                     rows: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """``gather_rows`` against a rowdict-compressed tile: the double gather
    ``dict_rows[refs[rows]]`` decodes on the fly — same AND + mask, same
    output, HBM traffic proportional to the dict instead of the tile."""
    L, k, nb = rows.shape
    g = dict_rows[refs[rows]]                     # [L, k, nb, Wb]
    anded = g[:, 0]
    for i in range(1, k):
        anded = anded & g[:, i]
    anded = jnp.where(valid[:, None, None], anded, jnp.uint32(0))
    return anded.reshape(L, nb * dict_rows.shape[1])


# --------------------------------------------------------------------------
# Scoring functions (built per-index: static n_hashes / method keeps the
# jit cache tidy)
# --------------------------------------------------------------------------

def make_score_fn(n_hashes: int, method: str = "vertical",
                  word_block: int | None = None,
                  term_block: int | None = None):
    """Returns score(arena, row_offset, block_width, terms [L,2], n_valid)
    -> int32 [n_slots] scores in slot order. ``word_block``/``term_block``
    override the kernel tile defaults (autotuner choices thread through
    here); None keeps the kernel defaults."""

    @jax.jit
    def score(arena, row_offset, block_width, terms, n_valid):
        L = terms.shape[0]
        h = hashing.hash_terms(terms, n_hashes)            # [L, k]
        rows = plan_rows(h, row_offset, block_width)       # [L, k, nb]
        valid = jnp.arange(L, dtype=jnp.int32) < n_valid
        if method == "lookup" and n_hashes == 1:
            # fused path (k=1): the gather happens inside the kernel.
            if row_offset.shape[0] == 1:
                return ops.bitslice_lookup_score(
                    arena, rows[:, 0, 0], valid.astype(jnp.int32),
                    word_block=word_block)
            idx = rows[:, 0, :].T                          # [nb, L]
            msk = jnp.broadcast_to(valid.astype(jnp.int32)[None, :],
                                   idx.shape)
            return ops.bitslice_lookup_score_blocks(arena, idx, msk,
                                                    word_block=word_block)
        flat = gather_rows(arena, rows, valid)             # [L, nb*Wb]
        return ops.bitslice_score(flat, method=method if method != "lookup"
                                  else "vertical", word_block=word_block,
                                  term_block=term_block)

    return score


def make_batch_score_fn(n_hashes: int, method: str = "vertical",
                        word_block: int | None = None,
                        term_block: int | None = None,
                        grid_order: str = "wq"):
    """Returns score(arena, row_offset, block_width, terms [Q,L,2],
    n_valid [Q]) -> int32 [Q, n_slots].

    method='lookup' with k=1 dispatches the whole batch to the fused
    multi-query kernel (one pallas_call, shared arena tiles) instead of
    vmapping — vmap cannot batch the scalar-prefetch gather, which is why
    the old engine silently fell back to the jnp ref scorer here. Other
    methods vmap the single-query scorer; 'lookup' with k>1 degrades to
    'vertical' (the AND over hash rows needs the materialized gather).

    ``word_block``/``term_block``/``grid_order`` are the autotuner's tile
    and grid knobs; defaults match the untuned kernels exactly.
    """
    if method == "lookup" and n_hashes == 1:
        @jax.jit
        def score_batch(arena, row_offset, block_width, terms, n_valid):
            Q, L = terms.shape[0], terms.shape[1]
            h = hashing.hash_terms(terms, n_hashes)        # [Q, L, 1]
            rows = plan_rows(h, row_offset, block_width)   # [Q, L, 1, nb]
            idx = jnp.swapaxes(rows[:, :, 0, :], 1, 2)     # [Q, nb, L]
            valid = (jnp.arange(L, dtype=jnp.int32)[None, :]
                     < n_valid[:, None])                   # [Q, L]
            msk = jnp.broadcast_to(valid.astype(jnp.int32)[:, None, :],
                                   idx.shape)
            return ops.bitslice_lookup_score_multi(arena, idx, msk,
                                                   word_block=word_block,
                                                   grid_order=grid_order)
        return score_batch

    inner = make_score_fn(
        n_hashes, "vertical" if method == "lookup" else method,
        word_block=word_block, term_block=term_block)
    return jax.jit(jax.vmap(inner, in_axes=(None, None, None, 0, 0)))


def make_comp_score_fn(n_hashes: int, method: str = "vertical",
                       word_block: int | None = None,
                       term_block: int | None = None):
    """Compressed twin of ``make_score_fn``: score(dict_rows, refs,
    row_offset, block_width, terms [L,2], n_valid) -> int32 [n_slots].

    The arena argument splits into the shard's dict + refs staged as-is on
    device; rows decode during the gather (in-kernel for the fused k=1
    lookup path, via the ``dict[refs[row]]`` double gather otherwise), so
    scores are bit-identical to the raw-tile scorer."""

    @jax.jit
    def score(dict_rows, refs, row_offset, block_width, terms, n_valid):
        L = terms.shape[0]
        h = hashing.hash_terms(terms, n_hashes)            # [L, k]
        rows = plan_rows(h, row_offset, block_width)       # [L, k, nb]
        valid = jnp.arange(L, dtype=jnp.int32) < n_valid
        if method == "lookup" and n_hashes == 1:
            idx = rows[:, 0, :].T                          # [nb, L]
            msk = jnp.broadcast_to(valid.astype(jnp.int32)[None, :],
                                   idx.shape)
            return ops.bitslice_lookup_score_blocks_comp(
                dict_rows, refs, idx, msk, word_block=word_block)
        flat = gather_rows_comp(dict_rows, refs, rows, valid)
        return ops.bitslice_score(flat, method=method if method != "lookup"
                                  else "vertical", word_block=word_block,
                                  term_block=term_block)

    return score


def make_comp_batch_score_fn(n_hashes: int, method: str = "vertical",
                             word_block: int | None = None,
                             term_block: int | None = None,
                             grid_order: str = "wq"):
    """Compressed twin of ``make_batch_score_fn``: score(dict_rows, refs,
    row_offset, block_width, terms [Q,L,2], n_valid [Q]) -> int32
    [Q, n_slots]. k=1 'lookup' dispatches the fused decode-in-the-loop
    multi-query kernel; other methods vmap the compressed single-query
    scorer (the decode is a jnp double gather, so vmap batches it fine)."""
    if method == "lookup" and n_hashes == 1:
        @jax.jit
        def score_batch(dict_rows, refs, row_offset, block_width,
                        terms, n_valid):
            Q, L = terms.shape[0], terms.shape[1]
            h = hashing.hash_terms(terms, n_hashes)        # [Q, L, 1]
            rows = plan_rows(h, row_offset, block_width)   # [Q, L, 1, nb]
            idx = jnp.swapaxes(rows[:, :, 0, :], 1, 2)     # [Q, nb, L]
            valid = (jnp.arange(L, dtype=jnp.int32)[None, :]
                     < n_valid[:, None])                   # [Q, L]
            msk = jnp.broadcast_to(valid.astype(jnp.int32)[:, None, :],
                                   idx.shape)
            return ops.bitslice_lookup_score_multi_comp(
                dict_rows, refs, idx, msk, word_block=word_block,
                grid_order=grid_order)
        return score_batch

    inner = make_comp_score_fn(
        n_hashes, "vertical" if method == "lookup" else method,
        word_block=word_block, term_block=term_block)
    return jax.jit(jax.vmap(inner, in_axes=(None, None, None, None, 0, 0)))


@dataclass
class SearchResult:
    """One query's reported documents, best-first.

    Fields:
        doc_ids:   int32 [n_hits] original document ids, descending score
                   (ties keep ascending-id order — the sort is stable).
        scores:    int32 [n_hits] q-gram containment scores, aligned with
                   ``doc_ids``; score <= n_terms, with one-sided Bloom
                   error (never below the true containment count).
        n_terms:   number of DISTINCT query q-grams (the paper's ell);
                   a full-containment hit has score == n_terms.
        threshold: the actual integer score cutoff applied: ceil(K * ell)
                   for ``search``/``search_batch``, the k-th best score
                   for ``top_k``, 0 for an empty result.
    """

    doc_ids: np.ndarray
    scores: np.ndarray
    n_terms: int
    threshold: int


class QueryEngine:
    """High-level search over a BitSlicedIndex.

    method: 'vertical' (default, Harley–Seal kernel), 'unpack'
    (paper-faithful kernel), 'lookup' (fused gather kernel, k=1 indexes),
    or 'ref' (pure jnp oracle).

    Dense storage (one shard) scores in one device call against the
    resident arena. Sharded storage scores shard by shard through
    ``tile_cache`` (default: an unbounded DeviceTileCache, so hot shards
    stay in HBM) and concatenates — bit-identical either way.

    ``compressed=True`` keeps dict-coded shards (codec 'rowdict' /
    'rowdict+rle') in their compressed (dict, refs) form on device and
    scores them through the fused-decode kernels; raw shards are
    unaffected. Results stay bit-identical — only the HBM working set and
    the per-row bandwidth change.
    """

    def __init__(self, index: BitSlicedIndex, method: str = "vertical",
                 term_pad: int = 64,
                 tile_cache: DeviceTileCache | None = None,
                 compressed: bool = False, prune_chunk: int = 32):
        self.index = index
        self.method = method
        self.term_pad = term_pad
        self.prune_chunk = prune_chunk
        self._score = make_score_fn(index.params.n_hashes, method)
        self._score_batch = make_batch_score_fn(index.params.n_hashes, method)
        self._paged = index.storage.n_shards > 1
        self.tiles = (tile_cache if tile_cache is not None
                      else DeviceTileCache(
                          index.storage,
                          pad_rows_to=common_tile_rows(index.storage)))
        self._shard_plans = plan_shards(index.layout,
                                        index.storage.shard_row_starts)
        # device-staged per-shard addressing (one H2D copy, not per query)
        self._shard_args = [(sp.shard, jnp.asarray(sp.row_offset),
                             jnp.asarray(sp.block_width))
                            for sp in self._shard_plans]
        self._host_slot = np.asarray(index.layout.doc_slot)
        self.compressed = bool(compressed) and any(
            index.storage.shard_codec(s) in _codec.DICT_CODECS
            for s in range(index.storage.n_shards))
        if self.compressed:
            self._score_comp = make_comp_score_fn(
                index.params.n_hashes, method)
            self._score_batch_comp = make_comp_batch_score_fn(
                index.params.n_hashes, method)

    # -- scoring -------------------------------------------------------------
    def _score_slots(self, padded: jnp.ndarray, L: jnp.ndarray) -> np.ndarray:
        if not self._paged:
            # tiles.get(0) caches the device copy for every backend
            # (a single-shard MappedArena would otherwise re-upload here)
            if self.compressed:
                dict_rows, refs = self.tiles.get_compressed(0)
                return np.asarray(self._score_comp(
                    dict_rows, refs, self.index.row_offset,
                    self.index.block_width, padded, L))
            return np.asarray(self._score(
                self.tiles.get(0), self.index.row_offset,
                self.index.block_width, padded, L))
        if self.compressed:
            return np.concatenate(run_paged_compressed(
                self.tiles, self._shard_args, self._score, self._score_comp,
                padded, L))
        return np.concatenate(
            run_paged(self.tiles, self._shard_args, self._score, padded, L))

    def _score_slots_batch(self, terms: jnp.ndarray, n_valid: jnp.ndarray
                           ) -> np.ndarray:
        if not self._paged:
            if self.compressed:
                dict_rows, refs = self.tiles.get_compressed(0)
                return np.asarray(self._score_batch_comp(
                    dict_rows, refs, self.index.row_offset,
                    self.index.block_width, terms, n_valid))
            return np.asarray(self._score_batch(
                self.tiles.get(0), self.index.row_offset,
                self.index.block_width, terms, n_valid))
        if self.compressed:
            return np.concatenate(run_paged_compressed(
                self.tiles, self._shard_args, self._score_batch,
                self._score_batch_comp, terms, n_valid), axis=1)
        return np.concatenate(
            run_paged(self.tiles, self._shard_args, self._score_batch,
                      terms, n_valid), axis=1)

    def score_terms(self, terms: np.ndarray) -> np.ndarray:
        """Distinct packed terms [L, 2] -> int32 scores [n_docs] (original
        document order)."""
        padded, L = pad_terms(terms, self.term_pad)
        slots = self._score_slots(jnp.asarray(padded), jnp.int32(L))
        return slots[self._host_slot]

    def score_terms_batch(self, terms: np.ndarray, n_valid: np.ndarray
                          ) -> np.ndarray:
        """terms [Q, L, 2], n_valid [Q] -> scores [Q, n_docs]."""
        slots = self._score_slots_batch(
            jnp.asarray(terms), jnp.asarray(n_valid, dtype=jnp.int32))
        return slots[:, self._host_slot]

    # -- search --------------------------------------------------------------
    def search(self, pattern, threshold: float = 0.8) -> SearchResult:
        """pattern: DNA string or uint8 code array. Reports every document
        whose q-gram score is >= ceil(threshold * ell), best first."""
        terms = compile_pattern(pattern, self.index.params)
        if terms.shape[0] == 0:
            return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
        scores = self.score_terms(terms)
        return select_hits(scores, terms.shape[0], threshold)

    def search_batch(self, patterns: list, threshold: float = 0.8
                     ) -> list[SearchResult]:
        """Batched search with shared padding (the paper's bulk queries)."""
        term_sets = [compile_pattern(p, self.index.params) for p in patterns]
        buf, ells = pad_term_batch(term_sets, self.term_pad)
        scores = self.score_terms_batch(buf, ells)
        return [select_hits(scores[i], int(ell), threshold)
                for i, ell in enumerate(ells)]

    def top_k(self, pattern, k: int = 10) -> SearchResult:
        """Rank documents by q-gram score, return the top k (paper's partial
        sort selection). ``threshold`` reports the k-th best score."""
        terms = compile_pattern(pattern, self.index.params)
        if terms.shape[0] == 0:
            return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
        scores = self.score_terms(terms)
        return select_top_k(scores, terms.shape[0], k)

    # -- pruned search (branch-and-bound over the coverage cutoff) -----------
    def _pruned_doc_scores(self, term_sets: list[np.ndarray],
                           required: np.ndarray, topk: np.ndarray,
                           stats: PruneStats | None) -> np.ndarray:
        buf, ells = pad_term_batch(term_sets, self.term_pad)
        slots = run_paged_pruned(
            self.tiles, self._shard_plans, buf, ells, required, topk,
            n_hashes=self.index.params.n_hashes,
            chunk_terms=self.prune_chunk, stats=stats)
        return slots[:, self._host_slot]

    def search_pruned(self, pattern, threshold: float = 0.8,
                      stats: PruneStats | None = None) -> SearchResult:
        """``search`` through the pruned executor — bit-identical results,
        arena I/O and kernel work scaled down by the threshold's kill rate
        (``stats`` receives the accounting)."""
        return self.search_batch_pruned([pattern], threshold, stats=stats)[0]

    def search_batch_pruned(self, patterns: list, threshold: float = 0.8,
                            stats: PruneStats | None = None
                            ) -> list[SearchResult]:
        """Batched ``search_batch`` twin of ``search_pruned``."""
        term_sets = [compile_pattern(p, self.index.params) for p in patterns]
        required = np.array([coverage_cutoff(threshold, t.shape[0])
                             for t in term_sets], dtype=np.int64)
        topk = np.zeros(len(term_sets), dtype=np.int32)
        scores = self._pruned_doc_scores(term_sets, required, topk, stats)
        return [select_hits(scores[i], int(t.shape[0]), threshold)
                for i, t in enumerate(term_sets)]

    def top_k_pruned(self, pattern, k: int = 10,
                     stats: PruneStats | None = None) -> SearchResult:
        """``top_k`` through the pruned executor: the cutoff tightens to
        the merged k-th largest running count as chunks accumulate, so
        blocks provably outside the final top-k stop being scored."""
        terms = compile_pattern(pattern, self.index.params)
        if terms.shape[0] == 0:
            return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
        scores = self._pruned_doc_scores(
            [terms], np.zeros(1, np.int64), np.array([k], np.int32), stats)
        return select_top_k(scores[0], terms.shape[0], k)
