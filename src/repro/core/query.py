"""Query processing (paper Fig. 3): HASH -> GATHER rows -> AND -> ADD -> select.

The engine consumes packed terms (uint32 [L, 2]) with a validity count,
produces per-document scores, and applies the coverage threshold K — the
fraction of the query's distinct q-grams that must hit a document for it to
be reported. Single queries and padded batches are supported; scoring runs
through the Pallas kernels (repro.kernels.ops) with a pure-jnp method for
oracle comparisons.

Distribution (mesh-sharded arenas, psum'd partial scores, distributed top-k)
lives in repro.index.distributed and reuses the same planning functions.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import dna, hashing
from .index import BitSlicedIndex
from ..kernels import ops


def plan_rows(
    hashes: jnp.ndarray, row_offset: jnp.ndarray, block_width: jnp.ndarray
) -> jnp.ndarray:
    """Map term hashes to arena rows, per block.

    hashes: uint32 [..., k]; returns int32 [..., k, n_blocks] — the paper's
    'large output range then modulo per sub-index' addressing."""
    w = block_width.astype(jnp.uint32)
    rows = hashes[..., None] % w
    return (rows + row_offset.astype(jnp.uint32)).astype(jnp.int32)


def gather_rows(arena: jnp.ndarray, rows: jnp.ndarray, valid: jnp.ndarray
                ) -> jnp.ndarray:
    """Gather + AND + mask: (arena [R, Wb], rows int32 [L, k, nb],
    valid bool [L]) -> uint32 [L, nb * Wb]."""
    L, k, nb = rows.shape
    g = arena[rows]                               # [L, k, nb, Wb]
    anded = g[:, 0]
    for i in range(1, k):
        anded = anded & g[:, i]
    anded = jnp.where(valid[:, None, None], anded, jnp.uint32(0))
    return anded.reshape(L, nb * arena.shape[1])


# The scoring function is built per-index (static n_hashes / method) to keep
# the jit cache tidy.
def make_score_fn(n_hashes: int, method: str = "vertical"):
    """Returns score(arena, row_offset, block_width, terms [L,2], n_valid)
    -> int32 [n_slots] scores in slot order."""

    @jax.jit
    def score(arena, row_offset, block_width, terms, n_valid):
        L = terms.shape[0]
        h = hashing.hash_terms(terms, n_hashes)            # [L, k]
        rows = plan_rows(h, row_offset, block_width)       # [L, k, nb]
        valid = jnp.arange(L, dtype=jnp.int32) < n_valid
        if method == "lookup" and n_hashes == 1 and row_offset.shape[0] == 1:
            # fused path: single block, k=1 — gather happens inside the kernel
            return ops.bitslice_lookup_score(
                arena, rows[:, 0, 0], valid.astype(jnp.int32))
        flat = gather_rows(arena, rows, valid)             # [L, nb*Wb]
        return ops.bitslice_score(flat, method=method if method != "lookup"
                                  else "vertical")

    return score


@dataclass
class SearchResult:
    doc_ids: np.ndarray    # int32, descending score
    scores: np.ndarray     # int32, aligned with doc_ids
    n_terms: int           # distinct query terms ell
    threshold: int         # score cut-off applied


class QueryEngine:
    """High-level search over a BitSlicedIndex.

    method: 'vertical' (default, Harley–Seal kernel), 'unpack'
    (paper-faithful kernel), 'lookup' (fused gather kernel, classic/k=1
    indexes), or 'ref' (pure jnp oracle).
    """

    def __init__(self, index: BitSlicedIndex, method: str = "vertical",
                 term_pad: int = 64):
        self.index = index
        self.method = method
        self.term_pad = term_pad
        self._score = make_score_fn(index.params.n_hashes, method)
        batch_inner = make_score_fn(
            index.params.n_hashes, "ref" if method == "lookup" else method)
        self._score_batch = jax.jit(
            jax.vmap(batch_inner, in_axes=(None, None, None, 0, 0)))

    # -- scoring -------------------------------------------------------------
    def _pad_terms(self, terms: np.ndarray) -> tuple[np.ndarray, int]:
        L = terms.shape[0]
        pad = max(self.term_pad,
                  ((L + self.term_pad - 1) // self.term_pad) * self.term_pad)
        out = np.zeros((pad, 2), dtype=np.uint32)
        out[:L] = terms
        return out, L

    def score_terms(self, terms: np.ndarray) -> np.ndarray:
        """Distinct packed terms [L, 2] -> int32 scores [n_docs] (original
        document order)."""
        padded, L = self._pad_terms(terms)
        slots = self._score(self.index.arena, self.index.row_offset,
                            self.index.block_width, jnp.asarray(padded),
                            jnp.int32(L))
        return np.asarray(slots)[np.asarray(self.index.doc_slot)]

    def score_terms_batch(self, terms: np.ndarray, n_valid: np.ndarray
                          ) -> np.ndarray:
        """terms [Q, L, 2], n_valid [Q] -> scores [Q, n_docs]."""
        slots = self._score_batch(self.index.arena, self.index.row_offset,
                                  self.index.block_width, jnp.asarray(terms),
                                  jnp.asarray(n_valid, dtype=jnp.int32))
        return np.asarray(slots)[:, np.asarray(self.index.doc_slot)]

    # -- search --------------------------------------------------------------
    def search(self, pattern, threshold: float = 0.8) -> SearchResult:
        """pattern: DNA string or uint8 code array. Reports every document
        whose q-gram score is >= ceil(threshold * ell), best first."""
        codes = dna.encode_dna(pattern) if isinstance(pattern, str) else pattern
        terms = dna.unique_terms(
            dna.pack_kmers(codes, self.index.params.kmer,
                           self.index.params.canonical))
        ell = terms.shape[0]
        if ell == 0:
            return SearchResult(np.zeros(0, np.int32), np.zeros(0, np.int32), 0, 0)
        scores = self.score_terms(terms)
        cut = max(1, math.ceil(threshold * ell))
        hits = np.nonzero(scores >= cut)[0]
        order = np.argsort(-scores[hits], kind="stable")
        return SearchResult(hits[order].astype(np.int32),
                            scores[hits][order].astype(np.int32), ell, cut)

    def search_batch(self, patterns: list, threshold: float = 0.8
                     ) -> list[SearchResult]:
        """Batched search with shared padding (the paper's bulk queries)."""
        term_sets = []
        for p in patterns:
            codes = dna.encode_dna(p) if isinstance(p, str) else p
            term_sets.append(dna.unique_terms(
                dna.pack_kmers(codes, self.index.params.kmer,
                               self.index.params.canonical)))
        ells = np.array([t.shape[0] for t in term_sets], dtype=np.int32)
        pad = max(self.term_pad,
                  ((int(ells.max(initial=1)) + self.term_pad - 1)
                   // self.term_pad) * self.term_pad)
        buf = np.zeros((len(patterns), pad, 2), dtype=np.uint32)
        for i, t in enumerate(term_sets):
            buf[i, : t.shape[0]] = t
        scores = self.score_terms_batch(buf, ells)
        results = []
        for i, ell in enumerate(ells):
            if ell == 0:
                results.append(SearchResult(np.zeros(0, np.int32),
                                            np.zeros(0, np.int32), 0, 0))
                continue
            cut = max(1, math.ceil(threshold * int(ell)))
            hits = np.nonzero(scores[i] >= cut)[0]
            order = np.argsort(-scores[i][hits], kind="stable")
            results.append(SearchResult(hits[order].astype(np.int32),
                                        scores[i][hits][order].astype(np.int32),
                                        int(ell), cut))
        return results

    def top_k(self, pattern, k: int = 10) -> SearchResult:
        """Rank documents by q-gram score, return the top k (paper's partial
        sort selection)."""
        codes = dna.encode_dna(pattern) if isinstance(pattern, str) else pattern
        terms = dna.unique_terms(
            dna.pack_kmers(codes, self.index.params.kmer,
                           self.index.params.canonical))
        scores = self.score_terms(terms)
        k = min(k, scores.shape[0])
        part = np.argpartition(-scores, k - 1)[:k]
        order = part[np.argsort(-scores[part], kind="stable")]
        return SearchResult(order.astype(np.int32),
                            scores[order].astype(np.int32),
                            terms.shape[0], 0)
