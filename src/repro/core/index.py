"""The COBS data structure: classic (ClaBS) and compact bit-sliced indexes.

Unified *arena* representation (TPU adaptation of the paper's concatenated
sub-index files): all sub-index blocks share the same document-word width
(block_docs // 32) and are stacked along the row axis into one uint32 arena

    arena : uint32 [total_rows, block_docs // 32]

with per-block row offsets and filter widths. A classic index is the special
case of a single block whose width covers the largest document — exactly the
ClaBS/BIGSI layout. Query row addressing for term t in block b is

    row(t, b) = row_offset[b] + hash(t) % w_b[b]

i.e. the paper's 'one hash function with a larger output range + modulo'.

Since the out-of-core refactor the index is a pair (ArenaLayout, storage):
the layout (repro.core.arena.ArenaLayout) is pure metadata, and the arena
bytes live behind a pluggable ArenaStorage — dense on device (DeviceArena),
dense on host (HostArena), or paged per-shard from disk (MappedArena over a
cobs-jax-v2 store, repro.core.store). ``index.arena`` still yields the
dense device array for legacy callers; shard-aware paths (QueryEngine,
repro.serve) address storage shards directly and never materialize it.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import bloom, theory
from .arena import (ArenaLayout, ArenaStorage, DeviceArena, HostArena,
                    MappedArena, wrap_arena)

DEFAULT_FPR = 0.3      # paper section 2.1: high FPR is optimal for this workload
DEFAULT_HASHES = 1     # paper: k = 1 minimizes cache faults / IOs
DEFAULT_KMER = 31      # microbial genomics standard


@dataclasses.dataclass(frozen=True)
class IndexParams:
    n_hashes: int = DEFAULT_HASHES
    fpr: float = DEFAULT_FPR
    kmer: int = DEFAULT_KMER
    canonical: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "IndexParams":
        return IndexParams(**d)


@jax.tree_util.register_pytree_node_class
class BitSlicedIndex:
    """Arena-layout bit-sliced signature index (classic or compact).

    Thin composition of ``layout`` (ArenaLayout metadata) and ``storage``
    (ArenaStorage bytes) plus the Bloom parameters. The historical flat
    constructor / attribute surface (arena, row_offset, block_width,
    doc_slot, doc_n_terms, block_docs, n_docs, params) is preserved:
    metadata attributes come back as cached device arrays and ``arena``
    materializes the dense device arena from whatever backend is attached.
    """

    def __init__(self, arena=None, row_offset=None, block_width=None,
                 doc_slot=None, doc_n_terms=None, block_docs: int = 0,
                 n_docs: int = 0, params: IndexParams | None = None, *,
                 layout: ArenaLayout | None = None,
                 storage: ArenaStorage | None = None):
        if layout is None:
            layout = ArenaLayout.make(row_offset, block_width, doc_slot,
                                      doc_n_terms, block_docs, n_docs)
        if storage is None:
            storage = wrap_arena(arena)
        self.layout = layout
        self.storage = storage
        self.params = params if params is not None else IndexParams()
        self._device_meta: dict[str, jnp.ndarray] = {}

    # -- pytree protocol (arrays are leaves; the rest is static aux). NOTE:
    # flattening materializes the dense arena — it exists for legacy
    # device_put/tree_map paths and is not the out-of-core route. ---------
    def tree_flatten(self):
        leaves = (self.arena, self.row_offset, self.block_width,
                  self.doc_slot, self.doc_n_terms)
        aux = (self.block_docs, self.n_docs, self.params)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    # -- legacy flat attribute surface --------------------------------------
    @property
    def arena(self) -> jnp.ndarray:
        """Dense device arena (materialized on demand for mapped storage)."""
        return self.storage.full_device()

    def _meta(self, name: str) -> jnp.ndarray:
        a = self._device_meta.get(name)
        if a is None:
            a = jnp.asarray(getattr(self.layout, name))
            self._device_meta[name] = a
        return a

    @property
    def row_offset(self) -> jnp.ndarray:
        return self._meta("row_offset")

    @property
    def block_width(self) -> jnp.ndarray:
        return self._meta("block_width")

    @property
    def doc_slot(self) -> jnp.ndarray:
        return self._meta("doc_slot")

    @property
    def doc_n_terms(self) -> jnp.ndarray:
        return self._meta("doc_n_terms")

    @property
    def block_docs(self) -> int:
        return self.layout.block_docs

    @property
    def n_docs(self) -> int:
        return self.layout.n_docs

    # -- derived properties -------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.layout.n_blocks

    @property
    def doc_words(self) -> int:
        return self.layout.doc_words

    @property
    def total_rows(self) -> int:
        return self.layout.total_rows

    @property
    def n_slots(self) -> int:
        return self.layout.n_slots

    def size_bytes(self) -> int:
        return self.storage.nbytes()

    def expected_fpr(self) -> np.ndarray:
        """Per-document analytic FPR given actual block widths (tests compare
        this to measured rates)."""
        w_b = self.layout.block_width
        slots = self.layout.doc_slot
        widths = w_b[slots // self.block_docs]
        v = self.layout.doc_n_terms
        return np.array(
            [theory.bloom_fpr(int(w), self.params.n_hashes, int(n))
             for w, n in zip(widths, v)]
        )


def _pad32(n: int) -> int:
    return ((n + 31) // 32) * 32


def plan_compact_layout(
    counts: np.ndarray,
    params: IndexParams,
    block_docs: int,
    row_align: int = bloom.ROW_ALIGN,
) -> tuple[ArenaLayout, np.ndarray]:
    """The pure planning half of a compact build: document order, block
    widths, and row offsets from term counts alone. Returns (layout, order)
    where order[j] is the original doc id at slot j — the builder's work
    list. Shared by the dense, parallel, and streaming builders so their
    outputs are bit-identical by construction."""
    n_docs = counts.shape[0]
    block_docs = _pad32(block_docs)
    order = np.argsort(counts, kind="stable")          # ascending by size
    doc_slot = np.empty(n_docs, dtype=np.int32)
    doc_slot[order] = np.arange(n_docs, dtype=np.int32)

    n_blocks = (n_docs + block_docs - 1) // block_docs
    widths = np.empty(n_blocks, dtype=np.int32)
    for b in range(n_blocks):
        ids = order[b * block_docs:(b + 1) * block_docs]
        v_max = int(counts[ids].max()) if ids.size else 0
        widths[b] = bloom.aligned_width(
            theory.bloom_size(max(v_max, 1), params.fpr, params.n_hashes),
            row_align)
    offsets = np.concatenate([[0], np.cumsum(widths)[:-1]]).astype(np.int32)
    layout = ArenaLayout.make(offsets, widths, doc_slot,
                              counts.astype(np.int32), block_docs, n_docs)
    return layout, order


def build_compact(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    block_docs: int = 1024,
    row_align: int = bloom.ROW_ALIGN,
) -> BitSlicedIndex:
    """COBS compact build: sort documents by size, block into groups of
    ``block_docs``, size each block's filter for its largest member."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    layout, order = plan_compact_layout(counts, params, block_docs, row_align)
    blocks = []
    for b in range(layout.n_blocks):
        ids = order[b * layout.block_docs:(b + 1) * layout.block_docs]
        blocks.append(bloom.build_block_matrix(
            [doc_terms[i] for i in ids], int(layout.block_width[b]),
            params.n_hashes, layout.block_docs))
    return BitSlicedIndex(
        layout=layout,
        storage=DeviceArena(jnp.asarray(np.concatenate(blocks, axis=0))),
        params=params,
    )


def build_classic(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    row_align: int = bloom.ROW_ALIGN,
) -> BitSlicedIndex:
    """ClaBS/BIGSI build: one uniform filter width sized for the LARGEST
    document (the layout whose waste motivates compaction, Fig. 4)."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    v_max = int(counts.max())
    w = bloom.aligned_width(
        theory.bloom_size(max(v_max, 1), params.fpr, params.n_hashes), row_align)
    block_docs = _pad32(n_docs)
    matrix = bloom.build_block_matrix(list(doc_terms), w, params.n_hashes, block_docs)
    layout = ArenaLayout.make(
        np.zeros(1, np.int32), np.full(1, w, np.int32),
        np.arange(n_docs, dtype=np.int32), counts.astype(np.int32),
        block_docs, n_docs)
    return BitSlicedIndex(layout=layout,
                          storage=DeviceArena(jnp.asarray(matrix)),
                          params=params)


def merge_classic(a: BitSlicedIndex, b: BitSlicedIndex) -> BitSlicedIndex:
    """Merge two classic indexes built with identical parameters and widths
    (paper section 2.3: 'classic indexes with the same parameters can be
    concatenated straightforwardly'). Column (document-axis) concatenation
    is the one merge that must touch bytes: rows interleave, so the merged
    arena is rebuilt dense from the sources' host shards."""
    if a.n_blocks != 1 or b.n_blocks != 1:
        raise ValueError("merge_classic only merges classic (single-block) indexes")
    if int(a.layout.block_width[0]) != int(b.layout.block_width[0]) \
            or a.params != b.params:
        raise ValueError("parameter mismatch")
    arena = jnp.concatenate([jnp.asarray(a.storage.full_host()),
                             jnp.asarray(b.storage.full_host())], axis=1)
    layout = ArenaLayout.make(
        a.layout.row_offset, a.layout.block_width,
        np.concatenate([a.layout.doc_slot,
                        b.layout.doc_slot + a.block_docs]),
        np.concatenate([a.layout.doc_n_terms, b.layout.doc_n_terms]),
        a.block_docs + b.block_docs, a.n_docs + b.n_docs)
    return BitSlicedIndex(layout=layout, storage=DeviceArena(arena),
                          params=a.params)


def merge_compact_layout(a: ArenaLayout, b: ArenaLayout) -> ArenaLayout:
    """Pure metadata half of the compact merge: blocks append along the row
    axis, b's slots shift by a's slot capacity."""
    if a.block_docs != b.block_docs:
        raise ValueError("block_docs mismatch")
    return ArenaLayout.make(
        np.concatenate([a.row_offset, b.row_offset + a.total_rows]),
        np.concatenate([a.block_width, b.block_width]),
        np.concatenate([a.doc_slot, b.doc_slot + a.n_slots]),
        np.concatenate([a.doc_n_terms, b.doc_n_terms]),
        a.block_docs, a.n_docs + b.n_docs)


def merge_compact(a: BitSlicedIndex, b: BitSlicedIndex) -> BitSlicedIndex:
    """Merge two COMPACT indexes without rebuilding (the paper's future-work
    item, section 2.3/4): sub-index blocks are independent, so the merged
    index is simply the concatenation of both block lists along the row
    axis — b's documents keep their own blocks, slots shift by a's slot
    capacity. Requires identical params and block_docs. Size optimality of
    the global staircase is not re-established (documents are only sorted
    within each source index); queries are exact either way.

    On the split layout this is O(metadata): when either side is sharded
    (or host/mapped) the merged storage is just the two shard lists back
    to back — no arena bytes are read or copied. Two dense device arenas
    keep the historical dense concatenation."""
    if a.params != b.params:
        raise ValueError("parameter mismatch")
    layout = merge_compact_layout(a.layout, b.layout)
    if isinstance(a.storage, DeviceArena) and isinstance(b.storage, DeviceArena):
        storage: ArenaStorage = DeviceArena(
            jnp.concatenate([a.storage.full_device(),
                             b.storage.full_device()], axis=0))
    else:
        storage = MappedArena.concat(a.storage, b.storage)
    return BitSlicedIndex(layout=layout, storage=storage, params=a.params)


# --------------------------------------------------------------------------
# Persistence. Two on-disk formats:
#   cobs-jax-v1 — JSON manifest + one compressed npz monolith (legacy;
#                 loading materializes the whole arena on host).
#   cobs-jax-v2 — JSON manifest + one raw .npy shard per block group
#                 (repro.core.store): loads as an np.memmap-backed
#                 MappedArena, so opening an index costs metadata only.
# ``save_index`` keeps writing v1 for compatibility (version=2 opts in);
# ``load_index`` dispatches on the manifest.
# --------------------------------------------------------------------------

def save_index(index: BitSlicedIndex, path: str | Path, *,
               version: int = 1, blocks_per_shard: int = 1) -> None:
    if version == 2:
        from . import store
        store.save_index_v2(index, path, blocks_per_shard=blocks_per_shard)
        return
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        path / "index.npz",
        arena=index.storage.full_host(),
        row_offset=index.layout.row_offset,
        block_width=index.layout.block_width,
        doc_slot=index.layout.doc_slot,
        doc_n_terms=index.layout.doc_n_terms,
    )
    manifest = {
        "format": "cobs-jax-v1",
        "block_docs": index.block_docs,
        "n_docs": index.n_docs,
        "params": index.params.to_json(),
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=2))


def load_index(path: str | Path) -> BitSlicedIndex:
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    fmt = manifest.get("format")
    if fmt == "cobs-jax-v2":
        from . import store
        return store.load_index_v2(path)
    if fmt != "cobs-jax-v1":
        raise ValueError(f"unknown index format in {path}")
    with np.load(path / "index.npz") as z:
        layout = ArenaLayout.make(
            z["row_offset"], z["block_width"], z["doc_slot"],
            z["doc_n_terms"], int(manifest["block_docs"]),
            int(manifest["n_docs"]))
        return BitSlicedIndex(
            layout=layout,
            storage=DeviceArena(jnp.asarray(z["arena"])),
            params=IndexParams.from_json(manifest["params"]),
        )
