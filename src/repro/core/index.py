"""The COBS data structure: classic (ClaBS) and compact bit-sliced indexes.

Unified *arena* representation (TPU adaptation of the paper's concatenated
sub-index files): all sub-index blocks share the same document-word width
(block_docs // 32) and are stacked along the row axis into one uint32 arena

    arena : uint32 [total_rows, block_docs // 32]

with per-block row offsets and filter widths. A classic index is the special
case of a single block whose width covers the largest document — exactly the
ClaBS/BIGSI layout. Query row addressing for term t in block b is

    row(t, b) = row_offset[b] + hash(t) % w_b[b]

i.e. the paper's 'one hash function with a larger output range + modulo'.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import bloom, theory

DEFAULT_FPR = 0.3      # paper section 2.1: high FPR is optimal for this workload
DEFAULT_HASHES = 1     # paper: k = 1 minimizes cache faults / IOs
DEFAULT_KMER = 31      # microbial genomics standard


@dataclasses.dataclass(frozen=True)
class IndexParams:
    n_hashes: int = DEFAULT_HASHES
    fpr: float = DEFAULT_FPR
    kmer: int = DEFAULT_KMER
    canonical: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "IndexParams":
        return IndexParams(**d)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitSlicedIndex:
    """Arena-layout bit-sliced signature index (classic or compact)."""

    arena: jnp.ndarray       # uint32 [total_rows, block_docs // 32]
    row_offset: jnp.ndarray  # int32  [n_blocks]
    block_width: jnp.ndarray # int32  [n_blocks]  (w_b, filter width per block)
    doc_slot: jnp.ndarray    # int32  [n_docs]    slot of original doc i
    doc_n_terms: jnp.ndarray # int32  [n_docs]
    block_docs: int          # docs per block (multiple of 32)
    n_docs: int
    params: IndexParams

    # -- pytree protocol (arrays are leaves; the rest is static aux) --------
    def tree_flatten(self):
        leaves = (self.arena, self.row_offset, self.block_width,
                  self.doc_slot, self.doc_n_terms)
        aux = (self.block_docs, self.n_docs, self.params)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    # -- derived properties -------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return int(self.row_offset.shape[0])

    @property
    def doc_words(self) -> int:
        return int(self.arena.shape[1])

    @property
    def total_rows(self) -> int:
        return int(self.arena.shape[0])

    @property
    def n_slots(self) -> int:
        return self.n_blocks * self.block_docs

    def size_bytes(self) -> int:
        return int(self.arena.size) * 4

    def expected_fpr(self) -> np.ndarray:
        """Per-document analytic FPR given actual block widths (tests compare
        this to measured rates)."""
        w_b = np.asarray(self.block_width)
        slots = np.asarray(self.doc_slot)
        widths = w_b[slots // self.block_docs]
        v = np.asarray(self.doc_n_terms)
        return np.array(
            [theory.bloom_fpr(int(w), self.params.n_hashes, int(n))
             for w, n in zip(widths, v)]
        )


def _pad32(n: int) -> int:
    return ((n + 31) // 32) * 32


def build_compact(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    block_docs: int = 1024,
    row_align: int = bloom.ROW_ALIGN,
) -> BitSlicedIndex:
    """COBS compact build: sort documents by size, block into groups of
    ``block_docs``, size each block's filter for its largest member."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    block_docs = _pad32(block_docs)
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    order = np.argsort(counts, kind="stable")          # ascending by size
    doc_slot = np.empty(n_docs, dtype=np.int32)
    doc_slot[order] = np.arange(n_docs, dtype=np.int32)

    n_blocks = (n_docs + block_docs - 1) // block_docs
    blocks, widths, offsets = [], [], []
    off = 0
    for b in range(n_blocks):
        ids = order[b * block_docs:(b + 1) * block_docs]
        v_max = int(counts[ids].max()) if ids.size else 0
        w = bloom.aligned_width(
            theory.bloom_size(max(v_max, 1), params.fpr, params.n_hashes), row_align)
        blocks.append(bloom.build_block_matrix(
            [doc_terms[i] for i in ids], w, params.n_hashes, block_docs))
        widths.append(w)
        offsets.append(off)
        off += w

    return BitSlicedIndex(
        arena=jnp.asarray(np.concatenate(blocks, axis=0)),
        row_offset=jnp.asarray(np.array(offsets, dtype=np.int32)),
        block_width=jnp.asarray(np.array(widths, dtype=np.int32)),
        doc_slot=jnp.asarray(doc_slot),
        doc_n_terms=jnp.asarray(counts.astype(np.int32)),
        block_docs=block_docs,
        n_docs=n_docs,
        params=params,
    )


def build_classic(
    doc_terms: list[np.ndarray],
    params: IndexParams = IndexParams(),
    row_align: int = bloom.ROW_ALIGN,
) -> BitSlicedIndex:
    """ClaBS/BIGSI build: one uniform filter width sized for the LARGEST
    document (the layout whose waste motivates compaction, Fig. 4)."""
    n_docs = len(doc_terms)
    if n_docs == 0:
        raise ValueError("empty document set")
    counts = np.array([t.shape[0] for t in doc_terms], dtype=np.int64)
    v_max = int(counts.max())
    w = bloom.aligned_width(
        theory.bloom_size(max(v_max, 1), params.fpr, params.n_hashes), row_align)
    block_docs = _pad32(n_docs)
    matrix = bloom.build_block_matrix(list(doc_terms), w, params.n_hashes, block_docs)
    return BitSlicedIndex(
        arena=jnp.asarray(matrix),
        row_offset=jnp.zeros((1,), dtype=jnp.int32),
        block_width=jnp.full((1,), w, dtype=jnp.int32),
        doc_slot=jnp.arange(n_docs, dtype=jnp.int32),
        doc_n_terms=jnp.asarray(counts.astype(np.int32)),
        block_docs=block_docs,
        n_docs=n_docs,
        params=params,
    )


def merge_classic(a: BitSlicedIndex, b: BitSlicedIndex) -> BitSlicedIndex:
    """Merge two classic indexes built with identical parameters and widths
    (paper section 2.3: 'classic indexes with the same parameters can be
    concatenated straightforwardly')."""
    if a.n_blocks != 1 or b.n_blocks != 1:
        raise ValueError("merge_classic only merges classic (single-block) indexes")
    if int(a.block_width[0]) != int(b.block_width[0]) or a.params != b.params:
        raise ValueError("parameter mismatch")
    arena = jnp.concatenate([a.arena, b.arena], axis=1)
    return BitSlicedIndex(
        arena=arena,
        row_offset=a.row_offset,
        block_width=a.block_width,
        doc_slot=jnp.concatenate([a.doc_slot, b.doc_slot + a.block_docs]),
        doc_n_terms=jnp.concatenate([a.doc_n_terms, b.doc_n_terms]),
        block_docs=a.block_docs + b.block_docs,
        n_docs=a.n_docs + b.n_docs,
        params=a.params,
    )


def merge_compact(a: BitSlicedIndex, b: BitSlicedIndex) -> BitSlicedIndex:
    """Merge two COMPACT indexes without rebuilding (the paper's future-work
    item, section 2.3/4): sub-index blocks are independent, so the merged
    index is simply the concatenation of both block lists along the row
    axis — b's documents keep their own blocks, slots shift by a's slot
    capacity. Requires identical params and block_docs. Size optimality of
    the global staircase is not re-established (documents are only sorted
    within each source index); queries are exact either way."""
    if a.params != b.params:
        raise ValueError("parameter mismatch")
    if a.block_docs != b.block_docs:
        raise ValueError("block_docs mismatch")
    return BitSlicedIndex(
        arena=jnp.concatenate([a.arena, b.arena], axis=0),
        row_offset=jnp.concatenate(
            [a.row_offset, b.row_offset + a.total_rows]),
        block_width=jnp.concatenate([a.block_width, b.block_width]),
        doc_slot=jnp.concatenate([a.doc_slot, b.doc_slot + a.n_slots]),
        doc_n_terms=jnp.concatenate([a.doc_n_terms, b.doc_n_terms]),
        block_docs=a.block_docs,
        n_docs=a.n_docs + b.n_docs,
        params=a.params,
    )


# --------------------------------------------------------------------------
# Persistence: a directory with a JSON manifest + npz payload. This is the
# single-host flavour; sharded checkpointing lives in repro.checkpoint.
# --------------------------------------------------------------------------

def save_index(index: BitSlicedIndex, path: str | Path) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        path / "index.npz",
        arena=np.asarray(index.arena),
        row_offset=np.asarray(index.row_offset),
        block_width=np.asarray(index.block_width),
        doc_slot=np.asarray(index.doc_slot),
        doc_n_terms=np.asarray(index.doc_n_terms),
    )
    manifest = {
        "format": "cobs-jax-v1",
        "block_docs": index.block_docs,
        "n_docs": index.n_docs,
        "params": index.params.to_json(),
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=2))


def load_index(path: str | Path) -> BitSlicedIndex:
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    if manifest.get("format") != "cobs-jax-v1":
        raise ValueError(f"unknown index format in {path}")
    with np.load(path / "index.npz") as z:
        return BitSlicedIndex(
            arena=jnp.asarray(z["arena"]),
            row_offset=jnp.asarray(z["row_offset"]),
            block_width=jnp.asarray(z["block_width"]),
            doc_slot=jnp.asarray(z["doc_slot"]),
            doc_n_terms=jnp.asarray(z["doc_n_terms"]),
            block_docs=int(manifest["block_docs"]),
            n_docs=int(manifest["n_docs"]),
            params=IndexParams.from_json(manifest["params"]),
        )
