"""cobs-jax-v2: the out-of-core, shard-per-block-group index directory.

Layout on disk::

    <path>/
      manifest.json            format, params, layout metadata, shard table
      meta.npz                 row_offset / block_width / doc_slot / doc_n_terms
      shard-000000.npy         raw (uncompressed) .npy — mmap-able
      shard-000001.npy         ...

Each shard holds the arena rows of one *block group* (``blocks_per_shard``
consecutive blocks; 1 by default, i.e. shard-per-block). The manifest's
shard table records, per shard, the file name, block range, row range, and
a blake2b content hash — so an opened store can verify integrity shard by
shard and a query can address exactly the shards its blocks live in.

Because shards are raw ``.npy`` files, ``np.load(..., mmap_mode='r')``
maps them without reading: opening a v2 index costs metadata only, and
arena bytes are paged in by the OS as queries touch rows (and staged to
device per shard by the DeviceTileCache). This is the representation that
delivers the paper's "does not need the complete index in RAM", and it is
the unit the multi-host placement (repro.index.distributed /
repro.index.placement) will schedule: a host serves the shard files its
manifest rows assign to it.

Writers stream: ``ShardStoreWriter.write_shard`` persists one finished
block group and forgets it, so building an index of any size needs host
memory for one block group at a time (see
repro.index.build_parallel.build_compact_streaming).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from . import codec as _codec
from .arena import ArenaLayout, MappedArena
from .index import BitSlicedIndex, IndexParams

FORMAT_V2 = "cobs-jax-v2"
TUNING_CACHE_NAME = "tuning.json"


def tuning_path(path: str | Path) -> Path:
    """The kernel-tuning cache persisted BESIDE a v2 store's manifest:
    tuned tile/grid configs key on the arena geometry the store fixes, so
    the cache travels with the shards it was measured for (reopening the
    store serves with measured choices, no re-tuning — see
    repro.kernels.autotune.TuningCache)."""
    return Path(path) / TUNING_CACHE_NAME


def _hash_array(a: np.ndarray) -> str:
    return hashlib.blake2b(np.ascontiguousarray(a).tobytes(),
                           digest_size=16).hexdigest()


# "pops-" prefix, not a "shard-" suffix: data shards must stay exactly
# the ``shard-*.npy`` glob that merge tooling and resume tests rely on
def _pops_name(s: int) -> str:
    return f"pops-{s:06d}.npy"


def row_popcounts(matrix: np.ndarray, *, rows_per_slab: int = 1 << 16
                  ) -> np.ndarray:
    """Per-slice popcount stats: uint32 [rows] with the number of set doc
    bits in each arena row of a decoded shard tile. Recorded at build time
    as a ``pops-*.npy`` sidecar so the pruned executor can order a query's
    terms rarest-first (low-popcount rows keep non-matching blocks'
    running counts low, which is what makes the branch-and-bound kill
    blocks early) without ever reading the arena itself."""
    out = np.empty(matrix.shape[0], dtype=np.uint32)
    for r0 in range(0, matrix.shape[0], rows_per_slab):
        slab = np.ascontiguousarray(matrix[r0:r0 + rows_per_slab])
        bits = np.unpackbits(slab.view(np.uint8), axis=1)
        out[r0:r0 + slab.shape[0]] = bits.sum(axis=1, dtype=np.int64)
    return out


def shard_row_bounds(layout: ArenaLayout, blocks_per_shard: int = 1
                     ) -> np.ndarray:
    """Shard boundaries (int64 [n_shards+1]) grouping ``blocks_per_shard``
    consecutive blocks per shard — always on block edges."""
    if blocks_per_shard < 1:
        raise ValueError("blocks_per_shard must be >= 1")
    bounds = [0]
    for b0 in range(0, layout.n_blocks, blocks_per_shard):
        b1 = min(b0 + blocks_per_shard, layout.n_blocks) - 1
        bounds.append(int(layout.row_offset[b1]) + int(layout.block_width[b1]))
    return np.asarray(bounds, dtype=np.int64)


def _shard_name(s: int) -> str:
    return f"shard-{s:06d}.npy"


def _shard_stem(s: int) -> str:
    return f"shard-{s:06d}"


_CODEC_COMPONENTS = {
    _codec.CODEC_RAW: ("data",),
    _codec.CODEC_ROWDICT: ("dict", "refs"),
    _codec.CODEC_ROWDICT_RLE: ("rle", "refs"),
    _codec.CODEC_RLE: ("rle",),
}


def _shard_files(s: int, codec: str) -> dict[str, str]:
    """Component name -> file name for shard ``s`` under ``codec``. Raw
    keeps the historic single ``shard-%06d.npy``; compressed shards store
    each component as its own mmap-able ``.npy``."""
    stem = _shard_stem(s)
    return {c: stem + _codec.COMPONENT_SUFFIX[c]
            for c in _CODEC_COMPONENTS[codec]}


def _pops_from_entry(path: Path, entry: dict) -> Path | None:
    """Popcount-sidecar path for one manifest shard row, or None for
    stores written before the stats field existed (readers then fall back
    to natural term order — the field is optional both ways)."""
    name = entry.get("pops")
    if not name:
        return None
    p = path / name
    return p if p.exists() else None


def _source_from_entry(path: Path, entry: dict, doc_words: int):
    """MappedArena source for one manifest shard row: the raw file path,
    or a lazy CompressedShardSource for non-raw codecs. Manifests written
    before the codec layer have no "codec" key — treated as raw."""
    codec = entry.get("codec", _codec.CODEC_RAW)
    if codec == _codec.CODEC_RAW:
        return path / entry["file"]
    rows = int(entry["rows"][1]) - int(entry["rows"][0])
    return _codec.CompressedShardSource(
        codec=codec,
        paths={c: path / f for c, f in entry["files"].items()},
        rows=rows,
        doc_words=int(doc_words),
        comp_nbytes=int(entry["comp_bytes"]))


class ShardStoreWriter:
    """Streaming writer for a v2 store.

    The layout (known up front from term counts alone) fixes the shard
    table; block-group matrices are then written one at a time in any
    order. ``finalize`` persists metadata + manifest and fails if shards
    are missing. Re-running over an existing directory resumes: shards
    whose file already matches the expected shape (and hash, if a partial
    manifest is present) are skipped by the builder via ``have_shard``.

    ``codec`` selects the per-shard tile codec (repro.core.codec.CODECS,
    or "auto" for smallest-wins): each tile is encoded independently and
    falls back to raw when compression doesn't pay, so a store may mix
    codecs shard by shard. Content hashes are ALWAYS over the decoded
    tile — raw<->compressed migration preserves them.
    """

    def __init__(self, path: str | Path, layout: ArenaLayout,
                 params: IndexParams, blocks_per_shard: int = 1,
                 codec: str = _codec.CODEC_RAW):
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.layout = layout
        self.params = params
        self.blocks_per_shard = int(blocks_per_shard)
        if codec not in _codec.CODECS + ("auto",):
            raise ValueError(f"unknown codec {codec!r}")
        self.codec = codec
        self.row_starts = shard_row_bounds(layout, blocks_per_shard)
        self.block_ranges = layout.shard_blocks(self.row_starts)
        self._hashes: dict[int, str] = {}
        self._entries: dict[int, dict] = {}   # codec/files/bytes per shard

    @property
    def n_shards(self) -> int:
        return len(self.row_starts) - 1

    def shard_shape(self, s: int) -> tuple[int, int]:
        rows = int(self.row_starts[s + 1] - self.row_starts[s])
        return rows, self.layout.doc_words

    def shard_blocks(self, s: int) -> tuple[int, int]:
        return self.block_ranges[s]

    @staticmethod
    def _valid_components(codec: str, arrays: dict, rows: int, W: int
                          ) -> bool:
        """Cheap (header/shape-only) consistency check for resumed shard
        component files — full integrity is the manifest hash's job."""
        try:
            if codec == _codec.CODEC_RAW:
                return (arrays["data"].shape == (rows, W)
                        and arrays["data"].dtype == np.uint32)
            if "refs" in arrays:
                r = arrays["refs"]
                if r.shape != (rows,) or r.dtype != np.int32:
                    return False
            if codec == _codec.CODEC_ROWDICT:
                d = arrays["dict"]
                return (d.ndim == 2 and d.shape[1] == W
                        and d.dtype == np.uint32)
            rle = arrays["rle"]
            if rle.ndim != 1 or rle.dtype != np.uint32 or rle.size < 3:
                return False
            if codec == _codec.CODEC_RLE:
                return int(rle[0]) == rows and int(rle[1]) == W
            return int(rle[1]) == W     # rowdict+rle header: [D, W, P]
        except (KeyError, IndexError, AttributeError):
            return False

    def _resume_entry(self, s: int) -> dict | None:
        """Inspect disk for a complete shard ``s`` written by ANY codec
        (a resumed build may change the requested codec; what's on disk
        wins). Returns the codec/files/byte fields of the manifest entry,
        or None when no consistent set of component files exists."""
        rows, W = self.shard_shape(s)
        for codec in _CODEC_COMPONENTS:
            files = _shard_files(s, codec)
            paths = {c: self.path / f for c, f in files.items()}
            if not all(p.exists() for p in paths.values()):
                continue
            try:
                arrays = {c: np.load(p, mmap_mode="r")
                          for c, p in paths.items()}
            except (ValueError, OSError):
                continue
            if not self._valid_components(codec, arrays, rows, W):
                continue
            comp = int(sum(int(a.nbytes) for a in arrays.values()))
            raw_nb = rows * W * 4
            entry = {"codec": codec, "files": files, "comp_bytes": comp,
                     "ratio": round(raw_nb / comp, 4) if comp else 1.0}
            if codec == _codec.CODEC_ROWDICT:
                entry["dict_rows"] = int(arrays["dict"].shape[0])
            elif codec == _codec.CODEC_ROWDICT_RLE:
                entry["dict_rows"] = int(arrays["rle"][0])
            pops_path = self.path / _pops_name(s)
            if pops_path.exists():
                try:
                    pops = np.load(pops_path, mmap_mode="r")
                    if pops.shape == (rows,):
                        entry["pops"] = _pops_name(s)
                        entry["mean_pop"] = round(
                            float(np.asarray(pops).mean()) if rows else 0.0,
                            4)
                except (ValueError, OSError):
                    pass
            return entry
        return None

    def have_shard(self, s: int) -> bool:
        """A resumable shard: component files exist, shapes consistent."""
        return self._resume_entry(s) is not None

    def _clean_shard_files(self, s: int) -> None:
        stem = _shard_stem(s)
        for name in [stem + suffix
                     for suffix in _codec.COMPONENT_SUFFIX.values()] \
                + [_pops_name(s)]:
            f = self.path / name
            if f.exists():
                f.unlink()

    def write_shard(self, s: int, matrix: np.ndarray) -> None:
        if matrix.shape != self.shard_shape(s) or matrix.dtype != np.uint32:
            raise ValueError(
                f"shard {s}: got {matrix.dtype}{matrix.shape}, want "
                f"uint32{self.shard_shape(s)}")
        tile = _codec.encode_tile(matrix, self.codec)
        self._clean_shard_files(s)   # stale other-codec components confuse resume
        files = _shard_files(s, tile.codec)
        for comp, name in files.items():
            np.save(self.path / name, tile.arrays[comp])
        # per-slice popcount sidecar: an OPTIONAL manifest field (old
        # stores simply lack it and readers fall back to natural term
        # order), so the format stays backward- and forward-compatible
        pops = row_popcounts(matrix)
        np.save(self.path / _pops_name(s), pops)
        self._hashes[s] = _hash_array(matrix)   # hash the DECODED tile
        entry = {"codec": tile.codec, "files": files,
                 "comp_bytes": tile.comp_nbytes,
                 "ratio": round(tile.ratio, 4),
                 "pops": _pops_name(s),
                 "mean_pop": round(float(pops.mean()) if pops.size else 0.0,
                                   4)}
        d = tile.dict_form()
        if d is not None:
            entry["dict_rows"] = int(d[0].shape[0])
        self._entries[s] = entry

    def _shard_host_from_disk(self, s: int, entry: dict) -> np.ndarray:
        arrays = {c: np.load(self.path / f, mmap_mode="r")
                  for c, f in entry["files"].items()}
        rows, W = self.shard_shape(s)
        return _codec.tile_from_arrays(entry["codec"], arrays, rows,
                                       W).decode()

    def finalize(self) -> Path:
        shards = []
        raw_total = comp_total = 0
        for s in range(self.n_shards):
            info = self._entries.get(s)
            if info is None:                   # resumed shard: read disk
                info = self._resume_entry(s)
                if info is None:
                    raise FileNotFoundError(
                        f"missing shard files for shard {s} in {self.path}")
            h = self._hashes.get(s)
            if h is None:                      # resumed shard: hash from disk
                h = _hash_array(self._shard_host_from_disk(s, info))
            b0, b1 = self.block_ranges[s]
            rows, W = self.shard_shape(s)
            raw_total += rows * W * 4
            comp_total += int(info["comp_bytes"])
            entry = {
                "blocks": [b0, b1],
                "rows": [int(self.row_starts[s]), int(self.row_starts[s + 1])],
                "hash": h,
                **info,
            }
            if info["codec"] == _codec.CODEC_RAW:
                entry["file"] = info["files"]["data"]   # legacy readers
            shards.append(entry)
        np.savez(self.path / "meta.npz",
                 row_offset=self.layout.row_offset,
                 block_width=self.layout.block_width,
                 doc_slot=self.layout.doc_slot,
                 doc_n_terms=self.layout.doc_n_terms)
        manifest = {
            "format": FORMAT_V2,
            "block_docs": self.layout.block_docs,
            "n_docs": self.layout.n_docs,
            "params": self.params.to_json(),
            "codec": self.codec,
            "raw_bytes": raw_total,
            "comp_bytes": comp_total,
            "ratio": round(raw_total / comp_total, 4) if comp_total else 1.0,
            "shards": shards,
        }
        out = self.path / "manifest.json"
        tmp = self.path / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest, indent=2))
        tmp.rename(out)                        # manifest commit is atomic
        return out


def _read_store_meta(path: Path) -> tuple[dict, ArenaLayout, IndexParams]:
    """Manifest + layout + params of a v2 store (metadata only)."""
    manifest = json.loads((path / "manifest.json").read_text())
    if manifest.get("format") != FORMAT_V2:
        raise ValueError(f"not a {FORMAT_V2} store: {path}")
    with np.load(path / "meta.npz") as z:
        layout = ArenaLayout.make(
            z["row_offset"], z["block_width"], z["doc_slot"],
            z["doc_n_terms"], int(manifest["block_docs"]),
            int(manifest["n_docs"]))
    params = IndexParams.from_json(manifest["params"])
    return manifest, layout, params


def _verify_shards(storage: MappedArena, shards: list[dict],
                   which: range | list[int] | None = None) -> None:
    """Check content hashes of the storage's shards against the manifest
    rows ``shards`` (local index i holds manifest row shards[i])."""
    for i in (range(len(shards)) if which is None else which):
        got = _hash_array(storage.shard_host(i))
        if got != shards[i]["hash"]:
            name = shards[i].get("file") or "+".join(
                sorted(shards[i].get("files", {}).values())) or f"#{i}"
            raise IOError(f"shard {name} content hash mismatch")


def open_store(path: str | Path, *, verify: bool = False
               ) -> tuple[ArenaLayout, MappedArena, IndexParams]:
    """Open a v2 store as (layout, mmap-backed storage, params) without
    reading arena bytes (``verify=True`` additionally checks every shard's
    content hash, which does read them)."""
    path = Path(path)
    manifest, layout, params = _read_store_meta(path)
    shards = manifest["shards"]
    starts = np.asarray([s["rows"][0] for s in shards]
                        + [shards[-1]["rows"][1]], dtype=np.int64)
    sources = [_source_from_entry(path, s, layout.doc_words)
               for s in shards]
    storage = MappedArena(sources, starts, doc_words=layout.doc_words,
                          pop_sources=[_pops_from_entry(path, s)
                                       for s in shards])
    if verify:
        _verify_shards(storage, shards)
    return layout, storage, params


@dataclass(frozen=True)
class SubStore:
    """A per-host view of a v2 store: only the assigned manifest rows.

    ``layout`` stays the FULL store layout (query addressing needs global
    block geometry), while ``storage`` maps only the selected shard files,
    re-indexed locally (local shard i is global manifest row
    ``shard_ids[i]``). ``global_row_starts`` gives the parent store's shard
    boundaries so per-shard addressing can be rebased against the global
    arena (see repro.core.query.plan_shards_subset).
    """

    layout: ArenaLayout
    storage: MappedArena
    params: IndexParams
    shard_ids: tuple[int, ...]
    global_row_starts: np.ndarray   # int64 [n_shards_total + 1]

    @property
    def n_shards_total(self) -> int:
        return len(self.global_row_starts) - 1


def open_substore(path: str | Path, shard_ids, *, verify: bool = False
                  ) -> SubStore:
    """Open a manifest-subset view of a v2 store: a host materializes (as
    lazily-mmapped sources) only the shard files its placement assigns to
    it. Metadata cost only; ``verify=True`` hash-checks exactly the
    selected shards (the host's integrity gate at open)."""
    path = Path(path)
    manifest, layout, params = _read_store_meta(path)
    shards = manifest["shards"]
    ids = sorted(dict.fromkeys(int(s) for s in shard_ids))
    if not ids:
        raise ValueError("open_substore needs at least one shard id")
    if ids[0] < 0 or ids[-1] >= len(shards):
        raise ValueError(f"shard ids {ids} out of range "
                         f"[0, {len(shards)})")
    global_starts = np.asarray([s["rows"][0] for s in shards]
                               + [shards[-1]["rows"][1]], dtype=np.int64)
    heights = [shards[g]["rows"][1] - shards[g]["rows"][0] for g in ids]
    local_starts = np.concatenate([[0], np.cumsum(heights)]).astype(np.int64)
    storage = MappedArena(
        [_source_from_entry(path, shards[g], layout.doc_words)
         for g in ids],
        local_starts, doc_words=layout.doc_words,
        pop_sources=[_pops_from_entry(path, shards[g]) for g in ids])
    if verify:
        _verify_shards(storage, [shards[g] for g in ids])
    return SubStore(layout=layout, storage=storage, params=params,
                    shard_ids=tuple(ids), global_row_starts=global_starts)


def load_index_v2(path: str | Path, *, verify: bool = False
                  ) -> BitSlicedIndex:
    layout, storage, params = open_store(path, verify=verify)
    return BitSlicedIndex(layout=layout, storage=storage, params=params)


def save_index_v2(index: BitSlicedIndex, path: str | Path, *,
                  blocks_per_shard: int = 1,
                  codec: str = _codec.CODEC_RAW) -> None:
    """Write any index (whatever its storage backend) as a v2 store, one
    block group at a time — host memory stays bounded by one shard."""
    writer = ShardStoreWriter(path, index.layout, index.params,
                              blocks_per_shard, codec=codec)
    starts = writer.row_starts
    for s in range(writer.n_shards):
        rows = np.arange(starts[s], starts[s + 1], dtype=np.int64)
        writer.write_shard(
            s, np.ascontiguousarray(
                index.storage.read_rows_host(rows).astype(np.uint32)))
    writer.finalize()


def migrate_store_codec(src: str | Path, dst: str | Path,
                        codec: str = "auto") -> dict:
    """Re-encode a v2 store under another codec (raw<->compressed both
    ways; ``codec`` may be any CODECS member or "auto"). Shard geometry
    is preserved exactly, and because content hashes cover the DECODED
    tile, every shard's hash is identical in src and dst — migration is
    integrity-checkable end to end. Returns the dst manifest."""
    src = Path(src)
    layout, storage, params = open_store(src)
    manifest = json.loads((src / "manifest.json").read_text())
    b0, b1 = manifest["shards"][0]["blocks"]
    writer = ShardStoreWriter(dst, layout, params,
                              blocks_per_shard=max(1, int(b1) - int(b0)),
                              codec=codec)
    if writer.n_shards != storage.n_shards or not np.array_equal(
            writer.row_starts, storage.shard_row_starts):
        raise ValueError("migrate_store_codec: shard geometry mismatch "
                         "(non-uniform blocks_per_shard store?)")
    for s in range(writer.n_shards):
        writer.write_shard(
            s, np.ascontiguousarray(np.asarray(storage.shard_host(s),
                                               dtype=np.uint32)))
    writer.finalize()
    return json.loads((Path(dst) / "manifest.json").read_text())


def migrate_v1_to_v2(src: str | Path, dst: str | Path, *,
                     blocks_per_shard: int = 1) -> None:
    """Rewrite a legacy v1 monolith directory as a v2 shard store. The v1
    npz must be decompressed once (that is the format's flaw); shards are
    then written group by group."""
    src = Path(src)
    manifest = json.loads((src / "manifest.json").read_text())
    if manifest.get("format") != "cobs-jax-v1":
        raise ValueError(f"not a cobs-jax-v1 index: {src}")
    with np.load(src / "index.npz") as z:
        layout = ArenaLayout.make(
            z["row_offset"], z["block_width"], z["doc_slot"],
            z["doc_n_terms"], int(manifest["block_docs"]),
            int(manifest["n_docs"]))
        params = IndexParams.from_json(manifest["params"])
        writer = ShardStoreWriter(dst, layout, params, blocks_per_shard)
        arena = z["arena"]
        for s in range(writer.n_shards):
            r0, r1 = int(writer.row_starts[s]), int(writer.row_starts[s + 1])
            writer.write_shard(s, np.ascontiguousarray(arena[r0:r1]))
    writer.finalize()


def merge_stores(a: str | Path, b: str | Path, out: str | Path) -> None:
    """Merge two v2 COMPACT stores into a third by manifest concatenation:
    shard files are hard-linked (copied if the filesystem refuses links)
    and never read — the paper's section 2.3 concatenation as an
    O(metadata + n_shards) directory operation."""
    import shutil

    la, sa, pa = open_store(a)
    lb, sb, pb = open_store(b)
    if pa != pb:
        raise ValueError("parameter mismatch")
    from .index import merge_compact_layout
    layout = merge_compact_layout(la, lb)

    out = Path(out)
    out.mkdir(parents=True, exist_ok=True)
    man_a = json.loads((Path(a) / "manifest.json").read_text())
    man_b = json.loads((Path(b) / "manifest.json").read_text())
    W = layout.doc_words
    shards, row_base, block_base = [], 0, 0
    raw_total = comp_total = 0
    for src_dir, man in ((Path(a), man_a), (Path(b), man_b)):
        for s in man["shards"]:
            i = len(shards)
            codec = s.get("codec", _codec.CODEC_RAW)
            src_files = s.get("files") or {"data": s["file"]}
            new_files = _shard_files(i, codec)
            for comp, src_name in src_files.items():
                target = out / new_files[comp]
                if target.exists():
                    target.unlink()
                try:
                    import os
                    os.link(src_dir / src_name, target)
                except OSError:
                    shutil.copyfile(src_dir / src_name, target)
            raw_nb = (int(s["rows"][1]) - int(s["rows"][0])) * W * 4
            comp_nb = int(s.get("comp_bytes", raw_nb))
            raw_total += raw_nb
            comp_total += comp_nb
            entry = {
                "blocks": [s["blocks"][0] + block_base,
                           s["blocks"][1] + block_base],
                "rows": [s["rows"][0] + row_base, s["rows"][1] + row_base],
                "hash": s["hash"],
                "codec": codec,
                "files": new_files,
                "comp_bytes": comp_nb,
                "ratio": float(s.get("ratio", 1.0)),
            }
            if "dict_rows" in s:
                entry["dict_rows"] = int(s["dict_rows"])
            if codec == _codec.CODEC_RAW:
                entry["file"] = new_files["data"]
            if s.get("pops") and (src_dir / s["pops"]).exists():
                target = out / _pops_name(i)
                if target.exists():
                    target.unlink()
                try:
                    import os
                    os.link(src_dir / s["pops"], target)
                except OSError:
                    shutil.copyfile(src_dir / s["pops"], target)
                entry["pops"] = _pops_name(i)
                if "mean_pop" in s:
                    entry["mean_pop"] = float(s["mean_pop"])
            shards.append(entry)
        row_base += int(man["shards"][-1]["rows"][1])
        block_base += int(man["shards"][-1]["blocks"][1])
    np.savez(out / "meta.npz",
             row_offset=layout.row_offset, block_width=layout.block_width,
             doc_slot=layout.doc_slot, doc_n_terms=layout.doc_n_terms)
    codecs = {man_a.get("codec", _codec.CODEC_RAW),
              man_b.get("codec", _codec.CODEC_RAW)}
    manifest = {
        "format": FORMAT_V2,
        "block_docs": layout.block_docs,
        "n_docs": layout.n_docs,
        "params": pa.to_json(),
        "codec": codecs.pop() if len(codecs) == 1 else "mixed",
        "raw_bytes": raw_total,
        "comp_bytes": comp_total,
        "ratio": round(raw_total / comp_total, 4) if comp_total else 1.0,
        "shards": shards,
    }
    tmp = out / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest, indent=2))
    tmp.rename(out / "manifest.json")
