"""cobs-jax-v2: the out-of-core, shard-per-block-group index directory.

Layout on disk::

    <path>/
      manifest.json            format, params, layout metadata, shard table
      meta.npz                 row_offset / block_width / doc_slot / doc_n_terms
      shard-000000.npy         raw (uncompressed) .npy — mmap-able
      shard-000001.npy         ...

Each shard holds the arena rows of one *block group* (``blocks_per_shard``
consecutive blocks; 1 by default, i.e. shard-per-block). The manifest's
shard table records, per shard, the file name, block range, row range, and
a blake2b content hash — so an opened store can verify integrity shard by
shard and a query can address exactly the shards its blocks live in.

Because shards are raw ``.npy`` files, ``np.load(..., mmap_mode='r')``
maps them without reading: opening a v2 index costs metadata only, and
arena bytes are paged in by the OS as queries touch rows (and staged to
device per shard by the DeviceTileCache). This is the representation that
delivers the paper's "does not need the complete index in RAM", and it is
the unit the multi-host placement (repro.index.distributed /
repro.index.placement) will schedule: a host serves the shard files its
manifest rows assign to it.

Writers stream: ``ShardStoreWriter.write_shard`` persists one finished
block group and forgets it, so building an index of any size needs host
memory for one block group at a time (see
repro.index.build_parallel.build_compact_streaming).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .arena import ArenaLayout, MappedArena
from .index import BitSlicedIndex, IndexParams

FORMAT_V2 = "cobs-jax-v2"
TUNING_CACHE_NAME = "tuning.json"


def tuning_path(path: str | Path) -> Path:
    """The kernel-tuning cache persisted BESIDE a v2 store's manifest:
    tuned tile/grid configs key on the arena geometry the store fixes, so
    the cache travels with the shards it was measured for (reopening the
    store serves with measured choices, no re-tuning — see
    repro.kernels.autotune.TuningCache)."""
    return Path(path) / TUNING_CACHE_NAME


def _hash_array(a: np.ndarray) -> str:
    return hashlib.blake2b(np.ascontiguousarray(a).tobytes(),
                           digest_size=16).hexdigest()


def shard_row_bounds(layout: ArenaLayout, blocks_per_shard: int = 1
                     ) -> np.ndarray:
    """Shard boundaries (int64 [n_shards+1]) grouping ``blocks_per_shard``
    consecutive blocks per shard — always on block edges."""
    if blocks_per_shard < 1:
        raise ValueError("blocks_per_shard must be >= 1")
    bounds = [0]
    for b0 in range(0, layout.n_blocks, blocks_per_shard):
        b1 = min(b0 + blocks_per_shard, layout.n_blocks) - 1
        bounds.append(int(layout.row_offset[b1]) + int(layout.block_width[b1]))
    return np.asarray(bounds, dtype=np.int64)


def _shard_name(s: int) -> str:
    return f"shard-{s:06d}.npy"


class ShardStoreWriter:
    """Streaming writer for a v2 store.

    The layout (known up front from term counts alone) fixes the shard
    table; block-group matrices are then written one at a time in any
    order. ``finalize`` persists metadata + manifest and fails if shards
    are missing. Re-running over an existing directory resumes: shards
    whose file already matches the expected shape (and hash, if a partial
    manifest is present) are skipped by the builder via ``have_shard``.
    """

    def __init__(self, path: str | Path, layout: ArenaLayout,
                 params: IndexParams, blocks_per_shard: int = 1):
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.layout = layout
        self.params = params
        self.blocks_per_shard = int(blocks_per_shard)
        self.row_starts = shard_row_bounds(layout, blocks_per_shard)
        self.block_ranges = layout.shard_blocks(self.row_starts)
        self._hashes: dict[int, str] = {}

    @property
    def n_shards(self) -> int:
        return len(self.row_starts) - 1

    def shard_shape(self, s: int) -> tuple[int, int]:
        rows = int(self.row_starts[s + 1] - self.row_starts[s])
        return rows, self.layout.doc_words

    def shard_blocks(self, s: int) -> tuple[int, int]:
        return self.block_ranges[s]

    def have_shard(self, s: int) -> bool:
        """A resumable shard: file exists with the expected shape/dtype."""
        f = self.path / _shard_name(s)
        if not f.exists():
            return False
        try:
            a = np.load(f, mmap_mode="r")
        except (ValueError, OSError):
            return False
        return a.shape == self.shard_shape(s) and a.dtype == np.uint32

    def write_shard(self, s: int, matrix: np.ndarray) -> None:
        if matrix.shape != self.shard_shape(s) or matrix.dtype != np.uint32:
            raise ValueError(
                f"shard {s}: got {matrix.dtype}{matrix.shape}, want "
                f"uint32{self.shard_shape(s)}")
        np.save(self.path / _shard_name(s), matrix)
        self._hashes[s] = _hash_array(matrix)

    def finalize(self) -> Path:
        shards = []
        for s in range(self.n_shards):
            f = self.path / _shard_name(s)
            if not f.exists():
                raise FileNotFoundError(f"missing shard file {f}")
            h = self._hashes.get(s)
            if h is None:                      # resumed shard: hash from disk
                h = _hash_array(np.load(f, mmap_mode="r"))
            b0, b1 = self.block_ranges[s]
            shards.append({
                "file": _shard_name(s),
                "blocks": [b0, b1],
                "rows": [int(self.row_starts[s]), int(self.row_starts[s + 1])],
                "hash": h,
            })
        np.savez(self.path / "meta.npz",
                 row_offset=self.layout.row_offset,
                 block_width=self.layout.block_width,
                 doc_slot=self.layout.doc_slot,
                 doc_n_terms=self.layout.doc_n_terms)
        manifest = {
            "format": FORMAT_V2,
            "block_docs": self.layout.block_docs,
            "n_docs": self.layout.n_docs,
            "params": self.params.to_json(),
            "shards": shards,
        }
        out = self.path / "manifest.json"
        tmp = self.path / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest, indent=2))
        tmp.rename(out)                        # manifest commit is atomic
        return out


def _read_store_meta(path: Path) -> tuple[dict, ArenaLayout, IndexParams]:
    """Manifest + layout + params of a v2 store (metadata only)."""
    manifest = json.loads((path / "manifest.json").read_text())
    if manifest.get("format") != FORMAT_V2:
        raise ValueError(f"not a {FORMAT_V2} store: {path}")
    with np.load(path / "meta.npz") as z:
        layout = ArenaLayout.make(
            z["row_offset"], z["block_width"], z["doc_slot"],
            z["doc_n_terms"], int(manifest["block_docs"]),
            int(manifest["n_docs"]))
    params = IndexParams.from_json(manifest["params"])
    return manifest, layout, params


def _verify_shards(storage: MappedArena, shards: list[dict],
                   which: range | list[int] | None = None) -> None:
    """Check content hashes of the storage's shards against the manifest
    rows ``shards`` (local index i holds manifest row shards[i])."""
    for i in (range(len(shards)) if which is None else which):
        got = _hash_array(storage.shard_host(i))
        if got != shards[i]["hash"]:
            raise IOError(f"shard {shards[i]['file']} content hash mismatch")


def open_store(path: str | Path, *, verify: bool = False
               ) -> tuple[ArenaLayout, MappedArena, IndexParams]:
    """Open a v2 store as (layout, mmap-backed storage, params) without
    reading arena bytes (``verify=True`` additionally checks every shard's
    content hash, which does read them)."""
    path = Path(path)
    manifest, layout, params = _read_store_meta(path)
    shards = manifest["shards"]
    starts = np.asarray([s["rows"][0] for s in shards]
                        + [shards[-1]["rows"][1]], dtype=np.int64)
    sources = [path / s["file"] for s in shards]
    storage = MappedArena(sources, starts, doc_words=layout.doc_words)
    if verify:
        _verify_shards(storage, shards)
    return layout, storage, params


@dataclass(frozen=True)
class SubStore:
    """A per-host view of a v2 store: only the assigned manifest rows.

    ``layout`` stays the FULL store layout (query addressing needs global
    block geometry), while ``storage`` maps only the selected shard files,
    re-indexed locally (local shard i is global manifest row
    ``shard_ids[i]``). ``global_row_starts`` gives the parent store's shard
    boundaries so per-shard addressing can be rebased against the global
    arena (see repro.core.query.plan_shards_subset).
    """

    layout: ArenaLayout
    storage: MappedArena
    params: IndexParams
    shard_ids: tuple[int, ...]
    global_row_starts: np.ndarray   # int64 [n_shards_total + 1]

    @property
    def n_shards_total(self) -> int:
        return len(self.global_row_starts) - 1


def open_substore(path: str | Path, shard_ids, *, verify: bool = False
                  ) -> SubStore:
    """Open a manifest-subset view of a v2 store: a host materializes (as
    lazily-mmapped sources) only the shard files its placement assigns to
    it. Metadata cost only; ``verify=True`` hash-checks exactly the
    selected shards (the host's integrity gate at open)."""
    path = Path(path)
    manifest, layout, params = _read_store_meta(path)
    shards = manifest["shards"]
    ids = sorted(dict.fromkeys(int(s) for s in shard_ids))
    if not ids:
        raise ValueError("open_substore needs at least one shard id")
    if ids[0] < 0 or ids[-1] >= len(shards):
        raise ValueError(f"shard ids {ids} out of range "
                         f"[0, {len(shards)})")
    global_starts = np.asarray([s["rows"][0] for s in shards]
                               + [shards[-1]["rows"][1]], dtype=np.int64)
    heights = [shards[g]["rows"][1] - shards[g]["rows"][0] for g in ids]
    local_starts = np.concatenate([[0], np.cumsum(heights)]).astype(np.int64)
    storage = MappedArena([path / shards[g]["file"] for g in ids],
                          local_starts, doc_words=layout.doc_words)
    if verify:
        _verify_shards(storage, [shards[g] for g in ids])
    return SubStore(layout=layout, storage=storage, params=params,
                    shard_ids=tuple(ids), global_row_starts=global_starts)


def load_index_v2(path: str | Path, *, verify: bool = False
                  ) -> BitSlicedIndex:
    layout, storage, params = open_store(path, verify=verify)
    return BitSlicedIndex(layout=layout, storage=storage, params=params)


def save_index_v2(index: BitSlicedIndex, path: str | Path, *,
                  blocks_per_shard: int = 1) -> None:
    """Write any index (whatever its storage backend) as a v2 store, one
    block group at a time — host memory stays bounded by one shard."""
    writer = ShardStoreWriter(path, index.layout, index.params,
                              blocks_per_shard)
    starts = writer.row_starts
    for s in range(writer.n_shards):
        rows = np.arange(starts[s], starts[s + 1], dtype=np.int64)
        writer.write_shard(
            s, np.ascontiguousarray(
                index.storage.read_rows_host(rows).astype(np.uint32)))
    writer.finalize()


def migrate_v1_to_v2(src: str | Path, dst: str | Path, *,
                     blocks_per_shard: int = 1) -> None:
    """Rewrite a legacy v1 monolith directory as a v2 shard store. The v1
    npz must be decompressed once (that is the format's flaw); shards are
    then written group by group."""
    src = Path(src)
    manifest = json.loads((src / "manifest.json").read_text())
    if manifest.get("format") != "cobs-jax-v1":
        raise ValueError(f"not a cobs-jax-v1 index: {src}")
    with np.load(src / "index.npz") as z:
        layout = ArenaLayout.make(
            z["row_offset"], z["block_width"], z["doc_slot"],
            z["doc_n_terms"], int(manifest["block_docs"]),
            int(manifest["n_docs"]))
        params = IndexParams.from_json(manifest["params"])
        writer = ShardStoreWriter(dst, layout, params, blocks_per_shard)
        arena = z["arena"]
        for s in range(writer.n_shards):
            r0, r1 = int(writer.row_starts[s]), int(writer.row_starts[s + 1])
            writer.write_shard(s, np.ascontiguousarray(arena[r0:r1]))
    writer.finalize()


def merge_stores(a: str | Path, b: str | Path, out: str | Path) -> None:
    """Merge two v2 COMPACT stores into a third by manifest concatenation:
    shard files are hard-linked (copied if the filesystem refuses links)
    and never read — the paper's section 2.3 concatenation as an
    O(metadata + n_shards) directory operation."""
    import shutil

    la, sa, pa = open_store(a)
    lb, sb, pb = open_store(b)
    if pa != pb:
        raise ValueError("parameter mismatch")
    from .index import merge_compact_layout
    layout = merge_compact_layout(la, lb)

    out = Path(out)
    out.mkdir(parents=True, exist_ok=True)
    man_a = json.loads((Path(a) / "manifest.json").read_text())
    man_b = json.loads((Path(b) / "manifest.json").read_text())
    shards, row_base, block_base = [], 0, 0
    for src_dir, man in ((Path(a), man_a), (Path(b), man_b)):
        for s in man["shards"]:
            i = len(shards)
            name = _shard_name(i)
            target = out / name
            if target.exists():
                target.unlink()
            try:
                import os
                os.link(src_dir / s["file"], target)
            except OSError:
                shutil.copyfile(src_dir / s["file"], target)
            shards.append({
                "file": name,
                "blocks": [s["blocks"][0] + block_base,
                           s["blocks"][1] + block_base],
                "rows": [s["rows"][0] + row_base, s["rows"][1] + row_base],
                "hash": s["hash"],
            })
        row_base += int(man["shards"][-1]["rows"][1])
        block_base += int(man["shards"][-1]["blocks"][1])
    np.savez(out / "meta.npz",
             row_offset=layout.row_offset, block_width=layout.block_width,
             doc_slot=layout.doc_slot, doc_n_terms=layout.doc_n_terms)
    manifest = {
        "format": FORMAT_V2,
        "block_docs": layout.block_docs,
        "n_docs": layout.n_docs,
        "params": pa.to_json(),
        "shards": shards,
    }
    tmp = out / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest, indent=2))
    tmp.rename(out / "manifest.json")
