"""Bloom filter construction for the bit-sliced index.

Builders are jit'd over (chunk, terms, width)-static shapes; the host-side
orchestration in index.py pads/chunks documents so only a handful of traces
occur per build. Bit layout convention used EVERYWHERE in this repo:

  bit-sliced matrix  M : uint32 [rows, doc_words]
  document d lives in   word d // 32, bit d % 32 (LSB-first)

so ``(M[r, d // 32] >> (d % 32)) & 1`` is Bloom bit r of document d.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing

ROW_ALIGN = 512      # filter widths rounded up -> fewer jit traces, aligned rows
TERM_ALIGN = 1024    # term-count padding granularity for the build scatter
DOC_WORD_BITS = 32   # documents per packed word


def aligned_width(w: int, align: int = ROW_ALIGN) -> int:
    return max(align, ((w + align - 1) // align) * align)


@partial(jax.jit, static_argnames=("w", "n_hashes"))
def build_filters(terms: jnp.ndarray, n_terms: jnp.ndarray, w: int, n_hashes: int):
    """Build Bloom filters for a chunk of documents.

    terms:   uint32 [C, T, 2]  packed terms, padded along T
    n_terms: int32  [C]        number of valid terms per document
    returns  bool   [C, w]     one filter per document
    """
    C, T, _ = terms.shape
    h = hashing.hash_terms(terms, n_hashes)            # uint32 [C, T, k]
    rows = (h % jnp.uint32(w)).astype(jnp.int32)       # [C, T, k]
    valid = (jnp.arange(T, dtype=jnp.int32)[None, :] < n_terms[:, None])
    rows = jnp.where(valid[:, :, None], rows, w)       # dump row w for padding
    rows = rows.reshape(C, T * rows.shape[-1])
    filt = jnp.zeros((C, w + 1), dtype=bool)
    filt = filt.at[jnp.arange(C, dtype=jnp.int32)[:, None], rows].set(True)
    return filt[:, :w]


@jax.jit
def pack_doc_major(filters: jnp.ndarray) -> jnp.ndarray:
    """bool [C, w] -> uint32 [w, C // 32] bit-sliced block (C % 32 == 0).

    This is the transpose into the paper's bit-sliced layout: each output row
    holds one Bloom position across all documents of the block.
    """
    C, w = filters.shape
    assert C % DOC_WORD_BITS == 0, "pad doc count to a multiple of 32 first"
    f = filters.T.reshape(w, C // DOC_WORD_BITS, DOC_WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(DOC_WORD_BITS, dtype=jnp.uint32))
    # bits are disjoint -> sum == bitwise or, stays exact in uint32
    return (f.astype(jnp.uint32) * weights).sum(axis=-1).astype(jnp.uint32)


def build_block_matrix(
    terms_list: list[np.ndarray],
    w: int,
    n_hashes: int,
    block_docs: int,
    max_chunk_bytes: int = 1 << 28,
) -> np.ndarray:
    """Build one sub-index block: uint32 [w, block_docs // 32].

    terms_list has <= block_docs documents; missing docs are empty columns
    (the paper pads the final block the same way). Documents are processed in
    chunks so the bool scatter buffer stays under max_chunk_bytes.
    """
    assert block_docs % DOC_WORD_BITS == 0
    n = len(terms_list)
    assert n <= block_docs
    chunk = max(DOC_WORD_BITS, min(block_docs, max_chunk_bytes // max(w, 1)))
    chunk = (chunk // DOC_WORD_BITS) * DOC_WORD_BITS
    parts = []
    for c0 in range(0, block_docs, chunk):
        c1 = min(c0 + chunk, block_docs)
        docs = terms_list[c0:min(c1, n)]
        counts = np.array([d.shape[0] for d in docs] + [0] * (c1 - c0 - len(docs)),
                          dtype=np.int32)
        t_max = int(counts.max()) if counts.size else 0
        t_pad = max(TERM_ALIGN, ((t_max + TERM_ALIGN - 1) // TERM_ALIGN) * TERM_ALIGN)
        buf = np.zeros((c1 - c0, t_pad, 2), dtype=np.uint32)
        for i, d in enumerate(docs):
            buf[i, : d.shape[0]] = d
        filt = build_filters(jnp.asarray(buf), jnp.asarray(counts), w, n_hashes)
        parts.append(np.asarray(pack_doc_major(filt)))
    return np.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
