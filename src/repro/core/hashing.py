"""Term hashing for the signature index.

The original COBS uses xxHash on the k-mer string. xxHash leans on 64-bit
multiplies which TPUs (and jax without x64) do not love, so we substitute a
murmur3-style 32-bit mix over the packed (lo, hi) uint32 words. The paper
only requires the k hash functions to be pairwise independent and well mixed;
tests/test_theory.py validates the empirical false-positive rate of the
resulting filters against the analytic Bloom/Theorem-1 predictions, so the
substitution is checked rather than assumed.

All functions exist in a jnp flavour (used on device inside the query/build
jits) and an np flavour (host-side oracle for tests).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_F1 = 0x85EBCA6B
_F2 = 0xC2B2AE35
_GOLD = 0x9E3779B9


def _rotl32(x, r: int, xp):
    r = np.uint32(r)
    return (x << r) | (x >> np.uint32(32 - r))


def _hash_impl(lo, hi, seed, xp):
    u32 = lambda v: xp.asarray(v, dtype=xp.uint32) if xp is jnp else np.uint32(v)
    c1, c2 = u32(_C1), u32(_C2)
    h = (seed.astype(xp.uint32) * u32(_GOLD)) ^ u32(0x2545F491)

    k = lo * c1
    k = _rotl32(k, 15, xp)
    k = k * c2
    h = h ^ k
    h = _rotl32(h, 13, xp)
    h = h * u32(5) + u32(0xE6546B64)

    k = hi * c1
    k = _rotl32(k, 15, xp)
    k = k * c2
    h = h ^ k
    h = _rotl32(h, 13, xp)
    h = h * u32(5) + u32(0xE6546B64)

    h = h ^ u32(8)  # 8 bytes mixed
    # fmix32 finalizer
    h = h ^ (h >> u32(16))
    h = h * u32(_F1)
    h = h ^ (h >> u32(13))
    h = h * u32(_F2)
    h = h ^ (h >> u32(16))
    return h


def hash_terms(terms: jnp.ndarray, n_hashes: int) -> jnp.ndarray:
    """Hash packed terms [..., 2] (uint32 lo/hi) with seeds 0..n_hashes-1.

    Returns uint32 [..., n_hashes] with full 2^32 output range. Range
    reduction to a concrete filter width happens later via modulo — exactly
    the paper's 'one hash function with a larger output range, then modulo'
    compaction trick (section 2.2).
    """
    terms = terms.astype(jnp.uint32)
    lo = terms[..., 0:1]
    hi = terms[..., 1:2]
    seeds = jnp.arange(n_hashes, dtype=jnp.uint32)
    shape = (1,) * (terms.ndim - 1) + (n_hashes,)
    seeds = seeds.reshape(shape)
    return _hash_impl(lo, hi, seeds, jnp)


def hash_terms_np(terms: np.ndarray, n_hashes: int) -> np.ndarray:
    """Host-side mirror of hash_terms (bit-identical; used as test oracle)."""
    terms = np.asarray(terms, dtype=np.uint32)
    lo = terms[..., 0:1]
    hi = terms[..., 1:2]
    seeds = np.arange(n_hashes, dtype=np.uint32)
    seeds = seeds.reshape((1,) * (terms.ndim - 1) + (n_hashes,))
    with np.errstate(over="ignore"):
        return _hash_impl(lo, hi, seeds, np)
