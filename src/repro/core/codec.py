"""Per-shard tile codecs: the compressed arena's encode/decode layer.

COBS arenas over genomic corpora are massively redundant (PAPERS.md's
"Hybrid Indexes for Repetitive Datasets"): near-duplicate documents hash
to IDENTICAL bit-sliced rows within a block, and sparse slices (low-FPR
blocks, short documents) are mostly zero words. Two codecs exploit the
two redundancy axes, with a per-tile raw fallback when neither pays:

* ``rowdict`` — dictionary of distinct rows. A tile [rows, W] becomes
  ``dict`` (uint32 [D, W], the distinct rows, lexicographically sorted
  by ``np.unique``) + ``refs`` (int32 [rows], row -> dictionary slot).
  This is the HBM-compressible form: the DeviceTileCache stages
  (dict, refs) instead of the expanded tile, and the fused Pallas
  kernels decode by one extra scalar indirection (``refs[row]``) in the
  BlockSpec index map — rows decompress HBM->VMEM on the way into the
  score loop, so effective gather bandwidth multiplies by rows/D.

* ``bitplane_rle`` — zero-run-length coding over the tile's word stream.
  Each arena row IS one bit plane of the block's signature matrix, so
  the row-major word stream walks plane by plane and sparse planes
  yield long zero runs. Disk-only: the stream is host-decoded at open /
  staging time (the decode cost is measured and fed to the planner's
  cost model via the obs registry's decode histogram).

* ``rowdict+rle`` — rowdict whose dictionary payload is additionally
  RLE-coded on disk (duplicate rows AND sparse distinct rows). The HBM
  form is still (dict, refs); only the disk bytes shrink further.

``encode_tile`` picks per tile: an explicit codec request still falls
back to ``raw`` when the coded form is not at least ``MIN_ENCODE_GAIN``
smaller — compression must never cost bytes. Decoding is exact
(bit-identical tiles), so the store's content hashes — computed over the
DECODED tile — are invariant under raw<->compressed migration.
"""
from __future__ import annotations

import dataclasses

import numpy as np

CODEC_RAW = "raw"
CODEC_ROWDICT = "rowdict"
CODEC_RLE = "bitplane_rle"
CODEC_ROWDICT_RLE = "rowdict+rle"

CODECS = (CODEC_RAW, CODEC_ROWDICT, CODEC_RLE, CODEC_ROWDICT_RLE)
# codecs whose HBM form is (dict_rows, refs) — the kernels decode these
DICT_CODECS = (CODEC_ROWDICT, CODEC_ROWDICT_RLE)

# An encoded tile must be at least this factor smaller than raw, else the
# tile stays raw (decode cost must buy real bytes, not round-off).
MIN_ENCODE_GAIN = 1.05

# Component names -> on-disk file suffixes (see store._shard_files).
COMPONENT_SUFFIX = {
    "data": ".npy",          # raw tile
    "dict": ".dict.npy",     # rowdict distinct rows
    "refs": ".refs.npy",     # rowdict row -> dict slot
    "rle": ".rle.npy",       # zero-run stream (tile or dict payload)
}


# --------------------------------------------------------------------------
# bit-plane zero-run coding (pure numpy, fully vectorized both ways)
# --------------------------------------------------------------------------

def rle_encode(matrix: np.ndarray) -> np.ndarray:
    """uint32 [rows, W] -> uint32 stream.

    Layout (all uint32): [rows, W, n_pairs] header, then the zero-run
    lengths [n_pairs], the literal-run lengths [n_pairs], then the
    literal words in order. Runs alternate zero/literal starting with a
    (possibly empty) zero run; lengths cover the flat row-major stream.
    """
    matrix = np.ascontiguousarray(matrix, dtype=np.uint32)
    rows, W = matrix.shape
    flat = matrix.reshape(-1)
    n = flat.size
    if n == 0:
        return np.array([rows, W, 0], dtype=np.uint32)
    nz = flat != 0
    change = np.flatnonzero(nz[1:] != nz[:-1])
    starts = np.concatenate([[0], change + 1])
    ends = np.concatenate([change + 1, [n]])
    lens = (ends - starts).astype(np.int64)
    if nz[starts[0]]:                       # leads with literals: empty
        lens = np.concatenate([[0], lens])  # zero run keeps the phase
    if lens.size % 2:                       # trails with zeros: empty
        lens = np.concatenate([lens, [0]])  # literal run closes the pair
    z, lit = lens[0::2], lens[1::2]
    return np.concatenate([
        np.array([rows, W, z.size], dtype=np.uint32),
        z.astype(np.uint32), lit.astype(np.uint32),
        flat[nz]])


def rle_decode(stream: np.ndarray) -> np.ndarray:
    """Inverse of ``rle_encode``: uint32 stream -> uint32 [rows, W]."""
    stream = np.asarray(stream, dtype=np.uint32)
    rows, W, P = (int(stream[0]), int(stream[1]), int(stream[2]))
    z = stream[3: 3 + P].astype(np.int64)
    lit = stream[3 + P: 3 + 2 * P].astype(np.int64)
    literals = stream[3 + 2 * P:]
    out = np.zeros(rows * W, dtype=np.uint32)
    if literals.size:
        lit_cum = np.concatenate([[0], np.cumsum(lit)[:-1]])
        lit_starts = np.cumsum(z) + lit_cum        # flat start per run
        idx = (np.arange(literals.size, dtype=np.int64)
               + np.repeat(lit_starts - lit_cum, lit))
        out[idx] = literals
    return out.reshape(rows, W)


# --------------------------------------------------------------------------
# tile encode / decode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompressedTile:
    """One encoded shard tile: codec + named component arrays.

    Components by codec — raw: {data}; rowdict: {dict, refs};
    rowdict+rle: {rle (coded dict), refs}; bitplane_rle: {rle}.
    """
    codec: str
    rows: int
    doc_words: int
    arrays: dict

    @property
    def raw_nbytes(self) -> int:
        return self.rows * self.doc_words * 4

    @property
    def comp_nbytes(self) -> int:
        return int(sum(int(a.nbytes) for a in self.arrays.values()))

    @property
    def ratio(self) -> float:
        comp = self.comp_nbytes
        return self.raw_nbytes / comp if comp else 1.0

    def decode(self) -> np.ndarray:
        """The exact original tile, uint32 [rows, doc_words]."""
        if self.codec == CODEC_RAW:
            return np.asarray(self.arrays["data"])
        if self.codec == CODEC_RLE:
            return rle_decode(self.arrays["rle"])
        d, refs = self.dict_form()
        return np.ascontiguousarray(d[refs])

    def dict_form(self) -> tuple[np.ndarray, np.ndarray] | None:
        """(dict_rows uint32 [D, W], refs int32 [rows]) for the rowdict
        codecs — the HBM-compressed form the kernels decode — else None."""
        if self.codec == CODEC_ROWDICT:
            return (np.asarray(self.arrays["dict"]),
                    np.asarray(self.arrays["refs"]))
        if self.codec == CODEC_ROWDICT_RLE:
            return (rle_decode(self.arrays["rle"]),
                    np.asarray(self.arrays["refs"]))
        return None


def _rowdict_split(matrix: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    uniq, inv = np.unique(matrix, axis=0, return_inverse=True)
    return (np.ascontiguousarray(uniq, dtype=np.uint32),
            np.ascontiguousarray(inv.reshape(-1), dtype=np.int32))


def encode_tile(matrix: np.ndarray, codec: str = "auto",
                min_gain: float = MIN_ENCODE_GAIN) -> CompressedTile:
    """Encode one tile. ``codec`` is a CODECS member or "auto" (smallest
    wins). Any choice — explicit included — falls back to raw when the
    coded form is not at least ``min_gain`` smaller than raw bytes."""
    matrix = np.ascontiguousarray(matrix, dtype=np.uint32)
    rows, W = matrix.shape
    if codec not in CODECS + ("auto",):
        raise ValueError(f"unknown codec {codec!r}; one of {CODECS}")
    raw_nb = matrix.nbytes
    candidates: list[tuple[int, str, dict]] = []
    if codec in ("auto", CODEC_ROWDICT, CODEC_ROWDICT_RLE) and rows > 0:
        d, refs = _rowdict_split(matrix)
        if codec in ("auto", CODEC_ROWDICT):
            candidates.append((d.nbytes + refs.nbytes, CODEC_ROWDICT,
                               {"dict": d, "refs": refs}))
        if codec in ("auto", CODEC_ROWDICT_RLE):
            dr = rle_encode(d)
            if dr.nbytes < d.nbytes:
                candidates.append((dr.nbytes + refs.nbytes,
                                   CODEC_ROWDICT_RLE,
                                   {"rle": dr, "refs": refs}))
    if codec in ("auto", CODEC_RLE) and rows > 0:
        r = rle_encode(matrix)
        candidates.append((r.nbytes, CODEC_RLE, {"rle": r}))
    candidates = [c for c in candidates if c[0] * min_gain <= raw_nb]
    if not candidates:
        return CompressedTile(CODEC_RAW, rows, W, {"data": matrix})
    nb, chosen, arrays = min(candidates, key=lambda c: (c[0], c[1]))
    return CompressedTile(chosen, rows, W, arrays)


def tile_from_arrays(codec: str, arrays: dict, rows: int, doc_words: int
                     ) -> CompressedTile:
    """Rehydrate a CompressedTile from loaded (possibly mmapped)
    component arrays — the store's open path."""
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r}")
    return CompressedTile(codec, int(rows), int(doc_words), dict(arrays))


@dataclasses.dataclass(frozen=True)
class CompressedShardSource:
    """Lazy handle on one compressed shard's component files: the
    MappedArena source for non-raw manifest rows. Component ``.npy``
    files mmap like raw shards, so opening costs metadata only; bytes
    are read when the tile is decoded or its dict form staged."""
    codec: str
    paths: dict            # component name -> Path
    rows: int
    doc_words: int
    comp_nbytes: int       # sum of component array bytes (manifest)

    def load(self) -> CompressedTile:
        arrays = {name: np.load(p, mmap_mode="r")
                  for name, p in self.paths.items()}
        return tile_from_arrays(self.codec, arrays, self.rows,
                                self.doc_words)
