"""Multi-index query frontend (paper section 4: 'our current COBS
implementation also already supports querying of multiple index files, such
that a frontend may select different datasets or categories').

Each sub-index keeps its own parameters and engine; results merge into a
single ranked list over a global document namespace (dataset, local_id).
This is also the unit for dataset-granular elasticity: attaching/detaching
a dataset never touches the other indexes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .index import BitSlicedIndex
from .query import QueryEngine


@dataclass
class MultiHit:
    dataset: str
    doc_id: int
    score: int
    n_terms: int


class MultiIndexEngine:
    def __init__(self, method: str = "vertical"):
        self._engines: dict[str, QueryEngine] = {}
        self.method = method

    def attach(self, name: str, index: BitSlicedIndex) -> None:
        if name in self._engines:
            raise KeyError(f"dataset {name!r} already attached")
        self._engines[name] = QueryEngine(index, method=self.method)

    def detach(self, name: str) -> None:
        del self._engines[name]

    @property
    def datasets(self) -> tuple[str, ...]:
        return tuple(self._engines)

    def search(self, pattern, threshold: float = 0.8,
               datasets: tuple[str, ...] | None = None) -> list[MultiHit]:
        """Query selected (default: all) datasets, merged and ranked by
        score, ties broken by (dataset, doc_id) for determinism. k-mer
        lengths may differ per dataset (each engine packs its own terms)."""
        hits: list[MultiHit] = []
        for name in (datasets if datasets is not None else self.datasets):
            eng = self._engines[name]
            r = eng.search(pattern, threshold=threshold)
            hits.extend(MultiHit(name, int(d), int(s), r.n_terms)
                        for d, s in zip(r.doc_ids, r.scores))
        hits.sort(key=lambda h: (-h.score / max(h.n_terms, 1),
                                 h.dataset, h.doc_id))
        return hits
