"""COBS core: the paper's contribution — a compact bit-sliced signature index."""
from . import bloom, dna, hashing, theory
from .index import (BitSlicedIndex, IndexParams, build_classic, build_compact,
                    load_index, merge_classic, merge_compact, save_index)
from .multi import MultiHit, MultiIndexEngine
from .query import (QueryEngine, SearchResult, make_batch_score_fn,
                    make_score_fn)

__all__ = [
    "BitSlicedIndex", "IndexParams", "QueryEngine", "SearchResult",
    "build_classic", "build_compact", "load_index", "merge_classic",
    "merge_compact", "save_index", "make_score_fn", "make_batch_score_fn",
    "MultiHit",
    "MultiIndexEngine", "bloom", "dna",
    "hashing", "theory",
]
