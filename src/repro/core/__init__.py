"""COBS core: the paper's contribution — a compact bit-sliced signature index."""
from . import bloom, codec, dna, hashing, store, theory
from .arena import (ArenaLayout, ArenaStorage, DeviceArena, DeviceTileCache,
                    HostArena, MappedArena)
from .codec import (CODECS, CompressedTile, encode_tile)
from .index import (BitSlicedIndex, IndexParams, build_classic, build_compact,
                    load_index, merge_classic, merge_compact, save_index)
from .multi import MultiHit, MultiIndexEngine
from .query import (QueryEngine, SearchResult, make_batch_score_fn,
                    make_score_fn)
from .store import (SubStore, load_index_v2, merge_stores,
                    migrate_store_codec, migrate_v1_to_v2, open_store,
                    open_substore, save_index_v2)

__all__ = [
    "ArenaLayout", "ArenaStorage", "BitSlicedIndex", "CODECS",
    "CompressedTile", "DeviceArena",
    "DeviceTileCache", "HostArena", "IndexParams", "MappedArena",
    "QueryEngine", "SearchResult",
    "SubStore",
    "build_classic", "build_compact", "encode_tile", "load_index",
    "load_index_v2",
    "merge_classic",
    "merge_compact", "merge_stores", "migrate_store_codec",
    "migrate_v1_to_v2",
    "open_store", "open_substore", "save_index",
    "save_index_v2", "make_score_fn", "make_batch_score_fn",
    "MultiHit",
    "MultiIndexEngine", "bloom", "codec", "dna",
    "hashing", "store", "theory",
]
