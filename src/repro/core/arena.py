"""Arena layout / storage split: the out-of-core representation of the index.

The paper's headline scaling property — "COBS does not need the complete
index in RAM" — requires the arena (uint32 [total_rows, doc_words]) to be
*addressable in shards* rather than one dense array. This module separates
the two concerns that BitSlicedIndex used to conflate:

* ``ArenaLayout`` — pure host-side metadata (per-block row offsets and
  filter widths, the document-slot permutation, term counts). It fully
  determines query addressing and never touches arena bytes; it is
  pytree-static in the sense that no piece of it is a traced value.

* ``ArenaStorage`` — where the arena bytes live. Three backends:

  - ``DeviceArena``  — one dense device array (the original behavior; the
    zero-copy migration path for existing code).
  - ``HostArena``    — one dense host array, moved to device lazily.
  - ``MappedArena``  — a list of row-range shards, each an ``np.memmap``
    over a raw ``.npy`` file (or an in-memory array for O(metadata)
    merges). Rows are paged to device per shard, on demand — the index
    never has to be resident anywhere end to end.

Shards always cover whole blocks (the store writes shard boundaries on
block-group edges), so per-shard query addressing is the global addressing
with row offsets rebased to the shard's first row.

``DeviceTileCache`` is the HBM paging policy: a bounded LRU of shard id ->
device tile with hit/fault counters, shared by the QueryEngine and the
serving subsystem (which exports the counters as metrics).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from pathlib import Path

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Geometric metadata of an arena; pure, host-side, and immutable.

    row_offset[b] is the global first arena row of block b; block b owns
    rows [row_offset[b], row_offset[b] + block_width[b]). Document i of
    the original corpus lives at slot doc_slot[i] (block slot//block_docs,
    column slot%block_docs).
    """

    row_offset: np.ndarray   # int32 [n_blocks]
    block_width: np.ndarray  # int32 [n_blocks]
    doc_slot: np.ndarray     # int32 [n_docs]
    doc_n_terms: np.ndarray  # int32 [n_docs]
    block_docs: int
    n_docs: int

    @staticmethod
    def make(row_offset, block_width, doc_slot, doc_n_terms,
             block_docs: int, n_docs: int) -> "ArenaLayout":
        return ArenaLayout(
            row_offset=np.asarray(row_offset, dtype=np.int32),
            block_width=np.asarray(block_width, dtype=np.int32),
            doc_slot=np.asarray(doc_slot, dtype=np.int32),
            doc_n_terms=np.asarray(doc_n_terms, dtype=np.int32),
            block_docs=int(block_docs),
            n_docs=int(n_docs),
        )

    # -- derived geometry ---------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return int(self.row_offset.shape[0])

    @property
    def doc_words(self) -> int:
        return self.block_docs // 32

    @property
    def total_rows(self) -> int:
        if self.n_blocks == 0:
            return 0
        return int(self.row_offset[-1]) + int(self.block_width[-1])

    @property
    def n_slots(self) -> int:
        return self.n_blocks * self.block_docs

    def block_row_range(self, b: int) -> tuple[int, int]:
        start = int(self.row_offset[b])
        return start, start + int(self.block_width[b])

    def shard_blocks(self, shard_row_starts: np.ndarray
                     ) -> list[tuple[int, int]]:
        """Partition blocks by shard: returns [(block_start, block_end)] per
        shard for row boundaries ``shard_row_starts`` (int64 [n_shards+1]).
        Every shard boundary must fall on a block boundary."""
        bounds = np.concatenate([self.row_offset.astype(np.int64),
                                 [self.total_rows]])
        out = []
        for s in range(len(shard_row_starts) - 1):
            lo = int(np.searchsorted(bounds, shard_row_starts[s]))
            hi = int(np.searchsorted(bounds, shard_row_starts[s + 1]))
            if (bounds[lo] != shard_row_starts[s]
                    or bounds[hi] != shard_row_starts[s + 1]):
                raise ValueError("shard boundary not on a block boundary")
            out.append((lo, hi))
        return out


# --------------------------------------------------------------------------
# Storage backends
# --------------------------------------------------------------------------

class ArenaStorage:
    """Protocol for arena byte storage.

    shape/dtype mirror the dense array; shards are contiguous row ranges
    covering [0, total_rows) whose boundaries are ``shard_row_starts``
    (int64 [n_shards + 1]).
    """

    shape: tuple[int, int]
    dtype: np.dtype
    shard_row_starts: np.ndarray

    @property
    def n_shards(self) -> int:
        return len(self.shard_row_starts) - 1

    def nbytes(self) -> int:
        return int(self.shape[0]) * int(self.shape[1]) * \
            np.dtype(self.dtype).itemsize

    def shard_nbytes(self, s: int) -> int:
        rows = int(self.shard_row_starts[s + 1] - self.shard_row_starts[s])
        return rows * int(self.shape[1]) * np.dtype(self.dtype).itemsize

    # -- byte access (implemented per backend) ------------------------------
    def shard_host(self, s: int) -> np.ndarray:
        raise NotImplementedError

    def shard_device(self, s: int) -> jnp.ndarray:
        return jnp.asarray(self.shard_host(s))

    def full_host(self) -> np.ndarray:
        return np.concatenate([np.asarray(self.shard_host(s))
                               for s in range(self.n_shards)], axis=0)

    def full_device(self) -> jnp.ndarray:
        """Dense device arena — the legacy path; materializes everything."""
        if self.n_shards == 1:
            return self.shard_device(0)
        return jnp.concatenate([self.shard_device(s)
                                for s in range(self.n_shards)], axis=0)

    def read_rows_host(self, rows: np.ndarray) -> np.ndarray:
        """Arbitrary global rows, host-side (point-query path). Pages only
        the rows' shards; never materializes the dense arena for mapped
        storage."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.size, self.shape[1]), dtype=self.dtype)
        flat = rows.reshape(-1)
        owner = np.searchsorted(self.shard_row_starts, flat, side="right") - 1
        for s in np.unique(owner):
            sel = owner == s
            local = flat[sel] - int(self.shard_row_starts[s])
            out[sel] = np.asarray(self.shard_host(int(s)))[local]
        return out.reshape(*rows.shape, self.shape[1])


def _starts(n_rows: int) -> np.ndarray:
    return np.array([0, n_rows], dtype=np.int64)


class DeviceArena(ArenaStorage):
    """One dense device-resident array — today's behavior, one shard."""

    def __init__(self, arena):
        self.arena = arena
        self.shape = tuple(arena.shape)
        self.dtype = np.dtype(getattr(arena, "dtype", np.uint32))
        self.shard_row_starts = _starts(self.shape[0])
        self._host: np.ndarray | None = None

    def shard_host(self, s: int) -> np.ndarray:
        if self._host is None:
            self._host = np.asarray(self.arena)
        return self._host

    def shard_device(self, s: int) -> jnp.ndarray:
        return self.arena

    def full_device(self):
        return self.arena


class HostArena(ArenaStorage):
    """One dense host array; the device copy is made lazily and cached."""

    def __init__(self, arena: np.ndarray):
        self.arena = np.asarray(arena)
        self.shape = tuple(self.arena.shape)
        self.dtype = self.arena.dtype
        self.shard_row_starts = _starts(self.shape[0])
        self._device: jnp.ndarray | None = None

    def shard_host(self, s: int) -> np.ndarray:
        return self.arena

    def shard_device(self, s: int) -> jnp.ndarray:
        if self._device is None:
            self._device = jnp.asarray(self.arena)
        return self._device


class MappedArena(ArenaStorage):
    """Row-range shards backed by raw ``.npy`` files (np.memmap) and/or
    in-memory arrays. File-backed shards are opened lazily with
    ``mmap_mode='r'`` so touching a shard costs page faults, not a load;
    in-memory sources make merge an O(metadata) shard-list concatenation.
    """

    def __init__(self, sources: list, shard_row_starts: np.ndarray,
                 doc_words: int, dtype=np.uint32):
        self.sources = list(sources)        # each: Path | str | np.ndarray
        self.shard_row_starts = np.asarray(shard_row_starts, dtype=np.int64)
        if len(self.sources) != self.n_shards:
            raise ValueError("sources / shard_row_starts length mismatch")
        self.shape = (int(self.shard_row_starts[-1]), int(doc_words))
        self.dtype = np.dtype(dtype)
        self._open: dict[int, np.ndarray] = {}

    def shard_host(self, s: int) -> np.ndarray:
        a = self._open.get(s)
        if a is None:
            src = self.sources[s]
            a = src if isinstance(src, np.ndarray) else np.load(
                src, mmap_mode="r")
            want_rows = int(self.shard_row_starts[s + 1]
                            - self.shard_row_starts[s])
            if a.shape != (want_rows, self.shape[1]):
                raise ValueError(
                    f"shard {s}: shape {a.shape} != "
                    f"({want_rows}, {self.shape[1]})")
            self._open[s] = a
        return a

    @staticmethod
    def concat(a: "ArenaStorage", b: "ArenaStorage") -> "MappedArena":
        """Row-axis concatenation without touching bytes: the merged arena
        is the two shard lists back to back (paper section 2.3 merging as
        an O(metadata) operation)."""
        if a.shape[1] != b.shape[1]:
            raise ValueError("doc_words mismatch")

        def shard_sources(st: ArenaStorage) -> list:
            if isinstance(st, MappedArena):
                return st.sources
            return [st.shard_host(s) for s in range(st.n_shards)]

        starts = np.concatenate([
            a.shard_row_starts,
            b.shard_row_starts[1:] + int(a.shard_row_starts[-1])])
        return MappedArena(shard_sources(a) + shard_sources(b), starts,
                           doc_words=a.shape[1], dtype=a.dtype)


def wrap_arena(arena) -> ArenaStorage:
    """Adopt a raw arena value under the storage protocol: numpy stays on
    host (HostArena), anything device-shaped (jax arrays, abstract
    ShapeDtypeStructs from the dry-run lowering) is a DeviceArena."""
    if isinstance(arena, ArenaStorage):
        return arena
    if isinstance(arena, np.ndarray):
        return HostArena(arena)
    return DeviceArena(arena)


# --------------------------------------------------------------------------
# HBM paging
# --------------------------------------------------------------------------

def common_tile_rows(storage: ArenaStorage) -> int | None:
    """Row count unifying all of a sharded storage's tiles (the tallest
    shard), or None for dense single-shard storage (no padding needed)."""
    if storage.n_shards <= 1:
        return None
    return int(np.max(np.diff(storage.shard_row_starts)))


class DeviceTileCache:
    """Bounded LRU of shard id -> device tile.

    ``capacity_bytes`` caps resident tile bytes (None = unbounded: every
    shard sticks after first touch, the right default for engines that own
    the whole device). A miss ("page fault") stages the shard host->device
    and may evict least-recently-used tiles; counters feed the serving
    metrics (shard residency / page faults).

    ``pad_rows_to`` zero-pads every staged tile to a common row count
    (typically the tallest shard): addressed rows are always < the real
    shard height, so results are unchanged, but all tiles share one shape
    and the scoring kernels compile ONCE per (bucket, method) instead of
    once per distinct shard height — compile time would otherwise dominate
    cold out-of-core serving on stores with many block groups.

    ``prefetch`` is the double-buffering hook: it stages a tile WITHOUT
    blocking the caller's compute stream (device transfers are dispatched
    asynchronously), so paged scoring loops can overlap the next shard's
    host->device copy with the current shard's kernel. ``faults`` counts
    every staging (demand or prefetch — each is one H2D transfer);
    ``prefetch_hits`` counts gets served by a previously prefetched tile,
    so prefetch_hits / prefetched is the prefetch usefulness rate exported
    by the serving metrics.

    ``device`` optionally pins staged tiles to a specific jax device — the
    multi-host serving path gives each fake-host worker its own device.
    """

    def __init__(self, storage: ArenaStorage,
                 capacity_bytes: int | None = None,
                 pad_rows_to: int | None = None,
                 device=None):
        self.storage = storage
        self.capacity_bytes = capacity_bytes
        self.pad_rows_to = pad_rows_to
        self.device = device
        self._tiles: "OrderedDict[int, jnp.ndarray]" = OrderedDict()
        self._prefetched: set[int] = set()
        self.resident_bytes = 0
        self.hits = 0
        self.faults = 0
        self.prefetched = 0
        self.prefetch_hits = 0
        # Per-shard accounting (the global totals above cannot say WHICH
        # shard keeps faulting when the working set outsizes the cache).
        self.shard_hits: dict[int, int] = {}
        self.shard_faults: dict[int, int] = {}
        self.shard_evictions: dict[int, int] = {}
        # Optional event hook: observer(shard, event, seconds) with event
        # in {"hit", "fault", "prefetch", "eviction"}; ``seconds`` is the
        # staging (dispatch) time for faults/prefetches, 0.0 otherwise.
        # The serving layer wires this to labeled registry counters and
        # to trace spans naming the faulted shard.
        self.observer = None

    def _notify(self, s: int, event: str, seconds: float = 0.0) -> None:
        if self.observer is not None:
            try:
                self.observer(s, event, seconds)
            except Exception:
                pass              # accounting must never fail a gather

    def _put(self, host: np.ndarray) -> jnp.ndarray:
        if self.device is None:
            return jnp.asarray(host)
        import jax
        return jax.device_put(host, self.device)

    def _stage(self, s: int) -> jnp.ndarray:
        if not self.pad_rows_to:
            return (self.storage.shard_device(s) if self.device is None
                    else self._put(self.storage.shard_host(s)))
        host = self.storage.shard_host(s)
        pad = self.pad_rows_to - host.shape[0]
        if pad < 0:
            raise ValueError(f"shard {s} taller than pad_rows_to")
        if pad == 0 and self.device is None:
            return self.storage.shard_device(s)
        return self._put(np.pad(host, ((0, pad), (0, 0))))

    def _tile_nbytes(self, s: int) -> int:
        if not self.pad_rows_to:
            return self.storage.shard_nbytes(s)
        return (self.pad_rows_to * int(self.storage.shape[1])
                * np.dtype(self.storage.dtype).itemsize)

    def __len__(self) -> int:
        return len(self._tiles)

    @property
    def resident_shards(self) -> tuple[int, ...]:
        return tuple(self._tiles)

    def _insert(self, s: int) -> tuple:
        t0 = time.perf_counter()
        tile = self._stage(s)
        staged_s = time.perf_counter() - t0
        need = self._tile_nbytes(s)
        if self.capacity_bytes is not None:
            while (self._tiles
                   and self.resident_bytes + need > self.capacity_bytes):
                old, _ = self._tiles.popitem(last=False)
                self.resident_bytes -= self._tile_nbytes(old)
                self._prefetched.discard(old)
                self.shard_evictions[old] = \
                    self.shard_evictions.get(old, 0) + 1
                self._notify(old, "eviction")
        self._tiles[s] = tile
        self.resident_bytes += need
        return tile, staged_s

    def get(self, s: int) -> jnp.ndarray:
        tile = self._tiles.get(s)
        if tile is not None:
            self._tiles.move_to_end(s)
            self.hits += 1
            self.shard_hits[s] = self.shard_hits.get(s, 0) + 1
            if s in self._prefetched:
                self._prefetched.discard(s)
                self.prefetch_hits += 1
            self._notify(s, "hit")
            return tile
        self.faults += 1
        self.shard_faults[s] = self.shard_faults.get(s, 0) + 1
        tile, staged_s = self._insert(s)
        self._notify(s, "fault", staged_s)
        return tile

    def prefetch(self, s: int) -> bool:
        """Stage shard ``s`` ahead of use (double buffering). The transfer
        is dispatched without blocking, so it overlaps with whatever the
        caller computes next; a later ``get(s)`` finds the tile resident.
        Counts as a fault (it IS one H2D staging); returns True if a
        transfer was started, False if the tile was already resident."""
        if s in self._tiles:
            return False
        self.faults += 1
        self.shard_faults[s] = self.shard_faults.get(s, 0) + 1
        self.prefetched += 1
        self._prefetched.add(s)
        _, staged_s = self._insert(s)
        self._notify(s, "prefetch", staged_s)
        return True

    def clear(self) -> None:
        self._tiles.clear()
        self._prefetched.clear()
        self.resident_bytes = 0
