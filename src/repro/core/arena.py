"""Arena layout / storage split: the out-of-core representation of the index.

The paper's headline scaling property — "COBS does not need the complete
index in RAM" — requires the arena (uint32 [total_rows, doc_words]) to be
*addressable in shards* rather than one dense array. This module separates
the two concerns that BitSlicedIndex used to conflate:

* ``ArenaLayout`` — pure host-side metadata (per-block row offsets and
  filter widths, the document-slot permutation, term counts). It fully
  determines query addressing and never touches arena bytes; it is
  pytree-static in the sense that no piece of it is a traced value.

* ``ArenaStorage`` — where the arena bytes live. Three backends:

  - ``DeviceArena``  — one dense device array (the original behavior; the
    zero-copy migration path for existing code).
  - ``HostArena``    — one dense host array, moved to device lazily.
  - ``MappedArena``  — a list of row-range shards, each an ``np.memmap``
    over a raw ``.npy`` file (or an in-memory array for O(metadata)
    merges). Rows are paged to device per shard, on demand — the index
    never has to be resident anywhere end to end.

Shards always cover whole blocks (the store writes shard boundaries on
block-group edges), so per-shard query addressing is the global addressing
with row offsets rebased to the shard's first row.

``DeviceTileCache`` is the HBM paging policy: a bounded LRU of shard id ->
device tile with hit/fault counters, shared by the QueryEngine and the
serving subsystem (which exports the counters as metrics).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from . import codec as _codec


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Geometric metadata of an arena; pure, host-side, and immutable.

    row_offset[b] is the global first arena row of block b; block b owns
    rows [row_offset[b], row_offset[b] + block_width[b]). Document i of
    the original corpus lives at slot doc_slot[i] (block slot//block_docs,
    column slot%block_docs).
    """

    row_offset: np.ndarray   # int32 [n_blocks]
    block_width: np.ndarray  # int32 [n_blocks]
    doc_slot: np.ndarray     # int32 [n_docs]
    doc_n_terms: np.ndarray  # int32 [n_docs]
    block_docs: int
    n_docs: int

    @staticmethod
    def make(row_offset, block_width, doc_slot, doc_n_terms,
             block_docs: int, n_docs: int) -> "ArenaLayout":
        return ArenaLayout(
            row_offset=np.asarray(row_offset, dtype=np.int32),
            block_width=np.asarray(block_width, dtype=np.int32),
            doc_slot=np.asarray(doc_slot, dtype=np.int32),
            doc_n_terms=np.asarray(doc_n_terms, dtype=np.int32),
            block_docs=int(block_docs),
            n_docs=int(n_docs),
        )

    # -- derived geometry ---------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return int(self.row_offset.shape[0])

    @property
    def doc_words(self) -> int:
        return self.block_docs // 32

    @property
    def total_rows(self) -> int:
        if self.n_blocks == 0:
            return 0
        return int(self.row_offset[-1]) + int(self.block_width[-1])

    @property
    def n_slots(self) -> int:
        return self.n_blocks * self.block_docs

    def block_row_range(self, b: int) -> tuple[int, int]:
        start = int(self.row_offset[b])
        return start, start + int(self.block_width[b])

    def shard_blocks(self, shard_row_starts: np.ndarray
                     ) -> list[tuple[int, int]]:
        """Partition blocks by shard: returns [(block_start, block_end)] per
        shard for row boundaries ``shard_row_starts`` (int64 [n_shards+1]).
        Every shard boundary must fall on a block boundary."""
        bounds = np.concatenate([self.row_offset.astype(np.int64),
                                 [self.total_rows]])
        out = []
        for s in range(len(shard_row_starts) - 1):
            lo = int(np.searchsorted(bounds, shard_row_starts[s]))
            hi = int(np.searchsorted(bounds, shard_row_starts[s + 1]))
            if (bounds[lo] != shard_row_starts[s]
                    or bounds[hi] != shard_row_starts[s + 1]):
                raise ValueError("shard boundary not on a block boundary")
            out.append((lo, hi))
        return out


# --------------------------------------------------------------------------
# Storage backends
# --------------------------------------------------------------------------

class ArenaStorage:
    """Protocol for arena byte storage.

    shape/dtype mirror the dense array; shards are contiguous row ranges
    covering [0, total_rows) whose boundaries are ``shard_row_starts``
    (int64 [n_shards + 1]).
    """

    shape: tuple[int, int]
    dtype: np.dtype
    shard_row_starts: np.ndarray

    @property
    def n_shards(self) -> int:
        return len(self.shard_row_starts) - 1

    def nbytes(self) -> int:
        return int(self.shape[0]) * int(self.shape[1]) * \
            np.dtype(self.dtype).itemsize

    def shard_nbytes(self, s: int) -> int:
        rows = int(self.shard_row_starts[s + 1] - self.shard_row_starts[s])
        return rows * int(self.shape[1]) * np.dtype(self.dtype).itemsize

    # -- byte access (implemented per backend) ------------------------------
    def shard_host(self, s: int) -> np.ndarray:
        raise NotImplementedError

    def shard_device(self, s: int) -> jnp.ndarray:
        return jnp.asarray(self.shard_host(s))

    def full_host(self) -> np.ndarray:
        return np.concatenate([np.asarray(self.shard_host(s))
                               for s in range(self.n_shards)], axis=0)

    def full_device(self) -> jnp.ndarray:
        """Dense device arena — the legacy path; materializes everything."""
        if self.n_shards == 1:
            return self.shard_device(0)
        return jnp.concatenate([self.shard_device(s)
                                for s in range(self.n_shards)], axis=0)

    def read_rows_host(self, rows: np.ndarray) -> np.ndarray:
        """Arbitrary global rows, host-side (point-query path). Pages only
        the rows' shards; never materializes the dense arena for mapped
        storage."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.size, self.shape[1]), dtype=self.dtype)
        flat = rows.reshape(-1)
        owner = np.searchsorted(self.shard_row_starts, flat, side="right") - 1
        for s in np.unique(owner):
            sel = owner == s
            local = flat[sel] - int(self.shard_row_starts[s])
            out[sel] = np.asarray(self.shard_host(int(s)))[local]
        return out.reshape(*rows.shape, self.shape[1])

    # -- popcount stats (recorded by v2 stores; absent elsewhere) -----------
    def has_popcounts(self) -> bool:
        return False

    def shard_popcounts(self, s: int) -> np.ndarray | None:
        return None

    def row_popcounts(self, rows: np.ndarray) -> np.ndarray | None:
        return None

    def mean_popcount(self) -> float | None:
        return None

    # -- compression surface (raw everywhere except MappedArena) ------------
    def shard_codec(self, s: int) -> str:
        """This shard's on-disk codec (repro.core.codec.CODECS)."""
        return _codec.CODEC_RAW

    def shard_comp_nbytes(self, s: int) -> int:
        """Encoded (on-disk) shard bytes (== shard_nbytes for raw)."""
        return self.shard_nbytes(s)

    def shard_hbm_nbytes(self, s: int) -> int:
        """Bytes the shard's compressed DEVICE form needs: dict + refs
        for rowdict codecs (what the tile cache stages), raw otherwise.
        Unlike ``shard_comp_nbytes`` this excludes disk-only RLE gains —
        it is the working-set number the cache accounts in."""
        return self.shard_nbytes(s)

    def shard_dict_host(self, s: int
                        ) -> tuple[np.ndarray, np.ndarray] | None:
        """The shard's HBM-compressible dictionary form — (dict_rows
        uint32 [D, W], refs int32 [rows]) for rowdict-coded shards, None
        otherwise. The DeviceTileCache stages THIS instead of the
        expanded tile when the compressed score path is planned."""
        return None

    def comp_summary(self) -> tuple[int, int, int]:
        """(raw_bytes, encoded_bytes, n_compressed_shards) over all
        shards — the store-level compression ratio the manifest records
        per shard, aggregated."""
        raw = comp = n = 0
        for s in range(self.n_shards):
            raw += self.shard_nbytes(s)
            comp += self.shard_comp_nbytes(s)
            if self.shard_codec(s) != _codec.CODEC_RAW:
                n += 1
        return raw, comp, n

    def dict_ratio(self) -> float | None:
        """HBM compression ratio of the dict-form shards: expanded bytes
        over (dict + refs) bytes, aggregated across every rowdict-coded
        shard. None when no shard has a dict form — the planner/tuner
        gate the compressed kernel paths on this."""
        raw = comp = 0
        for s in range(self.n_shards):
            if self.shard_codec(s) in _codec.DICT_CODECS:
                raw += self.shard_nbytes(s)
                comp += self.shard_hbm_nbytes(s)
        if comp == 0:
            return None
        return raw / comp


def _starts(n_rows: int) -> np.ndarray:
    return np.array([0, n_rows], dtype=np.int64)


class DeviceArena(ArenaStorage):
    """One dense device-resident array — today's behavior, one shard."""

    def __init__(self, arena):
        self.arena = arena
        self.shape = tuple(arena.shape)
        self.dtype = np.dtype(getattr(arena, "dtype", np.uint32))
        self.shard_row_starts = _starts(self.shape[0])
        self._host: np.ndarray | None = None

    def shard_host(self, s: int) -> np.ndarray:
        if self._host is None:
            self._host = np.asarray(self.arena)
        return self._host

    def shard_device(self, s: int) -> jnp.ndarray:
        return self.arena

    def full_device(self):
        return self.arena


class HostArena(ArenaStorage):
    """One dense host array; the device copy is made lazily and cached."""

    def __init__(self, arena: np.ndarray):
        self.arena = np.asarray(arena)
        self.shape = tuple(self.arena.shape)
        self.dtype = self.arena.dtype
        self.shard_row_starts = _starts(self.shape[0])
        self._device: jnp.ndarray | None = None

    def shard_host(self, s: int) -> np.ndarray:
        return self.arena

    def shard_device(self, s: int) -> jnp.ndarray:
        if self._device is None:
            self._device = jnp.asarray(self.arena)
        return self._device


class MappedArena(ArenaStorage):
    """Row-range shards backed by raw ``.npy`` files (np.memmap), lazy
    compressed sources (``repro.core.codec.CompressedShardSource``),
    and/or in-memory arrays. File-backed shards are opened lazily with
    ``mmap_mode='r'`` so touching a shard costs page faults, not a load;
    in-memory sources make merge an O(metadata) shard-list concatenation.

    Compressed sources decode on first ``shard_host`` touch (the decoded
    tile is cached; all existing raw consumers stay bit-identical), or
    hand their dictionary form to the tile cache via ``shard_dict_host``
    without ever expanding. ``decode_observer(shard, codec, seconds)``,
    when set, sees every host-side decode — the serving layer wires it
    to the obs registry's decode-time histogram.
    """

    def __init__(self, sources: list, shard_row_starts: np.ndarray,
                 doc_words: int, dtype=np.uint32, pop_sources: list | None
                 = None):
        self.sources = list(sources)        # Path | str | ndarray | source
        self.shard_row_starts = np.asarray(shard_row_starts, dtype=np.int64)
        if len(self.sources) != self.n_shards:
            raise ValueError("sources / shard_row_starts length mismatch")
        self.shape = (int(self.shard_row_starts[-1]), int(doc_words))
        self.dtype = np.dtype(dtype)
        self._open: dict[int, np.ndarray] = {}
        self._open_dict: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        # optional per-slice popcount sidecars (Path | ndarray | None per
        # shard, from the v2 manifest's "pops" field): rarest-term-first
        # ordering for the pruned executor; None entries degrade to
        # natural term order
        self.pop_sources = (list(pop_sources) if pop_sources is not None
                            else [None] * self.n_shards)
        if len(self.pop_sources) != self.n_shards:
            raise ValueError("pop_sources / shard_row_starts length mismatch")
        self._open_pops: dict[int, np.ndarray] = {}
        self.decode_observer = None
        self.decodes = 0

    def _shard_rows(self, s: int) -> int:
        return int(self.shard_row_starts[s + 1] - self.shard_row_starts[s])

    def _notify_decode(self, s: int, codec: str, seconds: float) -> None:
        self.decodes += 1
        if self.decode_observer is not None:
            try:
                self.decode_observer(s, codec, seconds)
            except Exception:
                pass              # accounting must never fail a read

    def shard_host(self, s: int) -> np.ndarray:
        a = self._open.get(s)
        if a is None:
            src = self.sources[s]
            if isinstance(src, _codec.CompressedShardSource):
                t0 = time.perf_counter()
                a = src.load().decode()
                self._notify_decode(s, src.codec,
                                    time.perf_counter() - t0)
            elif isinstance(src, np.ndarray):
                a = src
            else:
                a = np.load(src, mmap_mode="r")
            want_rows = self._shard_rows(s)
            if a.shape != (want_rows, self.shape[1]):
                raise ValueError(
                    f"shard {s}: shape {a.shape} != "
                    f"({want_rows}, {self.shape[1]})")
            self._open[s] = a
        return a

    # -- popcount stats surface ----------------------------------------------
    def has_popcounts(self) -> bool:
        """True when EVERY shard carries a popcount sidecar — the pruned
        executor needs a total order over a query's terms, so partial
        stats degrade to natural order."""
        return all(p is not None for p in self.pop_sources)

    def shard_popcounts(self, s: int) -> np.ndarray | None:
        """Per-row popcounts of shard ``s`` (uint32 [rows], mmap-backed),
        or None when the store predates the stats field."""
        src = self.pop_sources[s]
        if src is None:
            return None
        a = self._open_pops.get(s)
        if a is None:
            a = src if isinstance(src, np.ndarray) else np.load(
                src, mmap_mode="r")
            if a.shape != (self._shard_rows(s),):
                raise ValueError(
                    f"shard {s}: popcount sidecar shape {a.shape} != "
                    f"({self._shard_rows(s)},)")
            self._open_pops[s] = a
        return a

    def row_popcounts(self, rows: np.ndarray) -> np.ndarray | None:
        """Popcounts of arbitrary GLOBAL arena rows (int64 [..] -> int64
        [..]), reading only the touched sidecar pages — never the arena.
        None when any shard lacks stats."""
        if not self.has_popcounts():
            return None
        rows = np.asarray(rows, dtype=np.int64)
        flat = rows.reshape(-1)
        out = np.empty(flat.size, dtype=np.int64)
        owner = np.searchsorted(self.shard_row_starts, flat,
                                side="right") - 1
        for s in np.unique(owner):
            sel = owner == s
            local = flat[sel] - int(self.shard_row_starts[s])
            out[sel] = np.asarray(self.shard_popcounts(int(s))[local],
                                  dtype=np.int64)
        return out.reshape(rows.shape)

    def mean_popcount(self) -> float | None:
        """Mean set-bit count per arena row across all shards (the corpus
        density the serving planner's prune-rate prediction uses), or
        None without stats."""
        if not self.has_popcounts():
            return None
        total = n = 0
        for s in range(self.n_shards):
            p = self.shard_popcounts(s)
            total += int(np.asarray(p, dtype=np.int64).sum())
            n += p.shape[0]
        return total / n if n else 0.0

    # -- compression surface -------------------------------------------------
    def shard_codec(self, s: int) -> str:
        src = self.sources[s]
        if isinstance(src, _codec.CompressedShardSource):
            return src.codec
        return _codec.CODEC_RAW

    def shard_comp_nbytes(self, s: int) -> int:
        src = self.sources[s]
        if isinstance(src, _codec.CompressedShardSource):
            return int(src.comp_nbytes)
        return self.shard_nbytes(s)

    def shard_hbm_nbytes(self, s: int) -> int:
        d = self.shard_dict_host(s)
        if d is None:
            return self.shard_nbytes(s)
        return int(d[0].nbytes) + int(d[1].nbytes)

    def shard_dict_host(self, s: int
                        ) -> tuple[np.ndarray, np.ndarray] | None:
        if self.shard_codec(s) not in _codec.DICT_CODECS:
            return None
        cached = self._open_dict.get(s)
        if cached is None:
            src = self.sources[s]
            t0 = time.perf_counter()
            cached = src.load().dict_form()
            # rowdict mmaps straight through (no decode work); the +rle
            # variant expands its dictionary payload here — count it
            if src.codec == _codec.CODEC_ROWDICT_RLE:
                self._notify_decode(s, src.codec,
                                    time.perf_counter() - t0)
            self._open_dict[s] = cached
        return cached

    @staticmethod
    def concat(a: "ArenaStorage", b: "ArenaStorage") -> "MappedArena":
        """Row-axis concatenation without touching bytes: the merged arena
        is the two shard lists back to back (paper section 2.3 merging as
        an O(metadata) operation)."""
        if a.shape[1] != b.shape[1]:
            raise ValueError("doc_words mismatch")

        def shard_sources(st: ArenaStorage) -> list:
            if isinstance(st, MappedArena):
                return st.sources
            return [st.shard_host(s) for s in range(st.n_shards)]

        starts = np.concatenate([
            a.shard_row_starts,
            b.shard_row_starts[1:] + int(a.shard_row_starts[-1])])
        return MappedArena(shard_sources(a) + shard_sources(b), starts,
                           doc_words=a.shape[1], dtype=a.dtype)


def wrap_arena(arena) -> ArenaStorage:
    """Adopt a raw arena value under the storage protocol: numpy stays on
    host (HostArena), anything device-shaped (jax arrays, abstract
    ShapeDtypeStructs from the dry-run lowering) is a DeviceArena."""
    if isinstance(arena, ArenaStorage):
        return arena
    if isinstance(arena, np.ndarray):
        return HostArena(arena)
    return DeviceArena(arena)


# --------------------------------------------------------------------------
# HBM paging
# --------------------------------------------------------------------------

def _pad_dict_rows(n: int) -> int:
    """Pow2 padding (floor 8) for staged dictionary heights — mirrors the
    query planner's unique-row padding so compressed kernel shapes bucket
    into O(log) variants instead of one compile per distinct D."""
    return max(8, 1 << max(0, int(n) - 1).bit_length())


def common_tile_rows(storage: ArenaStorage) -> int | None:
    """Row count unifying all of a sharded storage's tiles (the tallest
    shard), or None for dense single-shard storage (no padding needed)."""
    if storage.n_shards <= 1:
        return None
    return int(np.max(np.diff(storage.shard_row_starts)))


class DeviceTileCache:
    """Bounded LRU of shard id -> device tile.

    ``capacity_bytes`` caps resident tile bytes (None = unbounded: every
    shard sticks after first touch, the right default for engines that own
    the whole device). A miss ("page fault") stages the shard host->device
    and may evict least-recently-used tiles; counters feed the serving
    metrics (shard residency / page faults).

    ``pad_rows_to`` zero-pads every staged tile to a common row count
    (typically the tallest shard): addressed rows are always < the real
    shard height, so results are unchanged, but all tiles share one shape
    and the scoring kernels compile ONCE per (bucket, method) instead of
    once per distinct shard height — compile time would otherwise dominate
    cold out-of-core serving on stores with many block groups.

    ``prefetch`` is the double-buffering hook: it stages a tile WITHOUT
    blocking the caller's compute stream (device transfers are dispatched
    asynchronously), so paged scoring loops can overlap the next shard's
    host->device copy with the current shard's kernel. ``faults`` counts
    every staging (demand or prefetch — each is one H2D transfer);
    ``prefetch_hits`` counts gets served by a previously prefetched tile,
    so prefetch_hits / prefetched is the prefetch usefulness rate exported
    by the serving metrics.

    ``device`` optionally pins staged tiles to a specific jax device — the
    multi-host serving path gives each fake-host worker its own device.

    Compressed residency: for rowdict-coded shards, ``get_compressed``
    stages the (dict_rows, refs) pair instead of the expanded tile — the
    HBM working set shrinks by the shard's measured ratio and the cache
    accounts the COMPRESSED bytes, so the same ``capacity_bytes`` holds
    ratio-times more shards. Raw and compressed forms of a shard are
    independent cache entries (int key vs ("c", shard)) sharing one LRU
    and one byte budget; ``raw_bytes_staged`` / ``comp_bytes_staged``
    accumulate staged bytes per form for the serving metrics.
    """

    def __init__(self, storage: ArenaStorage,
                 capacity_bytes: int | None = None,
                 pad_rows_to: int | None = None,
                 device=None):
        self.storage = storage
        self.capacity_bytes = capacity_bytes
        self.pad_rows_to = pad_rows_to
        self.device = device
        # key: shard id (raw tile) or ("c", shard id) (dict form)
        # The LRU map, byte budget, and counters mutate under one lock so
        # the cache is safe to share between the interactive scoring
        # workers and the bulk lane WITHOUT serializing their kernel
        # work behind the loop's backend lock: staged tiles are immutable
        # device arrays, so a reference obtained under the lock stays
        # valid through a concurrent eviction.
        self._lock = threading.RLock()
        self._tiles: "OrderedDict" = OrderedDict()
        self._sizes: dict = {}
        self._prefetched: set = set()
        self.resident_bytes = 0
        self.hits = 0
        self.faults = 0
        self.prefetched = 0
        self.prefetch_hits = 0
        self.raw_bytes_staged = 0
        self.comp_bytes_staged = 0
        # Per-shard accounting (the global totals above cannot say WHICH
        # shard keeps faulting when the working set outsizes the cache).
        self.shard_hits: dict[int, int] = {}
        self.shard_faults: dict[int, int] = {}
        self.shard_evictions: dict[int, int] = {}
        # Optional event hook: observer(shard, event, seconds) with event
        # in {"hit", "fault", "prefetch", "eviction"}; ``seconds`` is the
        # staging (dispatch) time for faults/prefetches, 0.0 otherwise.
        # The serving layer wires this to labeled registry counters and
        # to trace spans naming the faulted shard.
        self.observer = None

    def _notify(self, s: int, event: str, seconds: float = 0.0) -> None:
        if self.observer is not None:
            try:
                self.observer(s, event, seconds)
            except Exception:
                pass              # accounting must never fail a gather

    def _put(self, host: np.ndarray) -> jnp.ndarray:
        if self.device is None:
            return jnp.asarray(host)
        import jax
        return jax.device_put(host, self.device)

    def _stage(self, s: int) -> jnp.ndarray:
        if not self.pad_rows_to:
            return (self.storage.shard_device(s) if self.device is None
                    else self._put(self.storage.shard_host(s)))
        host = self.storage.shard_host(s)
        pad = self.pad_rows_to - host.shape[0]
        if pad < 0:
            raise ValueError(f"shard {s} taller than pad_rows_to")
        if pad == 0 and self.device is None:
            return self.storage.shard_device(s)
        return self._put(np.pad(host, ((0, pad), (0, 0))))

    def _stage_compressed(self, s: int) -> tuple:
        d = self.storage.shard_dict_host(s)
        if d is None:
            raise ValueError(
                f"shard {s} has no dict form "
                f"(codec {self.storage.shard_codec(s)!r})")
        dict_rows, refs = d
        D = int(dict_rows.shape[0])
        d_pad = _pad_dict_rows(D) - D
        if d_pad:
            dict_rows = np.pad(np.asarray(dict_rows), ((0, d_pad), (0, 0)))
        pad_to = self.pad_rows_to or int(refs.shape[0])
        r_pad = pad_to - int(refs.shape[0])
        if r_pad < 0:
            raise ValueError(f"shard {s} taller than pad_rows_to")
        if r_pad:                  # padded rows ref slot 0; never addressed
            refs = np.pad(np.asarray(refs), (0, r_pad))
        return (self._put(np.ascontiguousarray(dict_rows)),
                self._put(np.ascontiguousarray(refs)))

    def _tile_nbytes(self, s: int) -> int:
        if not self.pad_rows_to:
            return self.storage.shard_nbytes(s)
        return (self.pad_rows_to * int(self.storage.shape[1])
                * np.dtype(self.storage.dtype).itemsize)

    @staticmethod
    def _shard_of(key) -> int:
        return key[1] if isinstance(key, tuple) else key

    def __len__(self) -> int:
        return len(self._tiles)

    @property
    def resident_shards(self) -> tuple[int, ...]:
        return tuple(self._shard_of(k) for k in self._tiles)

    def has_compressed(self, s: int) -> bool:
        return ("c", s) in self._tiles

    def _evict_victim(self):
        """Ratio-aware victim selection: the least-recently-used RAW tile
        goes first — a dict-coded entry packs ratio-times more arena per
        resident byte (and costs a re-encode-shaped decode to restage), so
        raw tiles are the cheap bytes to give back. Falls back to plain
        LRU when only dict entries remain."""
        for key in self._tiles:                # OrderedDict: LRU first
            if not isinstance(key, tuple):
                return key
        return next(iter(self._tiles))

    def _insert(self, key) -> tuple:
        s = self._shard_of(key)
        compressed = isinstance(key, tuple)
        t0 = time.perf_counter()
        tile = self._stage_compressed(s) if compressed else self._stage(s)
        staged_s = time.perf_counter() - t0
        if compressed:
            need = sum(int(t.nbytes) for t in tile)
            self.comp_bytes_staged += need
        else:
            need = self._tile_nbytes(s)
            self.raw_bytes_staged += need
        if self.capacity_bytes is not None:
            while (self._tiles
                   and self.resident_bytes + need > self.capacity_bytes):
                old = self._evict_victim()
                del self._tiles[old]
                self.resident_bytes -= self._sizes.pop(old)
                self._prefetched.discard(old)
                old_s = self._shard_of(old)
                self.shard_evictions[old_s] = \
                    self.shard_evictions.get(old_s, 0) + 1
                self._notify(old_s, "eviction")
        self._tiles[key] = tile
        self._sizes[key] = need
        self.resident_bytes += need
        return tile, staged_s

    def _get(self, key):
        with self._lock:
            s = self._shard_of(key)
            tile = self._tiles.get(key)
            if tile is not None:
                self._tiles.move_to_end(key)
                self.hits += 1
                self.shard_hits[s] = self.shard_hits.get(s, 0) + 1
                if key in self._prefetched:
                    self._prefetched.discard(key)
                    self.prefetch_hits += 1
                self._notify(s, "hit")
                return tile
            self.faults += 1
            self.shard_faults[s] = self.shard_faults.get(s, 0) + 1
            tile, staged_s = self._insert(key)
            self._notify(s, "fault", staged_s)
            return tile

    def get(self, s: int) -> jnp.ndarray:
        return self._get(s)

    def get_compressed(self, s: int) -> tuple:
        """(dict_tile uint32 [D_pad, W], refs int32 [pad_rows_to]) on
        device — the fused kernels' decode inputs. D is padded to a pow2
        (floor 8) so kernel shapes bucket; refs pad with slot 0."""
        return self._get(("c", s))

    def _prefetch(self, key) -> bool:
        with self._lock:
            if key in self._tiles:
                return False
            s = self._shard_of(key)
            self.faults += 1
            self.shard_faults[s] = self.shard_faults.get(s, 0) + 1
            self.prefetched += 1
            self._prefetched.add(key)
            _, staged_s = self._insert(key)
            self._notify(s, "prefetch", staged_s)
            return True

    def prefetch(self, s: int) -> bool:
        """Stage shard ``s`` ahead of use (double buffering). The transfer
        is dispatched without blocking, so it overlaps with whatever the
        caller computes next; a later ``get(s)`` finds the tile resident.
        Counts as a fault (it IS one H2D staging); returns True if a
        transfer was started, False if the tile was already resident."""
        return self._prefetch(s)

    def prefetch_compressed(self, s: int) -> bool:
        """``prefetch`` for the dict form (see ``get_compressed``)."""
        return self._prefetch(("c", s))

    def clear(self) -> None:
        with self._lock:
            self._tiles.clear()
            self._sizes.clear()
            self._prefetched.clear()
            self.resident_bytes = 0
