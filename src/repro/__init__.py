"""repro: COBS (compact bit-sliced signature index) as a multi-pod JAX framework."""

__version__ = "1.0.0"
