"""ServingLoop: the active front-end the passive batcher was designed for.

Everything below ``submit`` in the serving stack is deliberately passive —
the micro-batcher flushes only when somebody polls it, which keeps it
deterministic for tests and embeddable anywhere. But COBS's one-kernel-
per-batch economics (the paper's §3 bulk query) only pay off when
CONCURRENT INDEPENDENT clients coalesce into shared micro-batches, and
independent clients cannot poll each other's server. The loop closes that
gap with two thread roles around an unmodified QueryServer / Frontend:

* the **dispatcher** sleeps until the batcher's ``next_due_at`` (or a
  submission wakes it), flushes due micro-batches via ``poll_batches``
  (expired requests are answered DROPPED right there), samples the
  queue-depth gauge, and hands each flushed batch to the work queue;
* **workers** pull flushed micro-batches and run ``score_batch``.
  Scoring is serialized per backend (one device; the planner's score-fn
  cache, tile cache, and metrics are single-threaded state), but response
  callbacks are delivered OUTSIDE the lock, so wire serialization and
  client wakeups overlap the next batch's kernel.

Requests enter through ``submit`` with a completion callback: fast paths
(result-cache hits, point queries, empty queries, backpressure REJECTED)
fire the callback synchronously; everything else fires it from the worker
that scores — or drops — the request. Exactly one callback per submit,
including during shutdown.

Backpressure is end to end: when the batcher's hard queue cap refuses a
request, the caller gets a Status.REJECTED response through the same
callback (the wire layer turns it into a 429-style reply) — never a hang.
``stop(drain=True)`` is graceful: no new submissions, every queued
request force-flushed and scored, every callback fired, then the threads
join. ``drain=False`` answers queued requests REJECTED without scoring.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import numpy as np

from .request import QueryResponse, Status

# Dispatcher fallback tick: the loop sleeps until the batcher's next due
# instant (submissions and finished batches wake it via an event), but
# never longer than this defensive bound — a missed wakeup is re-checked
# at worst one tick later. It is a backstop, not the latency floor.
DEFAULT_POLL_S = 0.1


class LoopClosed(RuntimeError):
    """submit() after stop(): the loop no longer accepts work."""


class ServingLoop:
    """Active dispatcher + scoring workers around a QueryServer/Frontend.

    ``backend`` is anything with the serving surface the two front-ends
    share: submit / poll_batches / score_batch / take_response / batcher /
    metrics / clock.
    """

    def __init__(self, backend, *, poll_interval_s: float = DEFAULT_POLL_S,
                 workers: int = 1):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.backend = backend
        self.poll_interval_s = poll_interval_s
        self.n_workers = workers
        # One reentrant lock serializes ALL backend access (submission,
        # flush, scoring): the backends are single-threaded by design.
        # Coalescing benefits — submissions arriving while a batch scores
        # queue up at the lock and enter the batcher together.
        self._lock = threading.RLock()
        self._cbs: dict[int, Callable[[QueryResponse], None]] = {}
        self._wake = threading.Event()
        self._batchq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._inflight = 0            # flushed batches not yet scored
        self._accepting = False
        self._stopping = False
        self._drain = True
        self._threads: list[threading.Thread] = []
        # Attached offline bulk lane (set by BulkLane(loop=...)): its
        # sweeps take this loop's lock one shard at a time and yield to
        # interactive batches between shards; stop() halts it first so a
        # mid-sweep job checkpoints before the workers join.
        self.bulk_lane = None

    # -- lifecycle -----------------------------------------------------------
    @property
    def clock(self):
        return self.backend.clock

    @property
    def running(self) -> bool:
        return bool(self._threads)

    def start(self) -> "ServingLoop":
        if self._threads:
            raise RuntimeError("loop already started")
        self._accepting = True
        self._stopping = False
        # The loop owns trace finishing: backends attach trace + stage
        # breakdown at response creation but leave the trace open so the
        # callback-delivery time lands in it as a final "deliver" span
        # (sealed in _deliver, after the callback returns).
        tracer = getattr(self.backend, "tracer", None)
        if tracer is not None:
            tracer.defer_finish = True
        d = threading.Thread(target=self._dispatch, name="serve-dispatch",
                             daemon=True)
        self._threads = [d] + [
            threading.Thread(target=self._work, name=f"serve-worker{i}",
                             daemon=True)
            for i in range(self.n_workers)]
        for t in self._threads:
            t.start()
        return self

    def stop(self, *, drain: bool = True, timeout_s: float = 60.0) -> None:
        """Graceful shutdown. drain=True scores everything still queued
        before returning; drain=False answers it REJECTED. Either way
        every outstanding callback fires before the threads join."""
        if not self._threads:
            return
        if self.bulk_lane is not None:
            self.bulk_lane.stop()
        with self._lock:
            self._accepting = False
            self._drain = drain
            self._stopping = True
        self._wake.set()
        for t in self._threads:
            t.join(timeout=timeout_s)
        self._threads = []
        tracer = getattr(self.backend, "tracer", None)
        if tracer is not None:
            tracer.defer_finish = False

    # -- submission ----------------------------------------------------------
    def submit(self, pattern=None, *, terms: Optional[np.ndarray] = None,
               threshold: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline: Optional[float] = None,
               trace_id: int = 0,
               on_done: Callable[[QueryResponse], None]) -> int:
        """Thread-safe submit; ``on_done(response)`` fires exactly once —
        synchronously for fast paths (cache hit, point query, REJECTED),
        from a loop thread otherwise. Raises LoopClosed after stop()."""
        with self._lock:
            if not self._accepting:
                raise LoopClosed("serving loop is shut down")
            rid = self.backend.submit(pattern, terms=terms,
                                      threshold=threshold, top_k=top_k,
                                      deadline=deadline,
                                      trace_id=trace_id)
            resp = self.backend.take_response(rid)
            if resp is None:
                # END-TO-END backpressure: the batcher's cap only counts
                # un-flushed requests, but the dispatcher moves flushed
                # batches to the (unbounded) work queue immediately — so
                # the loop caps TOTAL outstanding work (queued + flushed
                # + scoring) at the same knob. Checked only for requests
                # that actually ENQUEUED: fast paths (cache hits, point
                # queries, empty queries) cost no queue space and stay
                # servable under overload.
                if (len(self._cbs) >= self.backend.batcher.max_queued
                        and self.backend.retract(rid)):
                    self.backend.metrics.record_rejected()
                    resp = QueryResponse(rid, Status.REJECTED)
                else:
                    self._cbs[rid] = on_done
                    self.backend.metrics.set_queue_depth(
                        len(self.backend.batcher))
        if resp is not None:
            on_done(resp)          # outside the lock
        else:
            self._wake.set()
        return rid

    def pending(self) -> int:
        """Requests queued or mid-score (approximate, for monitoring)."""
        with self._lock:
            return len(self._cbs)

    def metrics_snapshot(self):
        """Consistent metrics snapshot: taken under the backend lock, so
        a monitoring thread never iterates the percentile deques while a
        worker is appending to them (deque mutation during iteration is
        a RuntimeError)."""
        with self._lock:
            return self.backend.metrics.snapshot()

    # -- internals -----------------------------------------------------------
    def _ready_callbacks(self) -> list[tuple[Callable, QueryResponse]]:
        """MUST be called under the lock: pair every finished response
        with its registered callback."""
        out = []
        for rid, resp in self.backend.pop_responses().items():
            cb = self._cbs.pop(rid, None)
            if cb is not None:
                out.append((cb, resp))
        return out

    def _deliver(self, ready: list[tuple[Callable, QueryResponse]]) -> None:
        tracer = getattr(self.backend, "tracer", None)
        for cb, resp in ready:
            t0 = self.clock()
            try:
                cb(resp)
            except Exception:
                # a dead client (e.g. socket closed mid-reply) must not
                # take the loop thread with it; the result is simply
                # undeliverable
                pass
            if resp.trace is not None and tracer is not None:
                resp.trace.add("deliver", t0, self.clock())
                tracer.finish(resp.trace)

    def _flush(self, *, force: bool) -> None:
        """Flush due batches into the work queue; deliver any DROPPED."""
        with self._lock:
            for b in self.backend.poll_batches(force=force):
                self._inflight += 1
                self._batchq.put(b)
            self.backend.metrics.set_queue_depth(len(self.backend.batcher))
            ready = self._ready_callbacks()
        self._deliver(ready)

    def _reject_queued(self) -> None:
        """drain=False shutdown: answer everything still queued REJECTED
        without scoring it."""
        with self._lock:
            ready = []
            for b in self.backend.poll_batches(force=True):
                for r in b.requests:
                    self.backend.metrics.record_rejected()
                    cb = self._cbs.pop(r.request_id, None)
                    if cb is not None:
                        ready.append((cb, QueryResponse(
                            r.request_id, Status.REJECTED)))
            ready.extend(self._ready_callbacks())
        self._deliver(ready)

    def _idle(self) -> bool:
        with self._lock:
            return len(self.backend.batcher) == 0 and self._inflight == 0

    def _dispatch(self) -> None:
        while not self._stopping:
            # sleep until the earliest flush deadline (or a submission /
            # stop wakes us); an empty batcher sleeps long — submissions
            # always wake the loop, so idleness costs nothing
            with self._lock:
                due = self.backend.batcher.next_due_at()
            # sleep until the due instant itself — a NEW earlier-due
            # submission always wakes the loop, so no shorter tick is
            # needed; poll_interval_s is a defensive ceiling, not a poll
            timeout = self.poll_interval_s if due is None else \
                min(max(0.0, due - self.clock()), self.poll_interval_s)
            if timeout > 0:
                self._wake.wait(timeout)
            self._wake.clear()
            self._flush(force=False)
        # shutdown: drain (score) or reject everything still queued, then
        # wait for workers to finish in-flight batches
        if self._drain:
            while not self._idle():
                self._flush(force=True)
                self._wake.wait(self.poll_interval_s)
                self._wake.clear()
        else:
            self._reject_queued()
            while not self._idle():
                self._wake.wait(self.poll_interval_s)
                self._wake.clear()
        for _ in range(self.n_workers):
            self._batchq.put(None)

    def _work(self) -> None:
        while True:
            batch = self._batchq.get()
            if batch is None:
                return
            ready: list = []
            with self._lock:
                try:
                    self.backend.score_batch(batch)
                except Exception:
                    # a kernel/device failure mid-batch: the batch is
                    # already out of the batcher, so answer its requests
                    # FAILED instead of letting the exception kill this
                    # worker (which would leak _inflight and wedge the
                    # loop) — exactly-once callbacks hold even here
                    for r in batch.requests:
                        resp = self.backend.take_response(r.request_id)
                        if resp is None:
                            self.backend.metrics.record_failed()
                            resp = self.backend.finalize_trace(
                                r.trace, QueryResponse(r.request_id,
                                                       Status.FAILED))
                        cb = self._cbs.pop(r.request_id, None)
                        if cb is not None:
                            ready.append((cb, resp))
                finally:
                    self._inflight -= 1
                ready.extend(self._ready_callbacks())
            self._deliver(ready)
            self._wake.set()      # dispatcher may be waiting on inflight
