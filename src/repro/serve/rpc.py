"""The networked shard data plane: real RPC fan-out with cancellable
hedges.

Until PR 10 the "multi-host" frontend was threads in one process: shard
dispatch was an in-process function call, so hedging was simulation-only
(a synchronous backup can never beat an already-returned primary) and
multi-machine benchmarks were dishonest. This module puts the wire
(repro.serve.net, protocol v4) under the ``HedgedExecutor`` seam:

* ``WorkerServer`` — one ``ShardWorker`` behind its own TCP server.
  SHARD_QUERY frames land in a job queue drained by a single scorer
  thread (one device per host — dispatches serialize anyway); CANCEL
  frames set the rid's cancellation flag, which the scorer observes
  between shard tiles (``ShardWorker.score_candidates(cancelled=...)``)
  and answers SHARD_CANCELLED without scoring the rest. STATS returns
  the worker's counters (``cancelled_tiles`` is the headline: a hedge
  loser was OBSERVABLY cancelled, not silently completed).
* ``WorkerChannel`` — one reconnecting client channel per placement
  node: a persistent pipelined connection, a reader thread resolving
  per-rid futures, liveness PINGs, and exponential backoff with jitter
  when the peer dies. A channel failure fails every in-flight future
  with ``RpcError`` (an ``AttemptFailed``: the executor fails over) and
  redials in the background — connections are reused across batches.
* ``WorkerPool`` — placement node name -> live channel, plus the
  fleet-level accounting (per-node PruneStats accumulated off
  SHARD_RESULT frames) the frontend's metrics deltas read.
* ``RpcFrontend`` — the scatter/gather frontend with its dispatch seam
  rewired: every shard dispatch is ``HedgedExecutor.run_async`` over
  channel futures, so hedged backups are REAL duplicate RPCs fired on
  the wall clock and the loser is cancelled with a CANCEL frame when
  the winner returns. Gather, final selection, and therefore results
  stay bit-identical to the in-process frontend and the single-host
  QueryEngine.
"""
from __future__ import annotations

import itertools
import json
import queue
import random
import socket
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from ..index.hedge import (AllReplicasFailed, AttemptFailed,
                           HedgedExecutor)
from ..index.placement import ShardPlacement
from ..obs import EventLog, KernelProfiler, Tracer
from .batcher import MicroBatcher
from .frontend import Frontend, FrontendConfig
from .metrics import ServingMetrics
from .net import (MSG_CANCEL, MSG_HELLO, MSG_PING, MSG_PONG,
                  MSG_SHARD_QUERY, MSG_SHARD_RESULT, MSG_STATS,
                  PROTO_VERSION, SHARD_CANCELLED, SHARD_FAILED, SHARD_OK,
                  _Session, decode_hello, decode_rid, decode_shard_query,
                  decode_shard_result, decode_stats, encode_cancel,
                  encode_hello, encode_ping, encode_shard_query,
                  encode_shard_result, encode_stats, read_frame,
                  write_frame)
from .worker import DispatchCancelled, ShardWorker


class ChannelDown(AttemptFailed):
    """The node's channel is not connected — the dispatch was never sent
    (the executor fails over without burning a wire round trip)."""


class RpcError(AttemptFailed):
    """An in-flight RPC failed because the channel died under it (torn
    frame, reset, worker killed mid-SHARD_RESULT). Distinct from
    ChannelDown so tests can assert pending futures fail with the
    channel-death error rather than a refused send."""


# -- worker side ---------------------------------------------------------------

class WorkerServer:
    """One ShardWorker process's TCP front door (protocol v4).

    ``straggle_s`` is the test/benchmark straggler hook: every dispatch
    sleeps that long BEFORE scoring, in small ticks that observe the
    cancellation flag — an injected tail that a hedged duplicate on a
    healthy worker beats, and whose cancellation is observable in
    ``cancelled_tiles``."""

    def __init__(self, worker: ShardWorker, *, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 64,
                 straggle_s: float = 0.0):
        self.worker = worker
        self.straggle_s = float(straggle_s)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(backlog)
        self.address: tuple[str, int] = self._listener.getsockname()
        self._jobs: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._conns: set[_Session] = set()
        self._conns_lock = threading.Lock()
        self._closing = False
        self._accept_thread: Optional[threading.Thread] = None
        self._scorer: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "WorkerServer":
        self._scorer = threading.Thread(target=self._score_loop,
                                        name="worker-score", daemon=True)
        self._scorer.start()
        self._accept_thread = threading.Thread(
            target=self._accept, name="worker-accept", daemon=True)
        self._accept_thread.start()
        return self

    def close(self, *, abort: bool = False) -> None:
        """Stop serving. ``abort=True`` dies like a killed process:
        every connection is severed IMMEDIATELY (clients see a dead
        peer mid-stream and fail over), queued jobs fail into the
        severed sockets instead of being drained gracefully."""
        self._closing = True
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        with self._conns_lock:
            sessions = list(self._conns)
            if abort:
                self._conns = set()
        if abort:
            for s in sessions:
                s.kick()
        self._jobs.put(None)
        if self._scorer is not None:
            self._scorer.join(timeout=5.0)
            self._scorer = None
        if not abort:
            with self._conns_lock:
                sessions, self._conns = list(self._conns), set()
        for s in sessions:
            s.finish(timeout_s=0.2 if abort else 1.0)

    # -- stats ---------------------------------------------------------------
    def stats(self) -> dict:
        w = self.worker
        return {"name": w.name,
                "shards": [int(g) for g in w.shard_ids],
                "n_docs": int(w.layout.n_docs),
                "dispatches": int(w.dispatches),
                "cancelled_tiles": int(w.cancelled_tiles),
                "pruned_dispatches": int(w.pruned_dispatches),
                "queue_depth": self._jobs.qsize()}

    # -- connection handling -------------------------------------------------
    def _accept(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                if self._closing:
                    conn.close()
                    continue
                session = _Session(conn)
                self._conns.add(session)
            threading.Thread(target=self._serve_conn, args=(session,),
                             name="worker-conn", daemon=True).start()

    def _serve_conn(self, session: _Session) -> None:
        conn = session.sock
        # rid -> cancellation flag, for every dispatch this connection
        # has in flight (rids are unique per connection; the flag is set
        # by CANCEL and observed by the scorer between shard tiles)
        flags: dict[int, threading.Event] = {}
        try:
            session.send(encode_hello(self.worker.params,
                                      self.worker.layout.n_docs,
                                      PROTO_VERSION))
            while True:
                payload = read_frame(conn)
                if payload is None or not payload:
                    return
                t = payload[0]
                if t == MSG_SHARD_QUERY:
                    (rid, gshard, buf, n_valid, cutoffs, topks,
                     n_live) = decode_shard_query(payload)
                    ev = threading.Event()
                    flags[rid] = ev
                    self._jobs.put((session, flags, rid, gshard, buf,
                                    n_valid, cutoffs, topks, n_live, ev))
                elif t == MSG_CANCEL:
                    # CANCEL follows its SHARD_QUERY on the same FIFO
                    # connection, so the flag always exists (or the
                    # dispatch already finished and was cleaned up)
                    ev = flags.get(decode_rid(payload))
                    if ev is not None:
                        ev.set()
                elif t == MSG_PING:
                    session.send(encode_ping(decode_rid(payload),
                                             pong=True))
                elif t == MSG_STATS:
                    fmt, _ = decode_stats(payload)
                    session.send(encode_stats(
                        fmt, json.dumps(self.stats()).encode()))
                else:
                    raise ConnectionError(f"unexpected message {t}")
        except (ConnectionError, OSError):
            pass
        finally:
            with self._conns_lock:
                owned = session in self._conns
                self._conns.discard(session)
            if owned:
                session.finish(timeout_s=1.0)

    # -- scoring -------------------------------------------------------------
    def _prune_tuple(self) -> tuple[int, int, int, int, int]:
        w = self.worker
        return (w.prune_stats.blocks_total, w.prune_stats.blocks_pruned,
                w.prune_stats.shard_visits_skipped,
                w.prune_stats.bytes_read, w.prune_baseline_bytes)

    def _score_loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            (session, flags, rid, gshard, buf, n_valid, cutoffs, topks,
             n_live, ev) = job
            try:
                if ev.is_set():
                    # cancelled while queued: never reached a tile
                    self.worker.cancelled_tiles += 1
                    raise DispatchCancelled("cancelled in queue")
                if self.straggle_s > 0:
                    # injected tail, ticking the cancellation flag the
                    # same way scoring checks it between tiles
                    end = time.monotonic() + self.straggle_s
                    while time.monotonic() < end:
                        if ev.is_set():
                            self.worker.cancelled_tiles += 1
                            raise DispatchCancelled("cancelled mid-tile")
                        time.sleep(0.002)
                prune0 = self._prune_tuple()
                terms_dev, nvalid_dev = self.worker.stage_batch(buf,
                                                                n_valid)
                cands, method = self.worker.score_candidates(
                    gshard, terms_dev, nvalid_dev, cutoffs, topks,
                    n_live, cancelled=ev.is_set)
                prune1 = self._prune_tuple()
                delta = tuple(b - a for a, b in zip(prune0, prune1))
                session.send(encode_shard_result(rid, SHARD_OK, method,
                                                 cands[:n_live], delta))
            except DispatchCancelled:
                session.send(encode_shard_result(rid, SHARD_CANCELLED,
                                                 "cancelled"))
            except AttemptFailed as e:
                session.send(encode_shard_result(rid, SHARD_FAILED,
                                                 str(e)))
            except Exception as e:       # noqa: BLE001 — reply, don't die
                session.send(encode_shard_result(rid, SHARD_FAILED,
                                                 repr(e)))
            finally:
                flags.pop(rid, None)


# -- frontend side -------------------------------------------------------------

# reconnect backoff: BASE * 2^attempt, capped, with +-50% jitter so a
# fleet of frontends does not redial a recovering worker in lockstep
BACKOFF_BASE_S = 0.05
BACKOFF_MAX_S = 2.0


class WorkerChannel:
    """One reconnecting channel to one worker process.

    Lives for the pool's lifetime: the connection is reused across
    batches, a dead peer fails every pending future with ``RpcError``
    (no hang — the executor fails over), and a background thread redials
    with exponential backoff + jitter until the worker returns."""

    def __init__(self, node: str, host: str, port: int, *,
                 metrics: Optional[ServingMetrics] = None,
                 timeout_s: float = 30.0,
                 backoff_base_s: float = BACKOFF_BASE_S,
                 backoff_max_s: float = BACKOFF_MAX_S):
        self.node, self.host, self.port = node, host, int(port)
        self.metrics = metrics
        self.timeout_s = timeout_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.params = None
        self.n_docs: Optional[int] = None
        self.healthy = False
        self.reconnects = 0          # successful dials after the first
        self.disconnects = 0
        self._connected_once = False
        self._sock: Optional[socket.socket] = None
        self._reader: Optional[threading.Thread] = None
        self._wlock = threading.Lock()
        self._flock = threading.Lock()
        self._pending: dict[int, Future] = {}
        self._pongs: dict[int, Future] = {}
        self._stats_futs: "queue.SimpleQueue[Future]" = queue.SimpleQueue()
        self._rids = itertools.count(1)
        self._closed = False
        # cumulative PruneStats accumulated off SHARD_RESULT deltas:
        # (blocks_total, blocks_pruned, visits_skipped, bytes_read,
        # baseline_bytes) — the remote analogue of worker.prune_stats
        self._prune = [0, 0, 0, 0, 0]
        self._redial = threading.Thread(target=self._reconnect_loop,
                                        name=f"chan-{node}", daemon=True)
        self._redial_wake = threading.Event()
        self._redial.start()

    # -- connection management -----------------------------------------------
    def _dial_once(self) -> bool:
        try:
            sock = socket.create_connection((self.host, self.port),
                                            timeout=self.timeout_s)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            hello = read_frame(sock)
            if hello is None or hello[0] != MSG_HELLO:
                sock.close()
                return False
            params, n_docs, _version = decode_hello(hello)
        except (OSError, ConnectionError):
            return False
        sock.settimeout(None)
        with self._flock:
            if self._closed:
                sock.close()
                return True              # stop redialing
            self.params, self.n_docs = params, n_docs
            self._sock = sock
            self.healthy = True
            reconnect = self._connected_once
            self._connected_once = True
            if reconnect:
                self.reconnects += 1
        self._reader = threading.Thread(target=self._read_loop,
                                        args=(sock,),
                                        name=f"chan-read-{self.node}",
                                        daemon=True)
        self._reader.start()
        if self.metrics is not None:
            self.metrics.record_channel(self.node, up=True,
                                        reconnect=reconnect)
        return True

    def _reconnect_loop(self) -> None:
        attempt = 0
        while not self._closed:
            if self.healthy:
                # park until the reader reports the channel down
                self._redial_wake.wait(timeout=0.25)
                self._redial_wake.clear()
                attempt = 0
                continue
            if self._dial_once():
                attempt = 0
                continue
            delay = min(self.backoff_max_s,
                        self.backoff_base_s * (2 ** attempt))
            time.sleep(delay * (0.5 + random.random()))
            attempt += 1

    def _fail_channel(self, err: Exception) -> None:
        """The peer died: mark unhealthy, fail EVERY pending future with
        a distinct error (no caller hangs), wake the redialer."""
        with self._flock:
            was_healthy = self.healthy
            self.healthy = False
            self._sock = None
            pending, self._pending = list(self._pending.values()), {}
            pongs, self._pongs = list(self._pongs.values()), {}
        stats = []
        while True:
            try:
                stats.append(self._stats_futs.get_nowait())
            except queue.Empty:
                break
        rpc_err = RpcError(f"channel to {self.node} "
                           f"({self.host}:{self.port}) died: {err!r}")
        for fut in pending + pongs + stats:
            _resolve(fut, error=rpc_err)
        if was_healthy:
            self.disconnects += 1
            if self.metrics is not None:
                self.metrics.record_channel(self.node, up=False)
                if pending:
                    self.metrics.record_rpc(self.node, "failed",
                                            len(pending))
        self._redial_wake.set()

    def _read_loop(self, sock: socket.socket) -> None:
        try:
            while True:
                payload = read_frame(sock)
                if payload is None or not payload:
                    raise ConnectionError("worker closed the channel")
                t = payload[0]
                if t == MSG_SHARD_RESULT:
                    rid, status, method, cands, prune = \
                        decode_shard_result(payload)
                    with self._flock:
                        fut = self._pending.pop(rid, None)
                        for i, d in enumerate(prune):
                            self._prune[i] += d
                    if fut is None:
                        continue         # cancelled and forgotten
                    if status == SHARD_OK:
                        if self.metrics is not None:
                            self.metrics.record_rpc(self.node, "ok")
                        _resolve(fut, value=(cands, method))
                    elif status == SHARD_CANCELLED:
                        _resolve(fut, error=AttemptFailed(
                            f"{self.node}: dispatch cancelled"))
                    else:
                        _resolve(fut, error=AttemptFailed(
                            f"{self.node}: {method}"))
                elif t == MSG_PONG:
                    nonce = decode_rid(payload)
                    with self._flock:
                        fut = self._pongs.pop(nonce, None)
                    if fut is not None:
                        _resolve(fut, value=True)
                elif t == MSG_STATS:
                    _, body = decode_stats(payload)
                    try:
                        sfut = self._stats_futs.get_nowait()
                    except queue.Empty:
                        raise ConnectionError("unsolicited STATS")
                    _resolve(sfut, value=body)
                else:
                    raise ConnectionError(f"unexpected message {t}")
        except Exception as e:           # noqa: BLE001 — sweep, then die
            self._fail_channel(e)

    # -- RPC surface ---------------------------------------------------------
    def submit_shard(self, gshard: int, buf: np.ndarray,
                     n_valid: np.ndarray, cutoffs: np.ndarray,
                     topks: np.ndarray, n_live: int) -> Future:
        """One shard dispatch in flight: returns a Future resolving to
        (cands, method). The rid rides on the future (``fut.rid``) so a
        hedging loser can be cancelled by id."""
        with self._flock:
            if not self.healthy or self._sock is None:
                raise ChannelDown(f"channel to {self.node} is down")
            rid = next(self._rids)
            fut: Future = Future()
            fut.rid = rid
            fut.node = self.node
            self._pending[rid] = fut
            sock = self._sock
        payload = encode_shard_query(rid, gshard, buf, n_valid, cutoffs,
                                     topks, n_live)
        try:
            with self._wlock:
                write_frame(sock, payload)
        except OSError as e:
            with self._flock:
                self._pending.pop(rid, None)
            self._fail_channel(e)
            raise ChannelDown(f"channel to {self.node} died on send") \
                from e
        if self.metrics is not None:
            self.metrics.record_rpc(self.node, "sent")
        return fut

    def cancel(self, rid: int) -> None:
        """Best-effort CANCEL: the worker checks the flag between shard
        tiles; a dispatch that already finished ignores it."""
        with self._flock:
            self._pending.pop(rid, None)
            sock = self._sock if self.healthy else None
        if sock is None:
            return
        try:
            with self._wlock:
                write_frame(sock, encode_cancel(rid))
        except OSError:
            pass
        if self.metrics is not None:
            self.metrics.record_rpc(self.node, "cancelled")

    def ping(self, timeout_s: float = 2.0) -> bool:
        """Liveness probe over the live channel (False when down)."""
        with self._flock:
            if not self.healthy or self._sock is None:
                return False
            nonce = next(self._rids)
            fut: Future = Future()
            self._pongs[nonce] = fut
            sock = self._sock
        try:
            with self._wlock:
                write_frame(sock, encode_ping(nonce))
            return bool(fut.result(timeout_s))
        except Exception:
            with self._flock:
                self._pongs.pop(nonce, None)
            return False

    def stats(self, timeout_s: float = 5.0) -> dict:
        with self._flock:
            if not self.healthy or self._sock is None:
                raise ChannelDown(f"channel to {self.node} is down")
            fut: Future = Future()
            self._stats_futs.put(fut)
            sock = self._sock
        with self._wlock:
            write_frame(sock, encode_stats(0))
        return json.loads(fut.result(timeout_s))

    def prune_counters(self) -> tuple[int, int, int, int, int]:
        with self._flock:
            return tuple(self._prune)

    def close(self) -> None:
        with self._flock:
            self._closed = True
            sock, self._sock = self._sock, None
            self.healthy = False
        self._redial_wake.set()
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            sock.close()
        if self._reader is not None:
            self._reader.join(timeout=2.0)


def _resolve(fut: Future, *, value=None, error: Exception = None) -> None:
    """Resolve a future that the hedging executor may have cancelled
    already (set_result on a cancelled Future raises)."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(value)
    except Exception:
        pass


class WorkerPool:
    """Placement node name -> live WorkerChannel.

    The pool owns the channels (connection reuse across batches and
    queries), surfaces fleet health, and aggregates the per-node
    PruneStats the frontend's metrics deltas read."""

    def __init__(self, nodes: dict[str, tuple[str, int]], *,
                 metrics: Optional[ServingMetrics] = None,
                 timeout_s: float = 30.0):
        self.channels: dict[str, WorkerChannel] = {
            node: WorkerChannel(node, host, port, metrics=metrics,
                                timeout_s=timeout_s)
            for node, (host, port) in nodes.items()}

    def bind_metrics(self, metrics: ServingMetrics) -> None:
        for ch in self.channels.values():
            ch.metrics = metrics
            metrics.record_channel(ch.node, up=ch.healthy)

    def wait_connected(self, timeout_s: float = 10.0) -> None:
        """Block until every channel has dialed its worker once."""
        deadline = time.monotonic() + timeout_s
        for ch in self.channels.values():
            while not ch.healthy:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"worker {ch.node} at {ch.host}:{ch.port} "
                        f"not reachable after {timeout_s:.0f}s")
                time.sleep(0.01)

    def channel(self, node: str) -> WorkerChannel:
        return self.channels[node]

    @property
    def params(self):
        for ch in self.channels.values():
            if ch.params is not None:
                return ch.params
        raise RuntimeError("no channel has completed its HELLO yet")

    @property
    def n_docs(self) -> int:
        for ch in self.channels.values():
            if ch.n_docs is not None:
                return ch.n_docs
        raise RuntimeError("no channel has completed its HELLO yet")

    def health(self) -> dict[str, bool]:
        return {n: ch.healthy for n, ch in self.channels.items()}

    def begin_shard(self, node: str, gshard: int, buf, n_valid, cutoffs,
                    topks, n_live: int) -> Future:
        return self.channels[node].submit_shard(gshard, buf, n_valid,
                                                cutoffs, topks, n_live)

    def cancel(self, node: str, fut: Future) -> None:
        rid = getattr(fut, "rid", None)
        if rid is not None:
            self.channels[node].cancel(rid)

    def prune_counters(self) -> tuple[int, int, int, int, int]:
        totals = [0, 0, 0, 0, 0]
        for ch in self.channels.values():
            for i, v in enumerate(ch.prune_counters()):
                totals[i] += v
        return tuple(totals)

    def close(self) -> None:
        for ch in self.channels.values():
            ch.close()


class RpcFrontend(Frontend):
    """The scatter/gather frontend over the RPC data plane.

    Identical to ``Frontend`` in everything above the dispatch seam
    (batching, gather, final selection, metrics, tracing) — only
    ``_scatter`` changes: each shard dispatch is an
    ``HedgedExecutor.run_async`` over ``WorkerPool`` channel futures, so
    hedged backups are real duplicate RPCs and losers are cancelled on
    the wire. Index parameters and document count come from the workers'
    HELLOs instead of local ShardWorker objects."""

    def __init__(self, pool: WorkerPool, placement: ShardPlacement,
                 config: FrontendConfig = FrontendConfig(), *,
                 clock: Optional[Callable[[], float]] = None):
        self.pool = pool
        self.workers: dict[str, ShardWorker] = {}   # dispatch is remote
        self.placement = placement
        self.config = config
        self.executor = HedgedExecutor(
            shards={}, hedge_after=config.hedge_after_s,
            max_hedges=config.max_hedges)
        self._simulated = False
        self.clock = clock if clock is not None else time.monotonic
        self.batcher = MicroBatcher(
            term_pad=config.term_pad, max_batch=config.max_batch,
            max_wait_s=config.max_wait_s, max_queued=config.max_queued,
            adaptive=config.adaptive_buckets)
        self.metrics = ServingMetrics()
        pool.bind_metrics(self.metrics)
        self.events = EventLog(config.trace_log,
                               ring=max(64, config.trace_ring))
        self.tracer = Tracer(enabled=config.tracing,
                             ring=config.trace_ring,
                             slow_ms=config.trace_slow_ms,
                             sink=self.events, clock=self.clock)
        self.metrics.tracer = self.tracer
        self.profiler = KernelProfiler(self.metrics.registry, None,
                                       enabled=config.profile_kernels)
        self._responses = {}
        self._next_id = 0
        self._dispatch_seq = 0
        self._seq_lock = threading.Lock()
        self.params = pool.params
        self.n_docs = pool.n_docs
        # run_async blocks a thread per in-flight shard, so the scatter
        # pool is mandatory here (sized at least one slot per shard up
        # to the configured width)
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, config.scatter_threads),
            thread_name_prefix="scatter")

    def verify_placement(self) -> dict[str, list[int]]:
        """Best-effort check that each node's worker actually holds its
        replica set (worker STATS lists its shards). Returns the gaps
        per node — empty dict when the fleet matches the placement."""
        gaps: dict[str, list[int]] = {}
        for node, held in self.placement.replica_assignment().items():
            if not held:
                continue
            try:
                shards = set(self.pool.channel(node).stats()["shards"])
            except Exception:            # noqa: BLE001
                continue                 # unreachable: checked at dispatch
            missing = [g for g in held if g not in shards]
            if missing:
                gaps[node] = missing
        return gaps

    def _scatter(self, staged, buf, n_valid, cutoffs, topks, Q: int):
        """Concurrent hedged RPC scatter: one run_async per shard on the
        scatter pool. Each dispatch fires its primary immediately, fires
        real duplicate backups on the wall clock if the primary dawdles
        past hedge_after, and cancels the loser when a winner returns."""
        ex = self.executor
        n_shards = self.placement.n_shards

        def dispatch(g: int):
            with self._seq_lock:
                self._dispatch_seq += 1
                seq = self._dispatch_seq
            return ex.run_async(
                seq, self.placement.replicas(g),
                begin=lambda node: self.pool.begin_shard(
                    node, g, buf, n_valid, cutoffs, topks, Q),
                cancel=self.pool.cancel)

        futures = [self._pool.submit(dispatch, g)
                   for g in range(n_shards)]
        out, failed = [], None
        for fut in futures:
            try:
                out.append(fut.result())
            except AllReplicasFailed as e:
                failed = e               # keep draining: pool stays clean
        if failed is not None:
            raise failed
        max_done = max((lat for _, lat, _ in out), default=0.0)
        return out, max_done

    def _tile_counters(self) -> tuple[int, int, int, int]:
        return (0, 0, 0, 0)              # tiles live in worker processes

    def _prune_counters(self) -> tuple[int, int, int, int, int]:
        return self.pool.prune_counters()

    def fail_worker(self, node: str) -> list[int]:
        return self.placement.fail(node)

    def recover_worker(self, node: str) -> list[int]:
        return self.placement.recover(node)

    def reset_metrics(self, *, clear_caches: bool = False) -> None:
        super().reset_metrics(clear_caches=clear_caches)
        self.pool.bind_metrics(self.metrics)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
        self.pool.close()
