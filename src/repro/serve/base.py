"""Shared serving-backend surface.

``QueryServer`` (single host) and ``Frontend`` (sharded scatter/gather)
present one identical control surface to their drivers: the synchronous
``step``/``drain`` loop, and the ``poll_batches`` / ``score_batch`` /
``take_response`` / ``retract`` quartet the active ``ServingLoop`` is
built on. The flush/drop accounting lives here ONCE so the two backends
cannot drift.

A backend provides ``batcher``, ``metrics``, ``clock``, ``_responses``,
and ``score_batch``. All per-request state rides on the QueryRequest
itself (terms, threshold, top_k, deadline), so a request that dies
before scoring — expired or retracted — leaves nothing behind to clean
up.
"""
from __future__ import annotations

from typing import Optional

from .batcher import MicroBatch
from .request import QueryResponse, Status


class ServingBackend:
    """Mixin: the driver-facing serving loop over a MicroBatcher."""

    def finalize_trace(self, trace, resp: QueryResponse) -> QueryResponse:
        """Attach a request's trace to its response: trace id, the
        per-stage timing breakdown (what the RESULT frame ships), and
        the Trace itself. The trace is SEALED here for synchronous
        drivers; under a ServingLoop the loop finishes it after
        callback delivery instead (``tracer.defer_finish``) so the
        slow-query log sees a "deliver" span too."""
        if trace is None:
            return resp
        resp.trace_id = trace.trace_id
        resp.trace = trace
        resp.stages = trace.stage_totals()
        tracer = getattr(self, "tracer", None)
        if tracer is not None and not tracer.defer_finish:
            tracer.finish(trace)
        return resp

    def poll_batches(self, now: Optional[float] = None, *,
                     force: bool = False) -> list[MicroBatch]:
        """Flush the batcher at ``now``: expired requests are answered
        DROPPED immediately, due micro-batches are returned for scoring
        (inline via ``step``, or from a serving-loop worker thread)."""
        now = self.clock() if now is None else now
        batches, expired = self.batcher.poll(now, force=force)
        for r in expired:
            self.metrics.record_dropped()
            if r.trace is not None:
                r.trace.add("queue_wait", r.submitted_at, now,
                            {"outcome": "dropped"})
            self._responses[r.request_id] = self.finalize_trace(
                r.trace, QueryResponse(
                    r.request_id, Status.DROPPED,
                    wait_s=max(0.0, now - r.submitted_at)))
        return batches

    def step(self, now: Optional[float] = None, *, force: bool = False
             ) -> int:
        """Score every micro-batch due at ``now``; returns requests
        answered this step (scored + dropped)."""
        dropped0 = self.metrics.dropped
        batches = self.poll_batches(now, force=force)
        n = self.metrics.dropped - dropped0
        for batch in batches:
            self.score_batch(batch)
            n += batch.size
        return n

    def drain(self) -> None:
        """Flush every queued request regardless of batch fill or
        timers."""
        while len(self.batcher):
            self.step(force=True)

    def pop_responses(self) -> dict[int, QueryResponse]:
        out = self._responses
        self._responses = {}
        return out

    def take_response(self, rid: int) -> Optional[QueryResponse]:
        """Pop one request's response if it is ready (the serving loop's
        fast-path check right after ``submit``)."""
        return self._responses.pop(rid, None)

    def retract(self, rid: int) -> bool:
        """Un-queue a just-submitted request (serving-loop backpressure:
        the caller answers it REJECTED itself). The retracted request's
        trace is sealed here — the caller's plain REJECTED response
        never passes back through finalize_trace."""
        req = self.batcher.retract_last(rid)
        if req is None:
            return False
        if req.trace is not None:
            tracer = getattr(self, "tracer", None)
            if tracer is not None:
                req.trace.add("reject", req.submitted_at, self.clock(),
                              {"reason": "backpressure"})
                tracer.finish(req.trace)
        return True
