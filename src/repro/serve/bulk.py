"""Offline bulk-query lane: shard-major streaming scans.

The interactive lane is query-major — every micro-batch visits every
shard, so a Q-query workload split into Q/B micro-batches restages each
tile up to Q/B times through a bounded ``DeviceTileCache``. The bulk
lane inverts the loop for deadline-free jobs (decontamination scans,
eval-set sweeps): ``core.query.run_shard_major`` stages each shard tile
into HBM ONCE (raw or dict form, the next shard prefetched while the
current one scores), streams the ENTIRE query set against it in
query-chunks, and accumulates per-(query, block) running counts with
the same rarest-first ordering and threshold early-exit the pruned
executor uses. The headline number is arena bytes staged per query: one
staging amortized over Q queries instead of Q/B stagings.

Scheduling: a ``BulkLane`` attached to a ``ServingLoop`` runs jobs on
its own thread, one shard at a time and WITHOUT the loop's backend
lock — the shared ``DeviceTileCache`` is internally locked and staged
tiles are immutable, so interactive batches keep scoring concurrently
while a shard sweeps (they contend only for the device, not a lock).
Between shards the lane polls ``MicroBatcher.next_due_at()`` (plus the
loop's in-flight batch count) and stops claiming shards whenever
interactive work is due — the p99-protection contract. Every completed
shard is a checkpoint:
``(next_shard, slots, required)`` round-trips through ``BulkJob.
checkpoint()`` / ``submit(resume=...)``, so an interrupted sweep resumes
without rescoring finished shards.

Threshold jobs can instead reuse ``run_paged_pruned`` per shard
(``pruned=True``): the branch-and-bound executor host-gathers only the
touched rows, so highly selective scans (decontamination at high
coverage thresholds) may never stage a tile at all — yield points and
checkpoints work identically.

Without a loop the lane is synchronous: ``submit()`` queues and
``drain()`` executes inline — the property-test entry point.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from ..core.query import (BulkStats, PruneStats, SearchResult,
                          compile_pattern, coverage_cutoff,
                          order_terms_rarest, pad_term_batch,
                          run_paged_pruned, run_shard_major, select_hits,
                          select_top_k)

# Dense shared padding for a bulk set: the sublane quantum, not the
# interactive lane's jit-bucket ``term_pad`` — one sweep compiles one
# shape anyway, so the only cost of padding is masked kernel work.
BULK_TERM_QUANTUM = 8


class BulkStatus(str, enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclasses.dataclass
class BulkJob:
    """One bulk query set sweeping the store.

    ``slots`` / ``required`` / ``next_shard`` are the live sweep state
    (global slot scores accumulate shard by shard) and double as the
    checkpoint. Queries are sorted by term count before the sweep
    (``perm``) so slabs stay length-homogeneous and short-query slabs
    exit their chunk loop early; ``results`` is mapped back to
    submission order at finalize."""

    job_id: int
    terms: np.ndarray               # uint32 [Q, L, 2], sorted by length
    n_valid: np.ndarray             # int32 [Q], sorted
    perm: np.ndarray                # int64 [Q]: sorted pos -> orig index
    threshold: float
    top_k: int
    pruned: bool = False            # per-shard run_paged_pruned instead
    tag: str = ""
    status: BulkStatus = BulkStatus.QUEUED
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0
    shards_total: int = 0
    next_shard: int = 0
    slots: Optional[np.ndarray] = None      # int32 [Q, n_slots]
    required: Optional[np.ndarray] = None   # int64 [Q], tightens (top-k)
    topk: Optional[np.ndarray] = None       # int32 [Q]
    order: Optional[np.ndarray] = None      # rarest-first term order
    stats: BulkStats = dataclasses.field(default_factory=BulkStats)
    prune: PruneStats = dataclasses.field(default_factory=PruneStats)
    results: Optional[list] = None          # SearchResult per query
    error: str = ""
    checkpoint_path: Optional[str] = None
    on_done: Optional[Callable] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    trace: Optional[object] = dataclasses.field(default=None, repr=False,
                                                compare=False)

    @property
    def n_queries(self) -> int:
        return int(self.terms.shape[0])

    @property
    def shards_done(self) -> int:
        return int(self.next_shard)

    @property
    def progress(self) -> float:
        if not self.shards_total:
            return 0.0
        return self.next_shard / self.shards_total

    @property
    def staged_bytes(self) -> int:
        return self.stats.bytes_staged

    @property
    def staged_bytes_per_query(self) -> float:
        q = self.n_queries
        return self.stats.bytes_staged / q if q else 0.0

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.done.wait(timeout)

    def checkpoint(self) -> dict:
        """Resumable sweep state after the last completed shard."""
        return {
            "next_shard": int(self.next_shard),
            "slots": None if self.slots is None else self.slots.copy(),
            "required": (None if self.required is None
                         else self.required.copy()),
        }

    def save(self, path) -> None:
        np.savez_compressed(
            path, next_shard=np.int64(self.next_shard),
            slots=self.slots if self.slots is not None
            else np.zeros((0, 0), np.int32),
            required=self.required if self.required is not None
            else np.zeros(0, np.int64))

    @staticmethod
    def load(path) -> dict:
        with np.load(path) as z:
            return {"next_shard": int(z["next_shard"]),
                    "slots": z["slots"], "required": z["required"]}


class BulkLane:
    """Scheduler for shard-major bulk sweeps over a serving backend.

    ``backend`` is a ``QueryServer`` or multi-host ``Frontend`` (the
    sweep walks each shard's primary worker's tile cache); ``loop`` an
    optional ``ServingLoop`` — with one, ``start()`` spawns the bulk
    thread: sweeps run concurrently with interactive scoring (the tile
    cache is internally locked) and the lane yields between shards when
    interactive work is due. Without one the lane is synchronous:
    ``drain()`` runs queued jobs inline."""

    def __init__(self, backend, loop=None, *, chunk_terms: int = 32,
                 query_chunk: Optional[int] = None,
                 word_block: Optional[int] = None,
                 yield_poll_s: float = 0.002,
                 headroom_s: float = 0.0):
        self.backend = backend
        self.loop = loop
        self.chunk_terms = int(chunk_terms)
        self.query_chunk = query_chunk
        self.word_block = (word_block if word_block is not None else
                           getattr(getattr(backend, "config", None),
                                   "word_block", None))
        self.yield_poll_s = float(yield_poll_s)
        self.headroom_s = float(headroom_s)
        self.clock = getattr(backend, "clock", time.monotonic)
        self._queue: deque = deque()
        self._jobs: dict[int, BulkJob] = {}
        self._next_id = 1
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        if loop is not None:
            loop.bulk_lane = self

    # -- backend topology ---------------------------------------------------
    def _params(self):
        be = self.backend
        if hasattr(be, "workers"):
            return next(iter(be.workers.values())).params
        return be.index.params

    def _layout(self):
        be = self.backend
        if hasattr(be, "workers"):
            return next(iter(be.workers.values())).layout
        return be.index.layout

    def _targets(self) -> tuple[list, list]:
        """(caches, plans) in global shard order — the sweep schedule.

        Multi-host: each shard is swept on its primary worker's tiles
        (first live replica when the primary is down); block ranges are
        global, so every worker's slots land at global columns."""
        be = self.backend
        if not hasattr(be, "workers"):
            plans = be.planner.shard_plans
            return [be.tiles] * len(plans), list(plans)
        caches, plans = [], []
        n_shards = be.placement.n_shards
        for g in range(n_shards):
            w = None
            for node in [be.placement.owner(g)] + be.placement.replicas(g):
                cand = be.workers.get(node)
                if cand is not None and cand.holds(g) and not cand.failed:
                    w = cand
                    break
            if w is None:
                raise RuntimeError(f"shard {g} has no live replica")
            caches.append(w.tiles)
            plans.append(w.plans[w._local[g]])
        return caches, plans

    # -- submission ---------------------------------------------------------
    def submit(self, patterns=None, *, term_sets=None,
               threshold: Optional[float] = None, top_k: int = 0,
               pruned: bool = False, tag: str = "",
               resume: Optional[dict] = None,
               checkpoint_path=None,
               on_done: Optional[Callable] = None) -> BulkJob:
        """Queue a bulk job. ``patterns`` (DNA strings / code arrays) or
        pre-compiled ``term_sets``; threshold XOR top_k per job. With a
        running lane thread the job starts when the queue reaches it;
        otherwise call ``drain()``. ``resume`` is a ``checkpoint()``
        dict (or ``BulkJob.load(path)``) from a prior partial sweep."""
        params = self._params()
        if term_sets is None:
            term_sets = [compile_pattern(p, params) for p in patterns]
        if threshold is None:
            threshold = float(getattr(getattr(self.backend, "config", None),
                                      "default_threshold", 0.5))
        buf, ells = pad_term_batch(term_sets, BULK_TERM_QUANTUM)
        ells = np.asarray(ells, dtype=np.int32)
        # Length-sorted sweep order: slabs stay dense (short-query slabs
        # break out of the term-chunk loop early) — adaptive batching's
        # histogram idea applied to the bulk set.
        perm = np.argsort(ells, kind="stable")
        buf, ells = buf[perm], ells[perm]
        Q = int(buf.shape[0])
        if top_k > 0:
            required = np.zeros(Q, dtype=np.int64)
            topk = np.full(Q, int(top_k), dtype=np.int32)
        else:
            required = np.array(
                [coverage_cutoff(threshold, int(e)) for e in ells],
                dtype=np.int64)
            topk = np.zeros(Q, dtype=np.int32)
        if pruned and top_k > 0:
            raise ValueError("pruned bulk mode serves threshold scans; "
                             "top-k jobs use the shard-major executor")
        with self._lock:
            job = BulkJob(job_id=self._next_id, terms=buf, n_valid=ells,
                          perm=perm, threshold=float(threshold),
                          top_k=int(top_k), pruned=bool(pruned), tag=tag,
                          required=required, topk=topk,
                          checkpoint_path=checkpoint_path,
                          on_done=on_done, submitted_at=self.clock())
            self._next_id += 1
            if resume is not None:
                job.next_shard = int(resume["next_shard"])
                if resume.get("slots") is not None and \
                        np.asarray(resume["slots"]).size:
                    job.slots = np.array(resume["slots"], dtype=np.int32)
                if resume.get("required") is not None and \
                        np.asarray(resume["required"]).size:
                    job.required = np.array(resume["required"],
                                            dtype=np.int64)
            self._jobs[job.job_id] = job
            self._queue.append(job)
        self._wake.set()
        return job

    def get(self, job_id: int) -> Optional[BulkJob]:
        with self._lock:
            return self._jobs.get(job_id)

    def jobs(self) -> list[BulkJob]:
        with self._lock:
            return list(self._jobs.values())

    def cancel(self, job_id: int) -> bool:
        """Cancel a queued job (running jobs finish their sweep)."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.status is not BulkStatus.QUEUED:
                return False
            job.status = BulkStatus.CANCELLED
            try:
                self._queue.remove(job)
            except ValueError:
                pass
        self._metrics().record_bulk_job("cancelled", queries=job.n_queries)
        job.done.set()
        return True

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "BulkLane":
        if self._thread is None:
            self._stopped = False
            self._thread = threading.Thread(target=self._run,
                                            name="bulk-lane", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout_s: float = 10.0) -> None:
        """Halt the lane thread. A mid-sweep job stays checkpointed at
        its last completed shard and returns to the queue head."""
        self._stopped = True
        self._wake.set()
        t = self._thread
        if t is not None:
            t.join(timeout_s)
            self._thread = None

    def drain(self) -> None:
        """Run every queued job to completion inline (synchronous mode —
        also valid with a loop stopped or not yet started)."""
        while True:
            with self._lock:
                if not self._queue:
                    return
                job = self._queue.popleft()
            if job.status is BulkStatus.QUEUED:
                self._execute(job, preemptible=False)

    # -- scheduling ---------------------------------------------------------
    def _run(self) -> None:
        while not self._stopped:
            with self._lock:
                job = self._queue.popleft() if self._queue else None
            if job is None:
                self._wake.wait(0.05)
                self._wake.clear()
                continue
            if job.status is not BulkStatus.QUEUED:
                continue
            self._execute(job, preemptible=True)
            if self._stopped and job.status is BulkStatus.RUNNING:
                # checkpointed mid-sweep: back to the queue for a restart
                job.status = BulkStatus.QUEUED
                with self._lock:
                    self._queue.appendleft(job)

    def _interactive_clear(self) -> bool:
        loop = self.loop
        if loop is None:
            return True
        if loop._inflight > 0 or not loop._batchq.empty():
            return False
        due = self.backend.batcher.next_due_at()
        return due is None or (due - self.clock()) > self.headroom_s

    def _metrics(self):
        return self.backend.metrics

    # -- execution ----------------------------------------------------------
    def _execute(self, job: BulkJob, *, preemptible: bool) -> None:
        try:
            caches, plans = self._targets()
            job.shards_total = len(plans)
            job.status = BulkStatus.RUNNING
            job.started_at = self.clock()
            tracer = getattr(self.backend, "tracer", None)
            if tracer is not None and job.trace is None:
                job.trace = tracer.begin(job.job_id)
            if job.order is None and plans:
                own = [sp for ca, sp in zip(caches, plans)
                       if ca is caches[0]]
                job.order = order_terms_rarest(
                    caches[0].storage, own, job.terms, job.n_valid,
                    n_hashes=self._params().n_hashes)
            yielded = False
            while job.next_shard < job.shards_total:
                if self._stopped and preemptible:
                    return                      # checkpointed; requeued
                if preemptible and not self._interactive_clear():
                    if not yielded:
                        yielded = True
                        self._metrics().record_bulk_yield()
                    time.sleep(self.yield_poll_s)
                    continue
                yielded = False
                self._step(job, caches, plans)
            self._finalize(job)
        except Exception as e:               # pragma: no cover - defensive
            job.status = BulkStatus.FAILED
            job.error = f"{type(e).__name__}: {e}"
            job.finished_at = self.clock()
            self._metrics().record_bulk_job("failed",
                                            queries=job.n_queries)
            tracer = getattr(self.backend, "tracer", None)
            if tracer is not None:
                tracer.finish(job.trace)
            job.done.set()
            if job.on_done is not None:
                job.on_done(job)

    def _step(self, job: BulkJob, caches: list, plans: list) -> None:
        """Sweep exactly one shard — the yield granularity. The step runs
        WITHOUT the loop's backend lock: the ``DeviceTileCache`` is
        internally locked and staged tiles are immutable device arrays,
        so interactive batches score concurrently instead of queueing
        behind a whole shard sweep; the lane merely stops claiming new
        shards while interactive work is due."""
        t0 = time.perf_counter()
        staged0 = job.stats.bytes_staged
        si = job.next_shard
        if job.pruned:
            self._step_pruned(job, caches[si], plans[si])
            job.next_shard = si + 1
        else:
            job.slots, job.next_shard, job.required = run_shard_major(
                caches, plans, job.terms, job.n_valid, job.required,
                job.topk, n_hashes=self._params().n_hashes,
                chunk_terms=self.chunk_terms,
                query_chunk=self.query_chunk,
                word_block=self.word_block, order=job.order,
                stats=job.stats, start_shard=si, out=job.slots,
                should_yield=lambda: True)
        dt = time.perf_counter() - t0
        staged = job.stats.bytes_staged - staged0
        self._metrics().record_bulk_shard(staged_bytes=staged, seconds=dt)
        if job.trace is not None:
            now = self.clock()
            job.trace.add("bulk_shard", now - dt, now,
                          tags={"shard": si, "staged_bytes": staged,
                                "job": job.job_id})
        if job.checkpoint_path:
            job.save(job.checkpoint_path)

    def _step_pruned(self, job: BulkJob, cache, sp) -> None:
        """Satellite reuse: one shard of a threshold scan through the
        branch-and-bound executor — host row gathers instead of a tile
        staging wherever the bound holds, device-promoted past the
        gather break-even. Bit-identical by ``run_paged_pruned``'s own
        contract."""
        W = int(cache.storage.shape[1])
        if job.slots is None:
            _, plans = self._targets()
            ncols = max(p.block_end for p in plans) * W * 32
            job.slots = np.zeros((job.n_queries, ncols), dtype=np.int32)
        b0 = cache.raw_bytes_staged + cache.comp_bytes_staged
        ps = PruneStats()
        scores = run_paged_pruned(
            cache, [sp], job.terms, job.n_valid, job.required, job.topk,
            n_hashes=self._params().n_hashes, chunk_terms=self.chunk_terms,
            word_block=self.word_block, order=job.order, stats=ps)
        moved = (cache.raw_bytes_staged + cache.comp_bytes_staged) - b0
        if moved:
            job.stats.tiles_staged += 1
            job.stats.bytes_staged += moved
        job.stats.shards_swept += 1
        job.stats.kernel_dispatches += ps.kernel_dispatches
        job.stats.blocks_total += ps.blocks_total
        job.stats.blocks_pruned += ps.blocks_pruned
        job.prune.merge(ps)
        m = self._metrics()
        if hasattr(m, "record_prune"):
            m.record_prune(blocks_total=ps.blocks_total,
                           blocks_pruned=ps.blocks_pruned,
                           tiles_skipped=ps.shard_visits_skipped,
                           bytes_saved=cache.storage.shard_nbytes(sp.shard)
                           - ps.bytes_read)
        col0 = sp.block_start * W * 32
        job.slots[:, col0:col0 + scores.shape[1]] = scores

    def _finalize(self, job: BulkJob) -> None:
        layout = self._layout()
        host_slot = np.asarray(layout.doc_slot)
        inv = np.empty_like(job.perm)
        inv[job.perm] = np.arange(job.perm.shape[0])
        results: list[SearchResult] = []
        for i in range(job.n_queries):
            p = int(inv[i])                  # sorted position of query i
            sc = job.slots[p][host_slot] if job.slots is not None else \
                np.zeros(layout.n_docs, dtype=np.int32)
            ell = int(job.n_valid[p])
            if job.top_k > 0:
                results.append(select_top_k(sc, ell, job.top_k))
            else:
                results.append(select_hits(sc, ell, job.threshold))
        job.results = results
        job.status = BulkStatus.DONE
        job.finished_at = self.clock()
        self._metrics().record_bulk_job("done", queries=job.n_queries)
        tracer = getattr(self.backend, "tracer", None)
        if tracer is not None:
            tracer.finish(job.trace)
        job.done.set()
        if job.on_done is not None:
            job.on_done(job)
