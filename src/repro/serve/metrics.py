"""Serving metrics: latency percentiles, batch occupancy, cache hit rate.

Plain in-process counters — the aggregation a production exporter would
scrape. Latencies are recorded per REQUEST (queue wait + service), batch
stats per micro-batch, so occupancy weighs each flush equally while the
percentiles weigh each query.

The multi-host frontend additionally records per-worker dispatch
latencies, hedge fires (backup requests issued by the HedgedExecutor),
hedge wins (backups that beat the primary), and failovers (dispatches
served by a non-primary replica because the primary was down); the tile
counters grew prefetch accounting for the double-buffered shard staging.

The network front-end (repro.serve.loop / repro.serve.net) adds three
gauges: ``queue_depth`` (batcher backlog, sampled by the dispatcher each
loop iteration, plus the high-water mark), ``connections`` (open client
sessions + the lifetime total), and the coalescing rate — batched
requests per kernel dispatch, the number that tells whether concurrent
independent clients actually share micro-batches (the bit-sliced
design's one-kernel-per-batch economics depend on it being > 1).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import Counter, deque

import numpy as np


@dataclasses.dataclass
class MetricsSnapshot:
    served: int
    rejected: int
    dropped: int
    cache_hits: int
    batches: int
    p50_ms: float
    p99_ms: float
    mean_occupancy: float
    cache_hit_rate: float
    methods: dict[str, int]
    # out-of-core arena paging (0 / empty for dense single-shard indexes)
    page_faults: int = 0
    tile_hits: int = 0
    resident_tiles: int = 0
    tile_hit_rate: float = 0.0
    # double-buffered prefetch (0 when paging is demand-only)
    prefetched_tiles: int = 0
    prefetch_hits: int = 0
    prefetch_hit_rate: float = 0.0
    # serving-loop / network front-end gauges
    queue_depth: int = 0          # batcher backlog at the last sample
    max_queue_depth: int = 0      # backlog high-water mark
    connections: int = 0          # open client sessions
    total_connections: int = 0    # sessions ever accepted
    coalesce_rate: float = 0.0    # batched requests per kernel dispatch
    # multi-host dispatch (0 / empty for the single-host QueryServer)
    failed: int = 0          # requests unservable (shard lost all replicas)
    dispatches: int = 0
    hedges_fired: int = 0
    hedges_won: int = 0
    hedge_fire_rate: float = 0.0
    failovers: int = 0
    worker_p99_ms: dict[str, float] = dataclasses.field(default_factory=dict)

    def report(self) -> str:
        meth = " ".join(f"{m}={n}" for m, n in sorted(self.methods.items()))
        s = (f"served={self.served} rejected={self.rejected} "
             f"dropped={self.dropped} batches={self.batches} "
             f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
             f"occupancy={self.mean_occupancy:.2f} "
             f"cache_hit_rate={self.cache_hit_rate:.2f} "
             f"tiles[resident={self.resident_tiles} "
             f"faults={self.page_faults} "
             f"hit_rate={self.tile_hit_rate:.2f} "
             f"prefetch_hit_rate={self.prefetch_hit_rate:.2f}] "
             f"dispatch[{meth}]")
        if self.total_connections or self.max_queue_depth:
            s += (f" net[conns={self.connections}/"
                  f"{self.total_connections} "
                  f"queue_depth={self.queue_depth} "
                  f"max_depth={self.max_queue_depth} "
                  f"coalesce={self.coalesce_rate:.2f}]")
        if self.dispatches:
            workers = " ".join(f"{w}={p:.2f}ms"
                               for w, p in sorted(self.worker_p99_ms.items()))
            s += (f" shard_rpcs[n={self.dispatches} "
                  f"hedge_rate={self.hedge_fire_rate:.3f} "
                  f"hedges_won={self.hedges_won} "
                  f"failovers={self.failovers} failed={self.failed}] "
                  f"workers_p99[{workers}]")
        return s


class ServingMetrics:
    """``window`` bounds the per-request/per-batch sample history (sliding
    window for the percentiles); the integer counters stay exact totals
    for the server's whole lifetime."""

    def __init__(self, window: int = 65536):
        self.latencies_s: "deque[float]" = deque(maxlen=window)
        self.wait_s: "deque[float]" = deque(maxlen=window)
        self.service_s: "deque[float]" = deque(maxlen=window)
        self.occupancies: "deque[float]" = deque(maxlen=window)
        self.batch_sizes: "deque[int]" = deque(maxlen=window)
        self.method_counts: Counter[str] = Counter()
        self.served = 0
        self.rejected = 0
        self.dropped = 0
        self.cache_hits = 0
        self.n_batches = 0
        self.page_faults = 0
        self.tile_hits = 0
        self.resident_tiles = 0
        self.prefetched_tiles = 0
        self.prefetch_hits = 0
        self.failed = 0
        self.dispatches = 0
        self.hedges_fired = 0
        self.hedges_won = 0
        self.failovers = 0
        self.batched_requests = 0   # requests served through a micro-batch
        self.queue_depth = 0
        self.max_queue_depth = 0
        self.connections = 0
        self.total_connections = 0
        self._window = window
        self._conn_lock = threading.Lock()
        self.worker_lat_s: dict[str, "deque[float]"] = {}
        # small recent-sample window per worker, for consumers that
        # re-derive statistics on the hot path (adaptive hedging computes
        # a p95 per batch — over 128 recent samples, not the full window)
        self.worker_recent_s: dict[str, "deque[float]"] = {}

    # -- recording ---------------------------------------------------------
    def record_request(self, *, wait_s: float, service_s: float,
                       cached: bool = False) -> None:
        self.served += 1
        self.wait_s.append(wait_s)
        self.service_s.append(service_s)
        self.latencies_s.append(wait_s + service_s)
        if cached:
            self.cache_hits += 1

    def record_batch(self, size: int, occupancy: float, method: str) -> None:
        self.batch_sizes.append(size)
        self.occupancies.append(occupancy)
        self.method_counts[method] += size
        self.n_batches += 1
        self.batched_requests += size

    def set_queue_depth(self, depth: int) -> None:
        """Gauge: batcher backlog (sampled by the serving loop)."""
        self.queue_depth = depth
        self.max_queue_depth = max(self.max_queue_depth, depth)

    def record_connection(self, delta: int) -> None:
        """Gauge: a client session opened (+1) or closed (-1). Called
        from per-connection threads — unlike every other recorder (which
        the serving loop serializes), this one locks its own counters."""
        with self._conn_lock:
            self.connections += delta
            if delta > 0:
                self.total_connections += delta

    def record_rejected(self) -> None:
        self.rejected += 1

    def record_dropped(self) -> None:
        self.dropped += 1

    def record_failed(self) -> None:
        """A request that could not be served: some shard it needs has no
        live replica left."""
        self.failed += 1

    def record_tiles(self, *, hits: int, faults: int, resident: int,
                     prefetched: int = 0, prefetch_hits: int = 0) -> None:
        """Device-tile cache activity for one scoring pass: cache hits,
        page faults (host->device shard stages, prefetches included), the
        resident-tile gauge after the pass, and the prefetch counters."""
        self.tile_hits += hits
        self.page_faults += faults
        self.resident_tiles = resident
        self.prefetched_tiles += prefetched
        self.prefetch_hits += prefetch_hits

    def record_worker(self, worker: str, latency_s: float) -> None:
        """One shard dispatch served by ``worker`` (hedged or not)."""
        self.dispatches += 1
        q = self.worker_lat_s.get(worker)
        if q is None:
            q = self.worker_lat_s[worker] = deque(maxlen=self._window)
            self.worker_recent_s[worker] = deque(maxlen=128)
        q.append(latency_s)
        self.worker_recent_s[worker].append(latency_s)

    def record_hedges(self, *, fired: int, won: int) -> None:
        self.hedges_fired += fired
        self.hedges_won += won

    def record_failovers(self, n: int) -> None:
        self.failovers += n

    # -- reading -----------------------------------------------------------
    def percentile_ms(self, p: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.fromiter(self.latencies_s, float),
                                   p) * 1e3)

    def snapshot(self) -> MetricsSnapshot:
        n_cacheable = self.served
        n_tiles = self.tile_hits + self.page_faults
        return MetricsSnapshot(
            page_faults=self.page_faults,
            tile_hits=self.tile_hits,
            resident_tiles=self.resident_tiles,
            tile_hit_rate=(self.tile_hits / n_tiles if n_tiles else 0.0),
            prefetched_tiles=self.prefetched_tiles,
            prefetch_hits=self.prefetch_hits,
            prefetch_hit_rate=(self.prefetch_hits / self.prefetched_tiles
                               if self.prefetched_tiles else 0.0),
            queue_depth=self.queue_depth,
            max_queue_depth=self.max_queue_depth,
            connections=self.connections,
            total_connections=self.total_connections,
            coalesce_rate=(self.batched_requests / self.n_batches
                           if self.n_batches else 0.0),
            failed=self.failed,
            dispatches=self.dispatches,
            hedges_fired=self.hedges_fired,
            hedges_won=self.hedges_won,
            hedge_fire_rate=(self.hedges_fired / self.dispatches
                             if self.dispatches else 0.0),
            failovers=self.failovers,
            worker_p99_ms={
                w: float(np.percentile(np.fromiter(q, float), 99) * 1e3)
                for w, q in sorted(self.worker_lat_s.items()) if q},
            served=self.served,
            rejected=self.rejected,
            dropped=self.dropped,
            cache_hits=self.cache_hits,
            batches=self.n_batches,
            p50_ms=self.percentile_ms(50),
            p99_ms=self.percentile_ms(99),
            mean_occupancy=(float(np.mean(self.occupancies))
                            if self.occupancies else 0.0),
            cache_hit_rate=(self.cache_hits / n_cacheable
                            if n_cacheable else 0.0),
            methods=dict(self.method_counts),
        )
